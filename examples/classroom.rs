//! The classroom deployment (paper §5.2): students behind the REST API
//! with a curated model list, per-student quotas, and RAG-style course
//! material uploaded through the delegated cache.
//!
//! Reports the §5.2 numbers: model mix, prompt-style association,
//! total inference cost (paper: <$10 across three courses).
//!
//! ```sh
//! cargo run --release --example classroom -- [--requests 300]
//! ```

use llmbridge::api::{Request, ServiceType};
use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::models::pricing::ModelId;
use llmbridge::util::cli::Args;
use llmbridge::workload::classroom::{self, PromptStyle};
use llmbridge::workload::corpus;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("requests", 300);
    let bridge = Bridge::open_with(
        args.get_or("artifacts", "artifacts"),
        BridgeConfig::default(),
    )?;

    // Course materials uploaded by students: FAQ + policy documents, chunked
    // and indexed by the delegated PUT (§5.2 "supporting RAG-style
    // workflows").
    let mut chunks = 0;
    for topic in ["education", "technology", "health"] {
        let (ids, _) = bridge.cache().put_delegated(
            bridge.generator(),
            ModelId::Phi3Mini,
            &format!("{topic} faq"),
            &corpus::faq_document(topic),
        )?;
        chunks += ids.len();
        let (ids, _) = bridge.cache().put_delegated(
            bridge.generator(),
            ModelId::Phi3Mini,
            &format!("{topic} policy"),
            &corpus::policy_document(topic),
        )?;
        chunks += ids.len();
    }
    println!("course materials indexed: {chunks} chunks\n");

    let allowed = vec![
        ModelId::Gpt4oMini,
        ModelId::Claude3Haiku,
        ModelId::Llama38b,
        ModelId::Phi3Mini,
    ];
    let reqs = classroom::generate(args.u64_or("seed", 42), 60, 145, n);
    let mut served = 0;
    let mut quota_rejections = 0;
    let mut imperative_by_model: std::collections::BTreeMap<&str, (u32, u32)> =
        Default::default();
    for r in &reqs {
        let mut req = Request::new(&r.student, &format!("{}-{}", r.course, r.student), &r.prompt)
            .service_type(ServiceType::UsageBased {
                allowed: allowed.clone(),
                fallback: ModelId::Gpt4oMini,
            })
            .with_traits(r.traits.clone());
        req.params.insert("model".into(), r.model.as_str().into());
        match bridge.handle(req) {
            Ok(_) => served += 1,
            Err(_) => quota_rejections += 1,
        }
        let e = imperative_by_model.entry(r.model.as_str()).or_default();
        if r.style == PromptStyle::Imperative {
            e.0 += 1;
        }
        e.1 += 1;
    }

    let t = bridge.telemetry();
    println!("== classroom report (paper §5.2) ==");
    println!("requests served:    {served} (quota rejections: {quota_rejections})");
    println!("total inference cost: ${:.4}  (paper: <$10 for 75K requests)", t.costs.total_usd());
    println!("\nmodel mix (paper: 73% 4o-mini / 13% haiku / 13% llama / 1% phi):");
    for (model, usage) in t.costs.per_model() {
        println!(
            "  {model:<18} calls={:<5} in={:<7} out={:<6} ${:.4}",
            usage.calls, usage.input_tokens, usage.output_tokens, usage.cost_usd
        );
    }
    println!("\nprompt style by model (paper: Phi-3 prompts are imperative/rule-based):");
    for (model, (imp, total)) in imperative_by_model {
        println!(
            "  {model:<18} imperative {imp}/{total} ({:.0}%)",
            100.0 * imp as f64 / total as f64
        );
    }
    Ok(())
}
