//! Quickstart: open the proxy, send one prompt under each delegation level,
//! inspect the transparency metadata, and regenerate for a better answer.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! The default build serves from the deterministic backend (no artifacts
//! needed); under `--features pjrt` run `make artifacts` first.

use llmbridge::api::{Request, ServiceType};
use llmbridge::coordinator::Bridge;
use llmbridge::models::pricing::ModelId;

fn show(tag: &str, resp: &llmbridge::api::Response) {
    let m = &resp.metadata;
    let models: Vec<String> = m
        .models_used
        .iter()
        .map(|(model, role)| format!("{model}[{role}]"))
        .collect();
    println!(
        "{tag:<16} cost=${:<9.6} in={:<4} out={:<3} ctx={} cache={:?} models={}",
        m.cost_usd,
        m.input_tokens,
        m.output_tokens,
        m.context_messages,
        m.cache,
        models.join(", ")
    );
}

fn main() -> anyhow::Result<()> {
    let bridge = Bridge::open("artifacts")?;
    let user = "quickstart";
    let prompt = "tell me about vaccination and why people in my community talk about it so much";

    // 1. Full delegation: the proxy picks models via the verification
    //    cascade (§3.3).
    let resp = bridge.handle(
        Request::new(user, "c1", prompt).service_type(ServiceType::default()),
    )?;
    show("model_selector", &resp);
    let first_id = resp.metadata.request_id;

    // 2. Explicit low-level control (Table 2's `fixed`).
    let resp = bridge.handle(Request::new(user, "c2", prompt).service_type(
        ServiceType::Fixed {
            model: ModelId::Gpt4oMini,
            cache: llmbridge::api::CachePolicy::Skip,
            context_k: 0,
        },
    ))?;
    show("fixed(4o-mini)", &resp);

    // 3. The cost/quality extremes.
    let resp = bridge
        .handle(Request::new(user, "c3", prompt).service_type(ServiceType::Cost))?;
    show("cost", &resp);
    let resp = bridge
        .handle(Request::new(user, "c4", prompt).service_type(ServiceType::Quality))?;
    show("quality", &resp);

    // 4. Iterate: not satisfied? regenerate() nudges toward quality
    //    (the WhatsApp "Get Better Answer" button).
    let better = bridge.regenerate(first_id, None)?;
    show("regenerate", &better);

    // 5. Everything is also visible through telemetry.
    println!(
        "\ntotal spent: ${:.6} across {} requests",
        bridge.telemetry().costs.total_usd(),
        bridge.telemetry().counters.get("requests"),
    );
    Ok(())
}
