//! The WhatsApp Q&A service (paper §5.1) rebuilt on the public API:
//! free-form questions, prefetched follow-up buttons (exact-cache hits),
//! "Get Better Answer" regeneration, trending-content pushes, and the
//! points leaderboard — all driven by a seeded deployment event stream.
//!
//! ```sh
//! cargo run --release --example whatsapp_qa -- [--users 6] [--turns 8]
//! ```

use llmbridge::api::{CacheOutcome, Request, ServiceType};
use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::models::pricing::ModelId;
use llmbridge::util::cli::Args;
use llmbridge::util::json::Json;
use llmbridge::workload::whatsapp::{Event, WhatsAppWorkload};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let users = args.usize_or("users", 6);
    let turns = args.usize_or("turns", 8);
    let bridge = Bridge::open_with(
        args.get_or("artifacts", "artifacts"),
        BridgeConfig {
            prefetch_followups: true, // the §5.1 latency-masking strategy
            ..Default::default()
        },
    )?;

    let workload = WhatsAppWorkload::generate(args.u64_or("seed", 7), users, turns);
    println!(
        "WhatsApp Q&A: {} users, {} events ({} conversations)\n",
        users,
        workload.events.len(),
        workload.conversations.len()
    );

    let mut last_request_id = vec![None; workload.conversations.len()];
    let mut button_hits = 0u32;
    let mut button_presses = 0u32;
    for event in &workload.events {
        match event {
            Event::Ask { conv, query } => {
                let c = &workload.conversations[*conv];
                let req = Request::new(&c.user, &c.id, &query.text)
                    .service_type(ServiceType::default())
                    .with_traits(query.traits.clone());
                let resp = bridge.handle(req)?;
                last_request_id[*conv] = Some(resp.metadata.request_id);
                // Points: 10 per question, tracked in the KV substrate.
                bridge.kv().update(&format!("points:{}", c.user), |old| {
                    Json::num(old.and_then(|j| j.as_f64()).unwrap_or(0.0) + 10.0)
                });
            }
            Event::Button { conv, prompt } => {
                // Follow-up button press: served from the prefetched exact
                // cache when the prefetcher anticipated it.
                let c = &workload.conversations[*conv];
                let req = Request::new(&c.user, &c.id, prompt).service_type(
                    ServiceType::Fixed {
                        model: ModelId::Claude3Haiku,
                        cache: llmbridge::api::CachePolicy::Auto,
                        context_k: 0,
                    },
                );
                let resp = bridge.handle(req)?;
                button_presses += 1;
                if resp.metadata.cache == CacheOutcome::ExactHit {
                    button_hits += 1;
                }
            }
            Event::Regenerate { conv } => {
                if let Some(id) = last_request_id[*conv] {
                    let better = bridge.regenerate(id, None)?;
                    last_request_id[*conv] = Some(better.metadata.request_id);
                }
            }
        }
    }

    // Deployment report (the §5.1 numbers, scaled down).
    let t = bridge.telemetry();
    println!("== deployment report ==");
    println!("requests handled:        {}", t.counters.get("requests"));
    println!("regenerations:           {}", t.counters.get("regenerations"));
    println!(
        "prefetched followups:    {}",
        t.counters.get("prefetched_followups")
    );
    println!(
        "button presses served from cache: {button_hits}/{button_presses}"
    );
    println!(
        "small-model LLM latency: mean {:?} p99.9 {:?}",
        t.llm_latency_small.mean(),
        t.llm_latency_small.quantile(0.999)
    );
    println!(
        "large-model LLM latency: mean {:?} p99.9 {:?}  (paper shape: large >> small)",
        t.llm_latency_large.mean(),
        t.llm_latency_large.quantile(0.999)
    );
    println!("total cost:              ${:.4}", t.costs.total_usd());

    // Leaderboard (daily ranking feature).
    let mut board: Vec<(String, f64)> = bridge
        .kv()
        .scan_prefix("points:")
        .into_iter()
        .map(|(k, v)| (k.trim_start_matches("points:").to_string(), v.as_f64().unwrap_or(0.0)))
        .collect();
    board.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\n== leaderboard ==");
    for (i, (user, pts)) in board.iter().take(5).enumerate() {
        println!("  #{} {user}: {pts} points", i + 1);
    }
    Ok(())
}
