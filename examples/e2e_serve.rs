//! END-TO-END DRIVER: brings up the full three-layer stack — AOT JAX/Pallas
//! artifacts executed via PJRT under the rust coordinator behind the REST
//! server — and drives it with a realistic multi-user WhatsApp-style
//! workload over real HTTP, reporting serving latency and throughput plus
//! the paper's deployment statistics.
//!
//! This is the "all layers compose" proof for a serving paper: batched
//! concurrent clients, per-user FIFO ordering, cache/prefetch effects, and
//! cost accounting in one run.
//!
//! ```sh
//! cargo run --release --example e2e_serve -- \
//!     [--users 8] [--turns 6] [--workers 4]
//! ```
//!
//! The default build serves from the deterministic backend; under
//! `--features pjrt` run `make artifacts` first to AOT-compile the pool.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::server::Server;
use llmbridge::util::cli::Args;
use llmbridge::util::json::Json;
use llmbridge::workload::whatsapp;

fn post(addr: std::net::SocketAddr, body: &str) -> anyhow::Result<(u16, Json)> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(
        format!(
            "POST /v1/request HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .unwrap_or("500")
        .parse()
        .unwrap_or(500);
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("{}");
    Ok((status, Json::parse(body)?))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let users = args.usize_or("users", 8);
    let turns = args.usize_or("turns", 6);
    let workers = args.usize_or("workers", 4);

    eprintln!("[e2e] loading artifacts + compiling PJRT executables...");
    let t0 = Instant::now();
    let bridge = Arc::new(Bridge::open_with(
        args.get_or("artifacts", "artifacts"),
        BridgeConfig {
            memoize: false, // measure real execution for every request
            ..Default::default()
        },
    )?);
    eprintln!("[e2e] engine up in {:?}", t0.elapsed());

    let server = Server::start(bridge.clone(), "127.0.0.1:0", workers)?;
    let addr = server.addr;
    eprintln!("[e2e] REST server on {addr}, {workers} workers");

    // Drive: one OS thread per user, each walking its conversation in
    // order over real HTTP (mix of service types like the deployment).
    let convs: Vec<_> = (0..users)
        .map(|u| whatsapp::conversation(args.u64_or("seed", 11), u, turns))
        .collect();
    let total_requests: usize = convs.iter().map(|c| c.queries.len()).sum();
    let errors = Arc::new(AtomicU64::new(0));
    let lat_us = Arc::new(std::sync::Mutex::new(Vec::<u64>::new()));

    let wall = Instant::now();
    let mut handles = Vec::new();
    for conv in convs {
        let errors = errors.clone();
        let lat_us = lat_us.clone();
        handles.push(std::thread::spawn(move || {
            for (i, q) in conv.queries.iter().enumerate() {
                let st = match i % 3 {
                    0 => r#"{"name":"model_selector"}"#,
                    1 => r#"{"name":"smart_context","k":5}"#,
                    _ => r#"{"name":"cost"}"#,
                };
                let body = Json::obj(vec![
                    ("user", Json::str(conv.user.clone())),
                    ("conversation", Json::str(conv.id.clone())),
                    ("prompt", Json::str(q.text.clone())),
                    ("service_type", Json::parse(st).unwrap()),
                ])
                .to_string();
                let t = Instant::now();
                match post(addr, &body) {
                    Ok((200, _)) => {
                        lat_us.lock().unwrap().push(t.elapsed().as_micros() as u64)
                    }
                    _ => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let elapsed = wall.elapsed();
    server.stop();

    // ---- report ---------------------------------------------------------
    let mut lats = lat_us.lock().unwrap().clone();
    lats.sort_unstable();
    let pct = |p: f64| -> Duration {
        if lats.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(lats[((lats.len() - 1) as f64 * p) as usize])
    };
    let t = bridge.telemetry();
    println!("\n== e2e serving report ==");
    println!("requests: {total_requests} over {users} users ({} errors)", errors.load(Ordering::Relaxed));
    println!("wall time: {elapsed:?}");
    println!(
        "throughput: {:.2} req/s (single-core PJRT engine)",
        total_requests as f64 / elapsed.as_secs_f64()
    );
    println!(
        "end-to-end latency: p50 {:?}  p90 {:?}  p99 {:?}  max {:?}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        pct(1.0)
    );
    println!(
        "LLM latency by class: small mean {:?} p99.9 {:?} | large mean {:?} p99.9 {:?}",
        t.llm_latency_small.mean(),
        t.llm_latency_small.quantile(0.999),
        t.llm_latency_large.mean(),
        t.llm_latency_large.quantile(0.999),
    );
    println!(
        "  (paper §5.1 shape: large-model mean/p99.9 3.8s/78s vs small 1.2s/15s — \
         direction preserved at simulator scale)"
    );
    println!("total cost: ${:.4}", t.costs.total_usd());
    println!("cache exact hits: {}", t.counters.get("cache_exact_hits"));
    println!("cascade escalations: {}", t.counters.get("cascade_escalations"));
    println!("\nmetrics json:\n{}", t.to_json().to_string());
    Ok(())
}
