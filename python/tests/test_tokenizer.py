"""Tokenizer vectors pinned against the rust implementation.

rust/tests/tokenizer_vectors.rs asserts the exact same (text -> ids)
pairs; if either side changes hashing these fail on both sides.
"""

from hypothesis import given, settings, strategies as st

from compile import model

# Shared pinned vectors (keep in sync with rust/tests/tokenizer_vectors.rs).
VECTORS = [
    ("", [1, 2]),
    ("hello world", [1, model.word_id("hello"), model.word_id("world"), 2]),
    (
        "Tell me about Sigcomm!",
        [
            1,
            model.word_id("tell"),
            model.word_id("me"),
            model.word_id("about"),
            model.word_id("sigcomm"),
            2,
        ],
    ),
]


def test_fnv1a_known_values():
    # Canonical FNV-1a 64 test vectors.
    assert model.fnv1a(b"") == 0xCBF29CE484222325
    assert model.fnv1a(b"a") == 0xAF63DC4C8601EC8C
    assert model.fnv1a(b"foobar") == 0x85944171F73967E8


def test_word_ids_in_range():
    for w in ["hello", "a", "1", "sigcomm", "x" * 50]:
        wid = model.word_id(w)
        assert model.FIRST_WORD_ID <= wid < model.VOCAB


def test_pinned_vectors():
    for text, want in VECTORS:
        ids, length = model.tokenize(text)
        assert ids[:length] == want, text
        assert all(t == model.PAD for t in ids[length:])


def test_case_and_punct_insensitive():
    a, _ = model.tokenize("Hello, WORLD!")
    b, _ = model.tokenize("hello world")
    assert a == b


@settings(max_examples=30, deadline=None)
@given(st.text(min_size=0, max_size=400))
def test_tokenize_total_function(text):
    ids, length = model.tokenize(text)
    assert len(ids) == model.SEQ_LEN
    assert 2 <= length <= model.SEQ_LEN
    assert ids[0] == model.BOS
    assert ids[length - 1] == model.EOS
    assert all(0 <= t < model.VOCAB for t in ids)


def test_truncation():
    long = " ".join(f"word{i}" for i in range(500))
    ids, length = model.tokenize(long)
    assert length == model.SEQ_LEN
