"""L2 model-pool tests: shapes, masking semantics, determinism, embedder."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


@pytest.fixture(scope="module")
def nano_theta():
    return model.init_lm_params(jax.random.PRNGKey(7), *model.VARIANTS["nano"])


@pytest.fixture(scope="module")
def embed_theta():
    return model.init_embed_params(jax.random.PRNGKey(9))


def _toks(text):
    ids, length = model.tokenize(text)
    return jnp.array(ids, jnp.int32), jnp.int32(length)


def test_lm_step_shape(nano_theta):
    toks, length = _toks("what is the capital of sudan")
    logits = model.lm_step_fn("nano")(toks, length, nano_theta)
    assert logits.shape == (model.VOCAB,)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_lm_step_deterministic(nano_theta):
    toks, length = _toks("tell me about sigcomm")
    f = model.lm_step_fn("nano")
    a = f(toks, length, nano_theta)
    b = f(toks, length, nano_theta)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lm_step_padding_inert(nano_theta):
    """Garbage in padded positions must not change the logits."""
    toks, length = _toks("hello world")
    f = model.lm_step_fn("nano")
    base = f(toks, length, nano_theta)
    toks2 = toks.at[int(length) :].set(1234)
    pert = f(toks2, length, nano_theta)
    np.testing.assert_allclose(np.asarray(base), np.asarray(pert), atol=1e-5)


def test_lm_step_context_sensitive(nano_theta):
    """Different prefixes must produce different next-token logits."""
    f = model.lm_step_fn("nano")
    t1, l1 = _toks("the weather in karachi today")
    t2, l2 = _toks("the history of the roman empire")
    a, b = f(t1, l1, nano_theta), f(t2, l2, nano_theta)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-3


@settings(max_examples=6, deadline=None)
@given(variant=st.sampled_from(["nano", "mini"]), seed=st.integers(0, 1000))
def test_lm_param_spec_roundtrip(variant, seed):
    d, layers = model.VARIANTS[variant]
    spec = model.lm_param_spec(d, layers)
    n = model.param_count(spec)
    theta = jnp.arange(n, dtype=jnp.float32)
    params = model.unflatten(theta, spec)
    # Every element is used exactly once and order is preserved.
    flat = jnp.concatenate([params[k].reshape(-1) for k, _ in spec])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(theta))


# ------------------------------------------------------------------ embedder


def test_embed_normalized(embed_theta):
    toks, length = _toks("how do i speed up my cache")
    e = model.embed(toks, length, embed_theta)
    assert e.shape == (model.EMBED_DIM,)
    np.testing.assert_allclose(float(jnp.linalg.norm(e)), 1.0, atol=1e-5)


def test_embed_semantic_structure(embed_theta):
    """Lexically-overlapping texts must embed closer than unrelated ones.

    This is the property the semantic cache (§3.5) relies on; the paper's
    example pair ('Tell me about SoCC' vs 'Talk to me about the SoCC
    conference') has high similarity while unrelated prompts score low.
    """

    def emb(text):
        toks, length = _toks(text)
        return model.embed(toks, length, embed_theta)

    a = emb("tell me about the socc conference")
    b = emb("talk to me about socc conference please")
    c = emb("recipe for chicken biryani with rice")
    sim_ab = float(jnp.dot(a, b))
    sim_ac = float(jnp.dot(a, c))
    assert sim_ab > sim_ac + 0.2, (sim_ab, sim_ac)
    assert sim_ab > 0.4


def test_embed_padding_inert(embed_theta):
    toks, length = _toks("health tips for winter")
    base = model.embed(toks, length, embed_theta)
    toks2 = toks.at[int(length) :].set(777)
    pert = model.embed(toks2, length, embed_theta)
    np.testing.assert_allclose(np.asarray(base), np.asarray(pert), atol=1e-6)


def test_embed_empty_text(embed_theta):
    toks, length = _toks("")
    e = model.embed(toks, length, embed_theta)
    assert bool(jnp.all(jnp.isfinite(e)))


def test_fused_matches_pallas(nano_theta):
    """The fused (XLA:CPU) lowering and the Pallas-kernel lowering must be
    numerically identical — the engine may serve either (§Perf)."""
    toks, length = _toks("compare the two lowering paths please")
    a = model.lm_step_fn("nano", interpret=True, fused=False)(
        toks, length, nano_theta
    )
    b = model.lm_step_fn("nano", interpret=True, fused=True)(
        toks, length, nano_theta
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4, rtol=1e-4)
