"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis shape sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, vmem_bytes
from compile.kernels.matmul import matmul
from compile.kernels.ref import attention_ref, matmul_ref

ATOL = 2e-5


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- attention


@settings(max_examples=12, deadline=None)
@given(
    heads=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 24, 40]),
    t=st.sampled_from([32, 64, 128]),
    length=st.integers(min_value=1, max_value=128),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_attention_matches_ref(heads, dh, t, length, seed):
    length = min(length, t)
    q = _rand(seed, (heads, t, dh))
    k = _rand(seed + 1, (heads, t, dh))
    v = _rand(seed + 2, (heads, t, dh))
    bias = jnp.where(jnp.arange(t) < length, 0.0, -1e30).astype(jnp.float32)
    got = attention(q, k, v, bias)
    want = attention_ref(q, k, v, bias)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 32), (32, 16), (16, 32)])
def test_attention_block_shape_invariance(block_q, block_k):
    """Output must not depend on the tiling schedule."""
    q, k, v = (_rand(i, (2, 128, 16)) for i in range(3))
    bias = jnp.where(jnp.arange(128) < 97, 0.0, -1e30).astype(jnp.float32)
    base = attention_ref(q, k, v, bias)
    got = attention(q, k, v, bias, block_q=block_q, block_k=block_k)
    np.testing.assert_allclose(got, base, atol=ATOL, rtol=1e-5)


def test_attention_causality():
    """Perturbing future positions must not change earlier outputs."""
    q, k, v = (_rand(i, (1, 64, 8)) for i in range(3))
    bias = jnp.zeros((64,), jnp.float32)
    base = attention(q, k, v, bias)
    k2 = k.at[:, 40:, :].add(3.0)
    v2 = v.at[:, 40:, :].add(3.0)
    pert = attention(q, k2, v2, bias)
    np.testing.assert_allclose(base[:, :40], pert[:, :40], atol=ATOL)
    assert not np.allclose(base[:, 40:], pert[:, 40:], atol=1e-3)


def test_attention_padding_is_inert():
    """Positions masked by kbias must not influence live outputs."""
    length = 50
    q, k, v = (_rand(i, (2, 128, 16)) for i in range(3))
    bias = jnp.where(jnp.arange(128) < length, 0.0, -1e30).astype(jnp.float32)
    base = attention(q, k, v, bias)
    k2 = k.at[:, length:, :].set(99.0)
    v2 = v.at[:, length:, :].set(-99.0)
    pert = attention(q, k2, v2, bias)
    np.testing.assert_allclose(base[:, :length], pert[:, :length], atol=ATOL)


def test_attention_softmax_rows_normalized():
    """Each live row of the implicit softmax must sum to ~1: with V = I-like
    inputs, output magnitudes stay bounded by max |v|."""
    q, k = _rand(0, (1, 32, 8)), _rand(1, (1, 32, 8))
    v = jnp.ones((1, 32, 8), jnp.float32)
    bias = jnp.zeros((32,), jnp.float32)
    out = attention(q, k, v, bias)
    np.testing.assert_allclose(out, jnp.ones_like(out), atol=1e-4)


def test_vmem_budget_within_tpu_core():
    assert vmem_bytes(32, 32, 40, 128) < 16 * 1024 * 1024


# ------------------------------------------------------------------ matmul


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([8, 32, 96, 128]),
    k=st.sampled_from([16, 64, 96, 128]),
    n=st.sampled_from([32, 64, 384, 640]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = _rand(seed, (m, k))
    b = _rand(seed + 7, (k, n))
    np.testing.assert_allclose(
        matmul(a, b), matmul_ref(a, b), atol=1e-4, rtol=1e-5
    )


def test_matmul_identity():
    a = _rand(3, (32, 32))
    np.testing.assert_allclose(matmul(a, jnp.eye(32)), a, atol=1e-6)


def test_matmul_block_invariance():
    a, b = _rand(0, (128, 96)), _rand(1, (96, 384))
    want = matmul_ref(a, b)
    for bm, bn in [(16, 32), (32, 64), (64, 96)]:
        got = matmul(a, b, block_m=bm, block_n=bn)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-5)
