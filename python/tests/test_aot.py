"""AOT pipeline tests: HLO text round-trips through the XLA client and the
compiled artifact agrees with the jit-executed python model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.fixture(scope="module")
def cpu_client():
    return xc.make_cpu_client()


def _compile_and_run(client, hlo_text, args):
    # Same round-trip the rust runtime performs: HLO text -> module ->
    # computation -> compile -> execute.  (jaxlib 0.8 only accepts MLIR
    # for compile_and_load, so we convert; the rust xla crate parses the
    # text directly via HloModuleProto::from_text_file.)
    module = xc._xla.hlo_module_from_text(hlo_text)
    comp = xc.XlaComputation(module.as_serialized_hlo_module_proto())
    mlir_str = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    devices = xc._xla.DeviceList(tuple(client.local_devices()))
    exe = client.compile_and_load(mlir_str, devices, xc.CompileOptions())
    bufs = [client.buffer_from_pyval(np.asarray(a)) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


def test_lm_hlo_matches_jit(cpu_client):
    hlo = aot.lower_lm("nano")
    d, layers = model.VARIANTS["nano"]
    theta = model.init_lm_params(jax.random.PRNGKey(3), d, layers)
    ids, length = model.tokenize("what is the tallest mountain")
    toks = jnp.array(ids, jnp.int32)
    want = model.lm_step_fn("nano")(toks, jnp.int32(length), theta)
    got = _compile_and_run(
        cpu_client,
        hlo,
        [np.array(ids, np.int32), np.int32(length), np.asarray(theta)],
    )
    np.testing.assert_allclose(got[0], np.asarray(want), atol=2e-4, rtol=1e-4)


def test_embedder_hlo_matches_jit(cpu_client):
    hlo = aot.lower_embedder()
    theta = model.init_embed_params(jax.random.PRNGKey(5))
    ids, length = model.tokenize("advice about healthy sleep habits")
    want = model.embed(jnp.array(ids, jnp.int32), jnp.int32(length), theta)
    got = _compile_and_run(
        cpu_client,
        hlo,
        [np.array(ids, np.int32), np.int32(length), np.asarray(theta)],
    )
    np.testing.assert_allclose(got[0], np.asarray(want), atol=1e-5)


def test_hlo_text_has_no_mosaic_custom_calls():
    """interpret=True must produce pure HLO executable on CPU PJRT."""
    hlo = aot.lower_lm("nano")
    assert "tpu_custom_call" not in hlo
    assert "mosaic" not in hlo.lower()


def test_weight_blob_layout(tmp_path):
    d, layers = model.VARIANTS["nano"]
    theta = model.init_lm_params(jax.random.PRNGKey(11), d, layers)
    path = tmp_path / "w.bin"
    n = aot.dump_weights(str(path), theta)
    assert n == model.param_count(model.lm_param_spec(d, layers))
    back = np.fromfile(path, dtype="<f4")
    np.testing.assert_array_equal(back, np.asarray(theta))
