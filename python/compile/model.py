"""L2: the simulated model pool — word-level transformer LMs in JAX.

These are the "LLMs" behind LLMBridge's model adapter.  Three width/depth
variants stand in for the nano / mini / large capability classes of the
paper's pool (Phi-3/Haiku-class, GPT-3.5/4o-mini-class, GPT-4/4o-class).
The forward pass calls the L1 Pallas kernels (attention.py, matmul.py) so
the whole stack lowers into one HLO module per variant.

Artifact signatures (all f32 / i32, fixed shapes, AOT-lowered by aot.py):

    lm_step(tokens i32[T], length i32[], theta f32[P]) -> logits f32[V]
        Next-token logits at position length-1.  Rust drives the decode
        loop, re-invoking lm_step with the growing token buffer.

    embed(tokens i32[T], length i32[], theta f32[PE]) -> f32[EMBED_DIM]
        L2-normalized text embedding: random-projected word unigram +
        bigram counts (a Johnson-Lindenstrauss sketch of lexical content;
        stands in for the paper's OpenAI text-embedding-3-large).

The word-hash tokenizer (FNV-1a over lowercased words, ids 16..V-1,
PAD=0 BOS=1 EOS=2 UNK=3) is mirrored bit-for-bit by rust/src/runtime/
tokenizer.rs; python/tests/test_tokenizer.py pins shared vectors.
"""

import functools
import json

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.matmul import matmul
from .kernels.ref import attention_ref, matmul_ref

VOCAB = 4096
SEQ_LEN = 128
NUM_HEADS = 4
EMBED_DIM = 64
BIGRAM_BUCKETS = 4096
NEG_INF = -1e30

# Pool variants: name -> (width, layers).  Width must divide by NUM_HEADS.
VARIANTS = {
    "nano": (64, 2),
    "mini": (96, 3),
    "large": (128, 4),
}


# --------------------------------------------------------------------------
# Tokenizer (mirrored in rust/src/runtime/tokenizer.rs)
# --------------------------------------------------------------------------

PAD, BOS, EOS, UNK = 0, 1, 2, 3
FIRST_WORD_ID = 16

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def fnv1a(data: bytes) -> int:
    h = _FNV_OFFSET
    for b in data:
        h = ((h ^ b) * _FNV_PRIME) & _MASK64
    return h


def words(text: str):
    out, cur = [], []
    for ch in text.lower():
        if ch.isascii() and ch.isalnum():
            cur.append(ch)
        else:
            if cur:
                out.append("".join(cur))
                cur = []
    if cur:
        out.append("".join(cur))
    return out


def word_id(word: str) -> int:
    return FIRST_WORD_ID + fnv1a(word.encode()) % (VOCAB - FIRST_WORD_ID)


def tokenize(text: str, seq_len: int = SEQ_LEN):
    """-> (tokens list[int] length seq_len, live length int)."""
    ids = [BOS] + [word_id(w) for w in words(text)][: seq_len - 2] + [EOS]
    length = len(ids)
    ids = ids + [PAD] * (seq_len - length)
    return ids, length


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------


def lm_param_spec(d: int, layers: int):
    """Ordered (name, shape) list; theta is this, flattened & concatenated."""
    spec = [("tok_emb", (VOCAB, d)), ("pos_emb", (SEQ_LEN, d))]
    for i in range(layers):
        spec += [
            (f"l{i}.ln1_g", (d,)),
            (f"l{i}.ln1_b", (d,)),
            (f"l{i}.w_qkv", (d, 3 * d)),
            (f"l{i}.b_qkv", (3 * d,)),
            (f"l{i}.w_o", (d, d)),
            (f"l{i}.b_o", (d,)),
            (f"l{i}.ln2_g", (d,)),
            (f"l{i}.ln2_b", (d,)),
            (f"l{i}.w_mlp1", (d, 4 * d)),
            (f"l{i}.b_mlp1", (4 * d,)),
            (f"l{i}.w_mlp2", (4 * d, d)),
            (f"l{i}.b_mlp2", (d,)),
        ]
    spec += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return spec


def embed_param_spec():
    return [
        ("r_uni", (VOCAB, EMBED_DIM)),
        ("r_bi", (BIGRAM_BUCKETS, EMBED_DIM)),
    ]


def param_count(spec) -> int:
    n = 0
    for _, shape in spec:
        size = 1
        for s in shape:
            size *= s
        n += size
    return n


def unflatten(theta, spec):
    """Slice the flat theta back into named arrays (static offsets)."""
    params, off = {}, 0
    for name, shape in spec:
        size = 1
        for s in shape:
            size *= s
        params[name] = theta[off : off + size].reshape(shape)
        off += size
    return params


def init_lm_params(key, d: int, layers: int):
    spec = lm_param_spec(d, layers)
    chunks = []
    for name, shape in spec:
        key, sub = jax.random.split(key)
        if name.endswith("_g"):
            arr = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b"):
            arr = jnp.zeros(shape, jnp.float32)
        elif name in ("tok_emb", "pos_emb"):
            arr = 0.06 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0]
            arr = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(
                float(fan_in)
            )
        chunks.append(arr.reshape(-1))
    return jnp.concatenate(chunks)


def init_embed_params(key):
    spec = embed_param_spec()
    chunks = []
    for _, shape in spec:
        key, sub = jax.random.split(key)
        chunks.append(
            (jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(shape[1]))
            .reshape(-1)
        )
    return jnp.concatenate(chunks)


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def lm_step(
    tokens, length, theta, *, d: int, layers: int, interpret=True, fused=False
):
    """Next-token logits at position length-1.  tokens: i32[T].

    Two lowering paths, bit-compatible to f32 tolerance (pinned by
    python/tests/test_model.py::test_fused_matches_pallas):

    * ``fused=False`` — the L1 Pallas kernels (interpret=True for CPU).
      This is the TPU-shaped path: on real hardware the kernels lower to
      Mosaic and own the VMEM/MXU schedule.
    * ``fused=True``  — plain jnp ops that XLA:CPU fuses aggressively.
      On the CPU PJRT plugin interpret-mode Pallas costs ~2.3x (the grid
      loop defeats fusion), so the serving artifacts default to this path
      (EXPERIMENTS.md §Perf).
    """
    p = unflatten(theta, lm_param_spec(d, layers))
    t = SEQ_LEN
    dh = d // NUM_HEADS
    pos = jnp.arange(t)
    kbias = jnp.where(pos < length, 0.0, NEG_INF).astype(jnp.float32)

    def mm(a, b):
        if fused:
            return matmul_ref(a, b)
        return matmul(a, b, interpret=interpret)

    def attn(q, k, v, bias):
        if fused:
            return attention_ref(q, k, v, bias)
        return attention(q, k, v, bias, interpret=interpret)

    x = p["tok_emb"][tokens] + p["pos_emb"]
    for i in range(layers):
        h = layer_norm(x, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
        qkv = mm(h, p[f"l{i}.w_qkv"]) + p[f"l{i}.b_qkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(z):  # (T, d) -> (H, T, dh)
            return z.reshape(t, NUM_HEADS, dh).transpose(1, 0, 2)

        o = attn(heads(q), heads(k), heads(v), kbias)
        o = o.transpose(1, 0, 2).reshape(t, d)
        x = x + mm(o, p[f"l{i}.w_o"]) + p[f"l{i}.b_o"]
        h2 = layer_norm(x, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
        m = mm(h2, p[f"l{i}.w_mlp1"]) + p[f"l{i}.b_mlp1"]
        m = jax.nn.gelu(m)
        x = x + mm(m, p[f"l{i}.w_mlp2"]) + p[f"l{i}.b_mlp2"]

    x = layer_norm(x, p["lnf_g"], p["lnf_b"])
    x_last = jax.lax.dynamic_slice(x, (length - 1, 0), (1, d))  # (1, d)
    logits = (x_last @ p["tok_emb"].T)[0]                        # tied head
    return logits


def embed(tokens, length, theta):
    """L2-normalized lexical sketch embedding.  tokens: i32[T]."""
    p = unflatten(theta, embed_param_spec())
    pos = jnp.arange(SEQ_LEN)
    valid = (tokens >= FIRST_WORD_ID) & (pos < length)
    uni = jnp.zeros((VOCAB,), jnp.float32).at[tokens].add(
        valid.astype(jnp.float32)
    )
    bg = (tokens[:-1] * 31 + tokens[1:]) % BIGRAM_BUCKETS
    vbg = (valid[:-1] & valid[1:]).astype(jnp.float32)
    big = jnp.zeros((BIGRAM_BUCKETS,), jnp.float32).at[bg].add(vbg)
    # Damp raw counts so repeated words don't dominate (soft tf).
    uni = jnp.log1p(uni)
    big = jnp.log1p(big)
    e = uni @ p["r_uni"] + big @ p["r_bi"]
    return e / jnp.maximum(jnp.linalg.norm(e), 1e-9)


def lm_step_fn(variant: str, interpret: bool = True, fused: bool = False):
    d, layers = VARIANTS[variant]
    return functools.partial(
        lm_step, d=d, layers=layers, interpret=interpret, fused=fused
    )


def manifest_entry(variant: str) -> dict:
    d, layers = VARIANTS[variant]
    return {
        "variant": variant,
        "d_model": d,
        "layers": layers,
        "heads": NUM_HEADS,
        "seq_len": SEQ_LEN,
        "vocab": VOCAB,
        "params": param_count(lm_param_spec(d, layers)),
        "hlo": f"lm_{variant}.hlo.txt",
        "hlo_fused": f"lm_{variant}_fused.hlo.txt",
        "weights": f"lm_{variant}.bin",
    }


if __name__ == "__main__":
    print(json.dumps([manifest_entry(v) for v in VARIANTS], indent=2))
