"""L1 perf report: VMEM footprint + MXU utilization *estimates* per block
shape for the Pallas attention kernel.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the L1
optimization target is structural: keep every (head, q-block) instance
comfortably inside a TPU core's ~16 MiB VMEM while maximizing MXU occupancy
(tiles as close to the 128x128 systolic array as the model width allows).

Usage: cd python && python -m compile.kernels.vmem_report
"""

from . import attention
from .. import model

VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128


def mxu_utilization(block_q: int, block_k: int, dh: int) -> float:
    """Fraction of the 128x128 MXU a QK^T tile occupies (both operand dims
    clamped at the systolic array edge)."""
    return min(block_q, MXU_DIM) * min(block_k, MXU_DIM) / (MXU_DIM * MXU_DIM)


def report(seq_len: int | None = None):
    seq_len = seq_len or model.SEQ_LEN
    rows = []
    for variant, (d, layers) in model.VARIANTS.items():
        dh = d // model.NUM_HEADS
        for bq in (16, 32, 64):
            for bk in (16, 32, 64):
                if seq_len % bq or seq_len % bk:
                    continue
                vmem = attention.vmem_bytes(bq, bk, dh, seq_len)
                rows.append(
                    {
                        "variant": variant,
                        "layers": layers,
                        "dh": dh,
                        "block_q": bq,
                        "block_k": bk,
                        "vmem_bytes": vmem,
                        "vmem_frac": vmem / VMEM_BYTES,
                        "mxu_util": mxu_utilization(bq, bk, dh),
                        "grid": (model.NUM_HEADS, seq_len // bq),
                    }
                )
    return rows


def main():
    print(
        f"{'variant':<8} {'dh':>3} {'bq':>3} {'bk':>3} {'grid':>8} "
        f"{'vmem':>10} {'%vmem':>7} {'mxu_util':>9}"
    )
    best = {}
    for r in report():
        print(
            f"{r['variant']:<8} {r['dh']:>3} {r['block_q']:>3} {r['block_k']:>3} "
            f"{str(r['grid']):>8} {r['vmem_bytes']:>10,} "
            f"{100*r['vmem_frac']:>6.2f}% {r['mxu_util']:>9.3f}"
        )
        key = r["variant"]
        # Best = max MXU utilization subject to <25% VMEM (leave room for
        # double-buffering and the MLP tiles).
        if r["vmem_frac"] < 0.25 and (
            key not in best or r["mxu_util"] > best[key]["mxu_util"]
        ):
            best[key] = r
    print("\nchosen block shapes (max MXU util under 25% VMEM):")
    for k, r in best.items():
        print(
            f"  {k}: block_q={r['block_q']} block_k={r['block_k']} "
            f"(vmem {100*r['vmem_frac']:.2f}%, mxu {r['mxu_util']:.3f})"
        )


if __name__ == "__main__":
    main()
