"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must agree with its oracle to float32
tolerance across the shape/dtype sweep in python/tests/.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, kbias):
    """Naive causal multi-head attention. q,k,v: (H, T, Dh); kbias: (T,)."""
    h, t, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("hqd,hkd->hqk", q, k) * scale          # (H, T, T)
    pos = jnp.arange(t)
    causal = pos[None, :] <= pos[:, None]                  # (T, T) q>=k
    s = jnp.where(causal[None, :, :], s + kbias[None, None, :], NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def matmul_ref(a, b):
    return a @ b
