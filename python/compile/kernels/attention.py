"""L1: fused multi-head causal attention as a Pallas kernel.

TPU adaptation of the flash-attention insight (see DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks staging K/V tiles through
shared memory, the BlockSpec grid streams per-(head, q-block) tiles
HBM->VMEM, the Q tile stays VMEM-resident, QK^T hits the MXU via jnp.dot
with f32 accumulation, and the online-softmax running statistics (m, l)
live in registers/VMEM scratch rather than shared memory.

Grid: (num_heads, T // BLOCK_Q).  Each program instance owns one q-block of
one head and loops over k-blocks with the numerically-stable streaming
softmax.  `interpret=True` is mandatory on CPU: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.

VMEM budget per instance (f32):
    q tile     BLOCK_Q x Dh
    k,v block  BLOCK_K x Dh  (x2)
    scores     BLOCK_Q x BLOCK_K
With BLOCK_Q = BLOCK_K = 32 and Dh <= 64 this is < 64 KiB, far inside the
~16 MiB VMEM of a TPU core; the roomy margin lets real-TPU builds raise
BLOCK_K for better MXU occupancy (see vmem_report.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale, block_k):
    """One (head, q-block) program instance of streaming causal attention."""
    qi = pl.program_id(1)
    q = q_ref[0]                      # (BQ, Dh)
    k = k_ref[0]                      # (T, Dh) — full key range for this head
    v = v_ref[0]                      # (T, Dh)
    bias = bias_ref[...]              # (T,)  0 for valid keys, -inf for padding

    block_q, dh = q.shape
    seq_len = k.shape[0]
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_blk = jax.lax.dynamic_slice(k, (kb * block_k, 0), (block_k, dh))
        v_blk = jax.lax.dynamic_slice(v, (kb * block_k, 0), (block_k, dh))
        b_blk = jax.lax.dynamic_slice(bias, (kb * block_k,), (block_k,))
        # MXU: (BQ, Dh) @ (Dh, BK) with f32 accumulation.
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32) * scale
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1
        )
        causal = k_pos <= q_pos       # (BQ, BK)
        s = jnp.where(causal, s + b_blk[None, :], NEG_INF)
        # Online softmax update.
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    nkb = seq_len // block_k
    acc, _, l = jax.lax.fori_loop(0, nkb, body, (acc0, m0, l0))
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def attention(
    q,
    k,
    v,
    kbias,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """Causal multi-head attention.

    Args:
      q, k, v: (H, T, Dh) f32.
      kbias: (T,) f32 additive key bias; 0 for valid positions and a large
        negative value for padding beyond the live sequence length.
    Returns:
      (H, T, Dh) f32 attention output.
    """
    h, t, dh = q.shape
    assert t % block_q == 0 and t % block_k == 0, (t, block_q, block_k)
    scale = 1.0 / (dh ** 0.5)
    grid = (h, t // block_q)
    kernel = functools.partial(_attn_kernel, scale=scale, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((1, t, dh), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((t,), lambda hh, qq: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, dh), jnp.float32),
        interpret=interpret,
    )(q, k, v, kbias)


def vmem_bytes(block_q: int, block_k: int, dh: int, t: int) -> int:
    """Estimated per-instance VMEM footprint in bytes (f32)."""
    tiles = (
        block_q * dh        # q tile
        + 2 * t * dh        # k, v (streamed range; worst case resident)
        + block_q * block_k  # score tile
        + 2 * block_q * dh  # acc + output
    )
    return 4 * tiles
