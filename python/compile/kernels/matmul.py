"""L1: blocked matmul Pallas kernel used by the transformer MLP layers.

Grid tiles the (M, N) output; the K dimension is kept VMEM-resident per
instance (K = model width <= 192 here, so an (BM, K) A-tile plus a (K, BN)
B-tile is a few tens of KiB — trivially inside VMEM).  On real TPU the
jnp.dot maps onto the 128x128 MXU systolic array; BM/BN are chosen as
multiples of 8x128 lanes where shapes allow, and we fall back to exact
divisors for the small widths used in this repo.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim: int, want: int) -> int:
    if dim % want == 0:
        return want
    for cand in (64, 32, 16, 8, 4, 2, 1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def matmul(a, b, *, block_m: int = 32, block_n: int = 64, interpret: bool = True):
    """C = A @ B with a (BM, BN)-tiled Pallas grid.

    a: (M, K) f32, b: (K, N) f32 -> (M, N) f32.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
