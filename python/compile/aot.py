"""AOT pipeline: lower the L2 model pool to HLO-text artifacts for rust.

Emits into artifacts/ (default ../artifacts relative to python/):
    lm_nano.hlo.txt / lm_mini.hlo.txt / lm_large.hlo.txt
    embedder.hlo.txt
    lm_*.bin, embedder.bin        flat little-endian f32 weight blobs
    manifest.json                 registry consumed by rust/src/runtime

Interchange is HLO *text*, never a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Pallas kernels are lowered with interpret=True so the resulting HLO is
plain ops executable on the CPU PJRT plugin (real-TPU lowering would emit
Mosaic custom-calls).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

SEED = 0x11A3B71D6E


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_lm(variant: str, fused: bool = False) -> str:
    d, layers = model.VARIANTS[variant]
    n_params = model.param_count(model.lm_param_spec(d, layers))
    fn = model.lm_step_fn(variant, interpret=True, fused=fused)

    def wrapped(tokens, length, theta):
        return (fn(tokens, length, theta),)

    lowered = jax.jit(wrapped).lower(
        jax.ShapeDtypeStruct((model.SEQ_LEN,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((n_params,), jnp.float32),
    )
    return to_hlo_text(lowered)


def lower_embedder() -> str:
    n_params = model.param_count(model.embed_param_spec())

    def wrapped(tokens, length, theta):
        return (model.embed(tokens, length, theta),)

    lowered = jax.jit(wrapped).lower(
        jax.ShapeDtypeStruct((model.SEQ_LEN,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((n_params,), jnp.float32),
    )
    return to_hlo_text(lowered)


def dump_weights(path: str, theta) -> int:
    arr = np.asarray(theta, dtype="<f4")
    arr.tofile(path)
    return arr.size


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    key = jax.random.PRNGKey(SEED % (2**32))
    manifest = {
        "tokenizer": {
            "kind": "fnv1a-word",
            "vocab": model.VOCAB,
            "seq_len": model.SEQ_LEN,
            "pad": model.PAD,
            "bos": model.BOS,
            "eos": model.EOS,
            "first_word_id": model.FIRST_WORD_ID,
        },
        "models": [],
        "embedder": None,
    }

    for variant in model.VARIANTS:
        entry = model.manifest_entry(variant)
        hlo = lower_lm(variant)
        with open(os.path.join(args.out_dir, entry["hlo"]), "w") as f:
            f.write(hlo)
        # Fused (XLA:CPU-friendly) twin of the same computation; the rust
        # engine serves this one on CPU (EXPERIMENTS.md §Perf).
        hlo_fused = lower_lm(variant, fused=True)
        with open(os.path.join(args.out_dir, entry["hlo_fused"]), "w") as f:
            f.write(hlo_fused)
        key, sub = jax.random.split(key)
        d, layers = model.VARIANTS[variant]
        theta = model.init_lm_params(sub, d, layers)
        n = dump_weights(os.path.join(args.out_dir, entry["weights"]), theta)
        assert n == entry["params"], (variant, n, entry["params"])
        manifest["models"].append(entry)
        print(f"lowered lm_{variant}: d={d} L={layers} params={n}")

    hlo = lower_embedder()
    with open(os.path.join(args.out_dir, "embedder.hlo.txt"), "w") as f:
        f.write(hlo)
    key, sub = jax.random.split(key)
    theta_e = model.init_embed_params(sub)
    n = dump_weights(os.path.join(args.out_dir, "embedder.bin"), theta_e)
    manifest["embedder"] = {
        "dim": model.EMBED_DIM,
        "bigram_buckets": model.BIGRAM_BUCKETS,
        "seq_len": model.SEQ_LEN,
        "params": n,
        "hlo": "embedder.hlo.txt",
        "weights": "embedder.bin",
    }
    print(f"lowered embedder: dim={model.EMBED_DIM} params={n}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
