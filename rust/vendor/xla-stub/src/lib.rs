//! Offline stand-in for the `xla-rs` PJRT bindings.
//!
//! The llmbridge `pjrt` feature compiles `runtime::engine::Engine` against
//! this crate so the engine path always *type-checks* without the XLA
//! extension library installed. It is a signature-compatible shell, not an
//! implementation: [`PjRtClient::cpu`] — the first call `Engine::load`
//! makes — returns an error, so a `pjrt` build that was not relinked
//! against the real bindings fails fast at engine spawn with a message
//! pointing at the swap instructions (README.md §PJRT backend), never
//! deep inside an execute call.
//!
//! Every method below mirrors the exact shape `runtime::engine` uses:
//! keep the two in sync when the engine grows a new PJRT call.

use std::fmt;

const STUB: &str = "xla stub: the `pjrt` feature was compiled against the vendored \
     API stub (rust/vendor/xla-stub); link the real xla-rs bindings to execute \
     artifacts — see README.md §PJRT backend";

/// The stub's only error: "this is not the real library".
#[derive(Debug)]
pub struct Error(&'static str);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.0)
    }
}

impl std::error::Error for Error {}

pub struct PjRtClient;
pub struct PjRtLoadedExecutable;
pub struct PjRtBuffer;
pub struct Literal;
pub struct HloModuleProto;
pub struct XlaComputation;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(STUB))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(STUB))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error(STUB))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(STUB))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(STUB))
    }
}

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error(STUB))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error(STUB))
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error(STUB))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_pointer_to_docs() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("README.md"));
    }
}
