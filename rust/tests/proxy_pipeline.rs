//! End-to-end proxy pipeline tests: every service type, transparency
//! metadata, regeneration, history semantics, caching, and quotas.

mod common;

use llmbridge::api::{CacheOutcome, CachePolicy, Request, ServiceType};
use llmbridge::models::pricing::ModelId;
use llmbridge::models::quality::QueryTraits;

fn traits(id: &str, difficulty: f64, factual: bool, requires_context: bool) -> QueryTraits {
    QueryTraits {
        id: id.into(),
        difficulty,
        factual,
        requires_context,
    }
}

#[test]
fn fixed_service_type_uses_requested_model() {
    let b = common::bridge();
    let req = Request::new("t-fixed", "c1", "tell me about mangoes").service_type(
        ServiceType::Fixed {
            model: ModelId::Llama38b,
            cache: CachePolicy::Skip,
            context_k: 0,
        },
    );
    let resp = b.handle(req).unwrap();
    assert_eq!(resp.metadata.models_used, vec![("llama-3-8b".to_string(), "answer".to_string())]);
    assert_eq!(resp.metadata.cache, CacheOutcome::Skipped);
    assert!(resp.metadata.cost_usd > 0.0);
    assert!(!resp.text.is_empty());
}

#[test]
fn cost_and_quality_pick_price_extremes() {
    let b = common::bridge();
    let cheap = b
        .handle(Request::new("t-cost", "c1", "short answer please").service_type(ServiceType::Cost))
        .unwrap();
    let dear = b
        .handle(
            Request::new("t-qual", "c1", "short answer please two").service_type(ServiceType::Quality),
        )
        .unwrap();
    let cheap_model = &cheap.metadata.models_used[0].0;
    let dear_model = &dear.metadata.models_used[0].0;
    let price = |m: &str| ModelId::parse(m).unwrap().spec().usd_per_mtok_in;
    assert!(price(dear_model) > price(cheap_model) * 10.0);
}

#[test]
fn model_selector_exposes_verifier_score() {
    let b = common::bridge();
    let req = Request::new("t-ms", "c1", "how common is diabetes these days")
        .service_type(ServiceType::default())
        .with_traits(traits("ms-q1", 0.5, false, false));
    let resp = b.handle(req).unwrap();
    let roles: Vec<&str> = resp.metadata.models_used.iter().map(|(_, r)| r.as_str()).collect();
    assert!(roles.contains(&"m1"));
    assert!(roles.contains(&"verifier"));
    let v = resp.metadata.verifier_score.expect("verifier score surfaced");
    assert!((0.0..=10.0).contains(&v));
    // Escalation implies m2 in the role list and higher cost.
    if roles.contains(&"m2") {
        assert!(resp.metadata.cost_usd > 0.0);
    }
}

#[test]
fn hard_queries_escalate_more_than_easy() {
    let b = common::bridge();
    let mut esc_hard = 0;
    let mut esc_easy = 0;
    for i in 0..30 {
        let hard = Request::new("t-esc", &format!("ch{i}"), &format!("difficult question {i}"))
            .service_type(ServiceType::default())
            .with_traits(traits(&format!("hard-{i}"), 0.9, false, false));
        let easy = Request::new("t-esc", &format!("ce{i}"), &format!("easy question {i}"))
            .service_type(ServiceType::default())
            .with_traits(traits(&format!("easy-{i}"), 0.1, false, false));
        if b.handle(hard).unwrap().metadata.models_used.iter().any(|(_, r)| r == "m2") {
            esc_hard += 1;
        }
        if b.handle(easy).unwrap().metadata.models_used.iter().any(|(_, r)| r == "m2") {
            esc_easy += 1;
        }
    }
    assert!(
        esc_hard > esc_easy + 5,
        "hard {esc_hard} vs easy {esc_easy}: verifier must route difficulty"
    );
}

#[test]
fn history_grows_and_context_counts() {
    let b = common::bridge();
    b.clear_history("t-hist", "c1");
    for i in 0..3 {
        let req = Request::new("t-hist", "c1", &format!("question number {i}")).service_type(
            ServiceType::Fixed {
                model: ModelId::Gpt4oMini,
                cache: CachePolicy::Skip,
                context_k: 5,
            },
        );
        let resp = b.handle(req).unwrap();
        assert_eq!(resp.metadata.context_messages, i, "turn {i}");
    }
    assert_eq!(b.history("t-hist", "c1").len(), 3);
}

#[test]
fn update_context_false_reads_but_does_not_write() {
    let b = common::bridge();
    b.clear_history("t-ro", "c1");
    b.handle(Request::new("t-ro", "c1", "first question").service_type(ServiceType::Cost))
        .unwrap();
    let ro = Request::new("t-ro", "c1", "what mood is the user in")
        .service_type(ServiceType::Fixed {
            model: ModelId::Gpt4oMini,
            cache: CachePolicy::Skip,
            context_k: 5,
        })
        .no_context_update();
    let resp = b.handle(ro).unwrap();
    assert_eq!(resp.metadata.context_messages, 1);
    assert_eq!(b.history("t-ro", "c1").len(), 1, "read-only prompt must not append");
}

#[test]
fn smart_context_standalone_drops_context() {
    let b = common::bridge();
    b.clear_history("t-sc", "c1");
    // Seed history.
    b.handle(Request::new("t-sc", "c1", "tell me about cricket").service_type(ServiceType::Cost))
        .unwrap();
    // A standalone query with traits the classifier reads.
    let req = Request::new("t-sc", "c1", "what is the tallest mountain in africa")
        .service_type(ServiceType::SmartContext {
            k: 5,
            model: ModelId::Claude3Haiku,
        })
        .with_traits(traits("sc-standalone-1", 0.3, false, false));
    let resp = b.handle(req).unwrap();
    // Context-LLM charged: two short calls by the §3.4 double-check.
    let ctx_calls = resp
        .metadata
        .models_used
        .iter()
        .filter(|(_, r)| r == "context-llm")
        .count();
    assert_eq!(ctx_calls, 2);
}

#[test]
fn smart_context_followup_keeps_context() {
    let b = common::bridge();
    b.clear_history("t-sc2", "c1");
    b.handle(Request::new("t-sc2", "c1", "tell me about malaria").service_type(ServiceType::Cost))
        .unwrap();
    let req = Request::new("t-sc2", "c1", "tell me more about that")
        .service_type(ServiceType::SmartContext {
            k: 5,
            model: ModelId::Claude3Haiku,
        })
        .with_traits(traits("sc-follow-1", 0.3, false, true));
    let resp = b.handle(req).unwrap();
    assert!(
        resp.metadata.context_messages >= 1,
        "dependent query should keep context (classifier is right w.h.p.)"
    );
}

#[test]
fn exact_cache_hit_is_free() {
    let b = common::bridge();
    b.cache().put_exact("more about henna art", "henna art is beautiful");
    let resp = b
        .handle(Request::new("t-exact", "c1", "More about HENNA art?").service_type(ServiceType::Cost))
        .unwrap();
    assert_eq!(resp.metadata.cache, CacheOutcome::ExactHit);
    assert_eq!(resp.metadata.cost_usd, 0.0);
    assert_eq!(resp.text, "henna art is beautiful");
    assert!(resp.metadata.models_used.is_empty());
}

#[test]
fn smart_cache_grounds_factual_queries() {
    let b = common::bridge();
    // Populate with the malaria article via delegated PUT.
    let article = llmbridge::workload::corpus::article("health", "malaria");
    let (ids, calls) = b
        .cache()
        .put_delegated(b.generator(), ModelId::Phi3Mini, &article.title, &article.text)
        .unwrap();
    assert!(!ids.is_empty());
    assert!(!calls.is_empty());
    let req = Request::new("t-scache", "c1", "how many people are affected by malaria")
        .service_type(ServiceType::SmartCache {
            model: ModelId::Phi3Mini,
        })
        .with_traits(traits("scache-q1", 0.4, true, false));
    let resp = b.handle(req).unwrap();
    match resp.metadata.cache {
        CacheOutcome::SemanticHit { score } => {
            assert!(score > 0.2, "score={score}");
            assert!(resp.metadata.grounded);
            assert!(resp.text.contains("malaria"), "grounded text carries facts");
        }
        ref other => {
            // The small model can (rarely, seeded) decline the hit; then it
            // must have answered directly, ungrounded.
            assert_eq!(*other, CacheOutcome::Miss);
            assert!(!resp.metadata.grounded);
        }
    }
}

#[test]
fn regenerate_escalates_and_replaces_history() {
    let b = common::bridge();
    b.clear_history("t-regen", "c1");
    let req = Request::new("t-regen", "c1", "give me advice on nutrition")
        .service_type(ServiceType::default())
        .with_traits(traits("regen-q1", 0.5, false, false));
    let first = b.handle(req).unwrap();
    let second = b.regenerate(first.metadata.request_id, None).unwrap();
    assert_eq!(second.metadata.regen_count, 1);
    assert_eq!(second.metadata.service_type, "fixed");
    // §5.1: history keeps one turn whose response is the regenerated one.
    let hist = b.history("t-regen", "c1");
    assert_eq!(hist.len(), 1);
    assert_eq!(hist[0].response, second.text);
    // Regeneration goes straight to the big model.
    assert!(second
        .metadata
        .models_used
        .iter()
        .any(|(m, _)| m == "gpt-4o" || m == "gpt-4"));
}

#[test]
fn regenerate_with_explicit_service_type() {
    let b = common::bridge();
    let req = Request::new("t-regen2", "c1", "what should i know about chai")
        .service_type(ServiceType::Cost);
    let first = b.handle(req).unwrap();
    let second = b
        .regenerate(first.metadata.request_id, Some(ServiceType::Quality))
        .unwrap();
    assert_eq!(second.metadata.service_type, "quality");
    assert!(second.metadata.cost_usd > first.metadata.cost_usd);
}

#[test]
fn unknown_regenerate_id_errors() {
    let b = common::bridge();
    assert!(b.regenerate(0xDEAD_BEEF, None).is_err());
}

#[test]
fn usage_based_denies_off_list_models_and_enforces_quota() {
    let mut cfg = llmbridge::coordinator::BridgeConfig::default();
    cfg.quota.max_requests = 3;
    let b = common::private_bridge(cfg);
    let st = ServiceType::UsageBased {
        allowed: vec![ModelId::Gpt4oMini, ModelId::Phi3Mini],
        fallback: ModelId::Gpt4oMini,
    };
    // Request gpt-4 (not allowed) -> falls back.
    let mut req = Request::new("student-1", "c1", "classify this message").service_type(st.clone());
    req.params.insert("model".into(), "gpt-4".into());
    let resp = b.handle(req).unwrap();
    assert_eq!(resp.metadata.models_used[0].0, "gpt-4o-mini");
    assert_eq!(b.telemetry().counters.get("model_denied"), 1);
    // Quota: 3 requests max.
    for i in 0..2 {
        b.handle(
            Request::new("student-1", "c1", &format!("another question {i}"))
                .service_type(st.clone()),
        )
        .unwrap();
    }
    let over = b.handle(
        Request::new("student-1", "c1", "one too many").service_type(st.clone()),
    );
    assert!(over.is_err(), "4th request must hit the quota");
    assert_eq!(b.telemetry().counters.get("quota_rejections"), 1);
    // Other students unaffected.
    assert!(b
        .handle(Request::new("student-2", "c1", "fresh user").service_type(st))
        .is_ok());
}

#[test]
fn latency_first_uses_fast_model() {
    let b = common::bridge();
    let resp = b
        .handle(
            Request::new("t-lat", "c1", "quick question about squash")
                .service_type(ServiceType::LatencyFirst),
        )
        .unwrap();
    assert_eq!(resp.metadata.models_used[0].0, "claude-3-haiku");
}

#[test]
fn telemetry_accumulates() {
    let b = common::bridge();
    let before = b.telemetry().counters.get("requests");
    b.handle(Request::new("t-tel", "c1", "telemetry probe").service_type(ServiceType::Cost))
        .unwrap();
    assert_eq!(b.telemetry().counters.get("requests"), before + 1);
    assert!(b.telemetry().costs.total_usd() > 0.0);
}

#[test]
fn metadata_json_is_parseable() {
    let b = common::bridge();
    let resp = b
        .handle(Request::new("t-json", "c1", "serialize me").service_type(ServiceType::Cost))
        .unwrap();
    let j = resp.to_json().to_string();
    let back = llmbridge::util::json::Json::parse(&j).unwrap();
    assert!(back.req("metadata").unwrap().get("cost_usd").is_some());
}

// ---------------------------------------------------------------------
// Cache GET-path semantics with real embeddings (§3.5 low-level API).
// ---------------------------------------------------------------------

#[test]
fn cache_get_type_filters_and_thresholds() {
    use llmbridge::cache::{CachedType, GetFilter};
    let b = common::bridge();
    let g = b.generator();
    // The §3.5 B-tree example: response-keyed entries match future prompts
    // that the prompt key would miss.
    let cache = llmbridge::cache::SemanticCache::new(b.engine().embed_dim());
    cache
        .put(
            g,
            "use data structures like b trees and tries",
            "how do i speed up my cache",
            false,
            &[
                (CachedType::Prompt, "how do i speed up my cache".into()),
                (
                    CachedType::Response,
                    "use data structures like b trees and tries".into(),
                ),
            ],
        )
        .unwrap();
    // Prompt-similar query hits via the Prompt key.
    let hits = cache
        .get(
            g,
            "how can i speed up my cache please",
            &GetFilter {
                types: Some(vec![CachedType::Prompt]),
                min_score: 0.3,
                k: 4,
            },
        )
        .unwrap();
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].matched_type, CachedType::Prompt);

    // Response-similar query misses under a Prompt-only filter...
    let hits = cache
        .get(
            g,
            "give me examples of popular data structures like tries",
            &GetFilter {
                types: Some(vec![CachedType::Prompt]),
                min_score: 0.35,
                k: 4,
            },
        )
        .unwrap();
    assert!(hits.is_empty(), "{hits:?}");
    // ...but hits when Response keys are allowed (the paper's point; our
    // JL-sketch embedder scores the pair lower than OpenAI's 0.64, so the
    // threshold is calibrated to our similarity distribution).
    let hits = cache
        .get(
            g,
            "give me examples of popular data structures like tries",
            &GetFilter {
                types: Some(vec![CachedType::Response]),
                min_score: 0.2,
                k: 4,
            },
        )
        .unwrap();
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].matched_type, CachedType::Response);

    // An unsatisfiable threshold filters everything (the stored prompt is
    // *identical* to this query, so cosine = 1.0 exactly; only > 1 fails).
    let hits = cache
        .get(
            g,
            "how do i speed up my cache",
            &GetFilter {
                types: None,
                min_score: 1.01,
                k: 4,
            },
        )
        .unwrap();
    assert!(hits.is_empty());
}

#[test]
fn delegated_put_generates_typed_keys() {
    use llmbridge::cache::{CachedType, GetFilter};
    use llmbridge::models::pricing::ModelId;
    let b = common::bridge();
    let g = b.generator();
    let cache = llmbridge::cache::SemanticCache::new(b.engine().embed_dim());
    let article = llmbridge::workload::corpus::article("sports", "cricket");
    let (ids, calls) = cache
        .put_delegated(g, ModelId::Phi3Mini, &article.title, &article.text)
        .unwrap();
    assert!(!ids.is_empty());
    assert!(!calls.is_empty(), "delegated PUT bills a cache-LLM call");
    assert!(cache.len_keys() > cache.len_objects(), "multiple keys per chunk");
    // A hypothetical-question style query lands on the article.
    let hits = cache
        .get(g, "tell me about cricket", &GetFilter::default())
        .unwrap();
    assert!(!hits.is_empty());
    assert!(hits[0].object.text.contains("cricket"));
    // Fact keys exist.
    let fact_hits = cache
        .get(
            g,
            "how many people play cricket every year",
            &GetFilter {
                types: Some(vec![CachedType::Fact]),
                min_score: 0.1,
                k: 3,
            },
        )
        .unwrap();
    assert!(!fact_hits.is_empty());
}

// ---------------------------------------------------------------------
// Similar / Summarize filters over real embeddings and generations.
// ---------------------------------------------------------------------

#[test]
fn similar_filter_ranks_by_embedding() {
    use llmbridge::context::{Filter, FilterCtx, Message};
    let b = common::bridge();
    let msgs: Vec<Message> = [
        "tell me about cricket matches in lahore",
        "recipe for chicken biryani with rice",
        "cricket rules for beginners explained",
    ]
    .iter()
    .enumerate()
    .map(|(i, p)| Message {
        prompt: p.to_string(),
        response: format!("answer {i}"),
        model: "m".into(),
        grounded_citations: false,
        seq: i as u64,
    })
    .collect();
    let traits = llmbridge::models::quality::QueryTraits {
        id: "sim-test".into(),
        difficulty: 0.3,
        factual: false,
        requires_context: false,
    };
    let cx = FilterCtx {
        generator: b.generator(),
        traits: &traits,
    };
    let f = Filter::Similar {
        threshold: 0.15,
        max: 2,
    };
    let sel = f
        .apply(&msgs, "what are the cricket rules in a match", &cx)
        .unwrap();
    // The two cricket messages, not the biryani one.
    assert!(sel.indices.contains(&0) || sel.indices.contains(&2), "{sel:?}");
    assert!(!sel.indices.contains(&1), "{sel:?}");
}

#[test]
fn summarize_filter_produces_synthetic_message() {
    use llmbridge::context::{Filter, FilterCtx, Message};
    use llmbridge::models::pricing::ModelId;
    let b = common::bridge();
    let msgs: Vec<Message> = (0..4)
        .map(|i| Message {
            prompt: format!("question about malaria number {i}"),
            response: format!("answer {i}"),
            model: "m".into(),
            grounded_citations: false,
            seq: i,
        })
        .collect();
    let traits = llmbridge::models::quality::QueryTraits {
        id: "sum-test".into(),
        difficulty: 0.3,
        factual: false,
        requires_context: true,
    };
    let cx = FilterCtx {
        generator: b.generator(),
        traits: &traits,
    };
    let f = Filter::Summarize {
        model: ModelId::Claude3Haiku,
    };
    let sel = f.apply(&msgs, "and what should i do next", &cx).unwrap();
    let materialized = sel.messages(&msgs);
    assert_eq!(materialized.len(), 1, "summary replaces the history");
    assert!(materialized[0].response.contains("malaria"), "lexical gist kept");
    assert_eq!(sel.llm_calls.len(), 1, "one summarize call billed");
    assert!((sel.sufficiency(4) - 0.8).abs() < 1e-9);
}

#[test]
fn batch_mode_compares_models_side_by_side() {
    // §5.2 future work: batch prompts across several models at once.
    let b = common::bridge();
    let prompts = vec![
        "classify this sentence as positive or negative".to_string(),
        "what are the benefits of lentils".to_string(),
    ];
    let models = vec![ModelId::Gpt4oMini, ModelId::Phi3Mini];
    let out = b.handle_batch("batch-user", &prompts, &models).unwrap();
    assert_eq!(out.len(), 2);
    for cmp in &out {
        assert_eq!(cmp.responses.len(), 2);
        let (m0, r0) = &cmp.responses[0];
        let (m1, r1) = &cmp.responses[1];
        assert_eq!(*m0, ModelId::Gpt4oMini);
        assert_eq!(*m1, ModelId::Phi3Mini);
        assert_ne!(r0.text, r1.text, "different models answer differently");
        // Benchmarking semantics: no context, no history pollution.
        assert_eq!(r0.metadata.context_messages, 0);
    }
    assert!(b.history("batch-user", "batch-0-gpt-4o-mini").is_empty());
}
