//! Default-backend determinism smoke: the no-`pjrt` build must produce
//! *fixed vectors for fixed inputs* — bit-identical across engine spawns
//! and across separate OS processes — and keep the similarity structure
//! `tests/runtime_smoke.rs` pins. Cross-process coverage drives the real
//! `llmbridge probe-backend` binary twice (via `CARGO_BIN_EXE_llmbridge`)
//! and diffs the fingerprints, so a regression to process-seeded state
//! (map iteration order, ASLR-derived hashes, clocks) cannot hide.
#![cfg(not(feature = "pjrt"))]

use llmbridge::runtime::{tokenizer, EngineHandle};
use llmbridge::vecdb::Metric;

#[test]
fn separate_spawns_are_bit_identical() {
    let a = EngineHandle::spawn_deterministic().unwrap();
    let b = EngineHandle::spawn_deterministic().unwrap();
    assert_eq!(a.backend_name(), "deterministic");
    assert_eq!(a.seq_len(), b.seq_len());
    assert_eq!(a.embed_dim(), b.embed_dim());
    for text in [
        "alpha beta gamma",
        "tell me about the socc conference",
        "",
        "Tell ME about THE socc CONFERENCE",
    ] {
        assert_eq!(a.embed_text(text).unwrap(), b.embed_text(text).unwrap(), "{text:?}");
    }
    let (tokens, live) = tokenizer::window("what is the capital of sudan", a.seq_len());
    for variant in ["nano", "mini", "large"] {
        assert_eq!(
            a.lm_logits(variant, tokens.clone(), live).unwrap(),
            b.lm_logits(variant, tokens.clone(), live).unwrap(),
            "{variant}"
        );
    }
    a.shutdown();
    b.shutdown();
}

#[test]
fn fingerprint_is_stable_across_processes() {
    let exe = env!("CARGO_BIN_EXE_llmbridge");
    let run = || {
        let out = std::process::Command::new(exe)
            .args(["probe-backend", "--text", "cross process determinism probe"])
            .output()
            .expect("spawn `llmbridge probe-backend`");
        assert!(
            out.status.success(),
            "probe-backend failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8(out.stdout).unwrap()
    };
    let first = run();
    let second = run();
    assert_eq!(first, second, "two processes must print identical fingerprints");
    assert!(first.contains("backend deterministic"), "{first}");
    // The fingerprint is not vacuous: it must match this (third) process's
    // in-memory embedding, bit for bit.
    let engine = EngineHandle::spawn_deterministic().unwrap();
    let emb = engine.embed_text("cross process determinism probe").unwrap();
    let bits: String = emb.iter().map(|v| format!("{:08x}", v.to_bits())).collect();
    assert!(
        first.contains(&bits),
        "binary fingerprint must contain the in-process embedding bits"
    );
    engine.shutdown();
}

#[test]
fn similarity_structure_holds_on_default_backend() {
    // The runtime_smoke contract, re-asserted directly against the default
    // backend: paraphrases beat unrelated texts by a clear margin, vectors
    // come back unit-normalized, and padding never leaks.
    let engine = EngineHandle::spawn_deterministic().unwrap();
    let a = engine.embed_text("tell me about the socc conference").unwrap();
    let b = engine
        .embed_text("talk to me about socc conference please")
        .unwrap();
    let c = engine.embed_text("recipe for chicken biryani with rice").unwrap();
    let sim_ab = Metric::Cosine.score(&a, &b);
    let sim_ac = Metric::Cosine.score(&a, &c);
    assert!(sim_ab > sim_ac + 0.2, "ab={sim_ab} ac={sim_ac}");
    let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-3);
    // Padding inertia on the lm path (mask correctness).
    let (tokens, live) = tokenizer::window("padding probe text", engine.seq_len());
    let clean = engine.lm_logits("nano", tokens.clone(), live).unwrap();
    let mut dirty = tokens;
    for t in dirty.iter_mut().skip(live as usize) {
        *t = 1234;
    }
    assert_eq!(clean, engine.lm_logits("nano", dirty, live).unwrap());
    engine.shutdown();
}
