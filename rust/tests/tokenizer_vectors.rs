//! Tokenizer vectors pinned against python/tests/test_tokenizer.py —
//! the two implementations must agree bit-for-bit or artifacts and proxy
//! disagree about token ids.

use llmbridge::runtime::tokenizer::{self, BOS, EOS, PAD};

#[test]
fn pinned_vectors_match_python() {
    // ("", [BOS, EOS])
    let (ids, live) = tokenizer::window("", 160);
    assert_eq!(&ids[..live as usize], &[BOS, EOS]);

    // "hello world"
    let (ids, live) = tokenizer::window("hello world", 160);
    assert_eq!(
        &ids[..live as usize],
        &[
            BOS,
            tokenizer::word_id("hello"),
            tokenizer::word_id("world"),
            EOS
        ]
    );

    // "Tell me about Sigcomm!"
    let (ids, live) = tokenizer::window("Tell me about Sigcomm!", 160);
    assert_eq!(
        &ids[..live as usize],
        &[
            BOS,
            tokenizer::word_id("tell"),
            tokenizer::word_id("me"),
            tokenizer::word_id("about"),
            tokenizer::word_id("sigcomm"),
            EOS
        ]
    );
    assert!(ids[live as usize..].iter().all(|&t| t == PAD));
}

#[test]
fn word_ids_match_fnv_definition() {
    // Mirrors python: FIRST_WORD_ID + fnv1a(word) % (VOCAB - FIRST_WORD_ID).
    for w in ["hello", "sigcomm", "a", "x1y2"] {
        let h = llmbridge::util::fnv1a(w.as_bytes());
        let expect = 16 + (h % (4096 - 16)) as i32;
        assert_eq!(tokenizer::word_id(w), expect);
    }
}

#[test]
fn case_and_punctuation_insensitive() {
    let (a, _) = tokenizer::window("Hello, WORLD!", 160);
    let (b, _) = tokenizer::window("hello world", 160);
    assert_eq!(a, b);
}
