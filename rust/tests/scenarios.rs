//! CI gate for the open-loop scenario engine: the full `default_matrix`
//! in smoke mode, on both server backends, plus typed-error coverage for
//! the hardened `tests/common` HttpClient.
//!
//! These are the same scenarios `benches/scenarios.rs` measures at full
//! size — here the point is not the numbers but the *invariants*: the
//! underloaded server serves everything, the overloaded one sheds with a
//! typed reason, the tripped breaker surfaces as `"breaker"`, the warm
//! cache actually hits (and costs less than cold), node B applies node
//! A's entries, and — the tentpole — no response ever observes a
//! half-applied config during the live generation swap.

mod common;

use std::sync::Mutex;
use std::time::Duration;

use llmbridge::scenario::{default_matrix, run_matrix, RunOptions, ScenarioOutcome};
use llmbridge::server::ServerBackend;

/// The two matrix runs share one process; serialize them so neither's
/// calibration measures the other's load.
static MATRIX_LOCK: Mutex<()> = Mutex::new(());

fn run_smoke_matrix(backend: ServerBackend) -> Vec<ScenarioOutcome> {
    let _guard = MATRIX_LOCK.lock().unwrap();
    let engine = common::bridge().engine().clone();
    run_matrix(&engine, &default_matrix(), &RunOptions::new(backend, true))
        .expect("scenario matrix")
}

fn by_name<'a>(outcomes: &'a [ScenarioOutcome], name: &str) -> &'a ScenarioOutcome {
    outcomes
        .iter()
        .find(|o| o.name == name)
        .unwrap_or_else(|| panic!("no outcome named {name}"))
}

fn assert_matrix_invariants(outcomes: &[ScenarioOutcome], backend: &str) {
    assert_eq!(outcomes.len(), default_matrix().len(), "[{backend}] one outcome per scenario");

    // Underload: everything scheduled is served; nothing shed or dropped.
    let under = by_name(outcomes, "underload");
    assert!(under.served > 0, "[{backend}] underload served nothing");
    assert_eq!(under.shed, 0, "[{backend}] underload shed: {:?}", under.shed_by_reason);
    assert_eq!(under.transport_errors, 0, "[{backend}] underload transport errors");
    assert_eq!(under.served, under.scheduled, "[{backend}] underload dropped requests");
    assert!(under.p50_us > 0, "[{backend}] latencies were measured");

    // Overload with watermark 1: admission control must visibly engage.
    let over = by_name(outcomes, "overload_shed");
    assert!(over.shed > 0, "[{backend}] overload_shed shed nothing");
    assert!(
        over.shed_by_reason.contains_key("admission"),
        "[{backend}] overload shed reasons missing 'admission': {:?}",
        over.shed_by_reason
    );
    assert!(over.served + over.shed + over.transport_errors == over.scheduled);

    // A tripped per-model breaker surfaces as typed 503 "breaker" sheds
    // on the quality tenant, while other tenants keep being served.
    let trip = by_name(outcomes, "breaker_trip");
    assert!(trip.served > 0, "[{backend}] breaker_trip served nothing");
    assert!(
        trip.shed_by_reason.get("breaker").copied().unwrap_or(0) > 0,
        "[{backend}] breaker_trip shed reasons missing 'breaker': {:?}",
        trip.shed_by_reason
    );

    // Cache: the pre-warmed exact store hits nearly always; the cold one
    // (the serve path never writes the exact store) essentially never.
    let cold = by_name(outcomes, "cache_cold");
    let warm = by_name(outcomes, "cache_warm");
    assert!(
        warm.cache_hit_rate > 0.9,
        "[{backend}] warm hit rate {} <= 0.9",
        warm.cache_hit_rate
    );
    assert!(
        cold.cache_hit_rate < 0.1,
        "[{backend}] cold hit rate {} >= 0.1",
        cold.cache_hit_rate
    );
    assert!(
        warm.cost_per_1k_usd < cold.cost_per_1k_usd,
        "[{backend}] warm cost/1k {} not below cold {}",
        warm.cost_per_1k_usd,
        cold.cost_per_1k_usd
    );

    // Two-node: node B applied node A's replicated cache entries.
    let sync = by_name(outcomes, "two_node_sync");
    assert!(
        sync.sync_applied.unwrap_or(0) > 0,
        "[{backend}] two_node_sync applied nothing: {:?}",
        sync.sync_applied
    );

    // Reconfig: the swap landed, traffic ran on both sides of it, and —
    // the invariant — not one response mixed old- and new-pool models.
    let rc = by_name(outcomes, "reconfig");
    assert_eq!(rc.reconfig_applied, Some(true), "[{backend}] admin config swap failed");
    let inv = rc.invariant.expect("reconfig invariant report");
    assert_eq!(inv.checked, rc.served, "[{backend}] every served response was checked");
    assert_eq!(
        inv.mixed, 0,
        "[{backend}] {} responses observed a half-applied config",
        inv.mixed
    );
    assert!(inv.old_only > 0, "[{backend}] no traffic on the old pool before cutover");
    assert!(inv.new_only > 0, "[{backend}] no traffic on the new pool after cutover");
    assert!(rc.cutover_slo_violations.is_some(), "[{backend}] cutover window measured");
}

#[test]
fn smoke_matrix_auto_backend() {
    let outcomes = run_smoke_matrix(ServerBackend::Auto);
    assert_matrix_invariants(&outcomes, "auto");
}

#[test]
fn smoke_matrix_threaded_backend() {
    let outcomes = run_smoke_matrix(ServerBackend::Threaded);
    assert_matrix_invariants(&outcomes, "threaded");
}

// ---- typed-error coverage for the hardened tests/common HttpClient ----

/// A one-shot peer that writes `payload` and then either drops the
/// connection or goes silent.
fn misbehaving_peer(payload: &'static [u8], drop_after: bool) -> std::net::SocketAddr {
    use std::io::{Read, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut sink = [0u8; 4096];
        let _ = s.read(&mut sink);
        s.write_all(payload).unwrap();
        if drop_after {
            let _ = s.shutdown(std::net::Shutdown::Both);
        } else {
            std::thread::sleep(Duration::from_secs(5));
        }
    });
    addr
}

#[test]
fn http_client_read_timeout_is_typed() {
    // Headers promise a body that never arrives: the old client hung for
    // 30 s then panicked; the hardened one returns Timeout within the
    // configured read timeout.
    let addr = misbehaving_peer(
        b"HTTP/1.1 200 OK\r\nContent-Length: 64\r\nConnection: keep-alive\r\n\r\n",
        false,
    );
    let mut c = common::HttpClient::try_connect(addr, Duration::from_millis(200)).unwrap();
    let t0 = std::time::Instant::now();
    let err = c.try_get("/v1/health").unwrap_err();
    assert_eq!(err, common::HttpError::Timeout("body"));
    assert!(t0.elapsed() < Duration::from_secs(3), "timed out promptly");
}

#[test]
fn http_client_mid_response_drop_is_typed() {
    let addr = misbehaving_peer(
        b"HTTP/1.1 200 OK\r\nContent-Length: 64\r\nConnection: keep-alive\r\n\r\npartial",
        true,
    );
    let mut c = common::HttpClient::try_connect(addr, Duration::from_secs(2)).unwrap();
    assert_eq!(
        c.try_post("/v1/request", "{}").unwrap_err(),
        common::HttpError::Closed("body")
    );
}

#[test]
fn http_client_panicking_api_still_works_end_to_end() {
    let addr = misbehaving_peer(
        b"HTTP/1.1 200 OK\r\nContent-Length: 15\r\nConnection: close\r\n\r\n{\"status\":\"ok\"}",
        true,
    );
    let mut c = common::HttpClient::connect(addr);
    let (status, head, json) = c.post_full("/x", "{}");
    assert_eq!(status, 200);
    assert!(head.to_ascii_lowercase().contains("connection: close"));
    assert_eq!(json.get("status").and_then(|s| s.as_str()), Some("ok"));
}
