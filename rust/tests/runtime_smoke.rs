//! Runtime integration: the serving backend (deterministic by default;
//! PJRT over real artifacts under `--features pjrt`) answers lm/embed
//! calls and the generator drives the decode loop deterministically.

mod common;

use llmbridge::models::pricing::ModelId;
use llmbridge::runtime::tokenizer;
use llmbridge::vecdb::Metric;

#[test]
fn lm_logits_deterministic_and_padding_inert() {
    let b = common::bridge();
    let engine = b.engine();
    let (tokens, live) =
        tokenizer::window("what is the capital of sudan", engine.seq_len());
    let a = engine.lm_logits("nano", tokens.clone(), live).unwrap();
    let c = engine.lm_logits("nano", tokens.clone(), live).unwrap();
    assert_eq!(a, c);
    assert_eq!(a.len(), 4096);
    // Garbage beyond `live` must not change logits (mask correctness).
    let mut dirty = tokens.clone();
    for t in dirty.iter_mut().skip(live as usize) {
        *t = 1234;
    }
    let d = engine.lm_logits("nano", dirty, live).unwrap();
    for (x, y) in a.iter().zip(&d) {
        assert!((x - y).abs() < 1e-4, "{x} vs {y}");
    }
}

#[test]
fn variants_disagree() {
    let b = common::bridge();
    let engine = b.engine();
    let (tokens, live) = tokenizer::window("tell me about cricket", engine.seq_len());
    let nano = engine.lm_logits("nano", tokens.clone(), live).unwrap();
    let large = engine.lm_logits("large", tokens, live).unwrap();
    let diff: f32 = nano.iter().zip(&large).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1.0, "different weights must give different logits");
}

#[test]
fn embedder_similarity_structure() {
    let b = common::bridge();
    let engine = b.engine();
    let a = engine.embed_text("tell me about the socc conference").unwrap();
    let bb = engine
        .embed_text("talk to me about socc conference please")
        .unwrap();
    let c = engine.embed_text("recipe for chicken biryani with rice").unwrap();
    let sim_ab = Metric::Cosine.score(&a, &bb);
    let sim_ac = Metric::Cosine.score(&a, &c);
    assert!(sim_ab > sim_ac + 0.2, "ab={sim_ab} ac={sim_ac}");
    // Normalized.
    let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-3);
}

#[test]
fn generator_deterministic_and_memoized() {
    let b = common::bridge();
    let g = b.generator();
    let c1 = g
        .generate(ModelId::Gpt4oMini, "what are the benefits of dates", None)
        .unwrap();
    let c2 = g
        .generate(ModelId::Gpt4oMini, "what are the benefits of dates", None)
        .unwrap();
    assert_eq!(c1.text, c2.text);
    assert!(!c1.from_memo);
    assert!(c2.from_memo, "second identical call must hit the memo");
    assert_eq!(c1.latency, c2.latency, "memo preserves measured latency");
    assert!(c1.output_tokens >= 1);
    assert_eq!(c1.input_tokens, 6);
    assert!(c1.cost_usd > 0.0);
}

#[test]
fn models_give_different_texts() {
    let b = common::bridge();
    let g = b.generator();
    let prompt = "explain vaccination in simple words";
    let mini = g.generate(ModelId::Gpt4oMini, prompt, None).unwrap();
    let large = g.generate(ModelId::Gpt4o, prompt, None).unwrap();
    assert_ne!(mini.text, large.text);
    // Bigger models produce longer (more detailed) answers by budget.
    assert!(
        ModelId::Gpt4o.spec().default_max_new > ModelId::Gpt4oMini.spec().default_max_new
    );
}

#[test]
fn larger_model_slower() {
    let b = common::bridge();
    let g = b.generator();
    // Fresh prompts (avoid memo), fixed output length for a fair compare.
    let nano = g
        .generate(ModelId::Phi3Mini, "latency probe alpha", Some(8))
        .unwrap();
    let large = g
        .generate(ModelId::Gpt4o, "latency probe alpha", Some(8))
        .unwrap();
    assert!(
        large.latency > nano.latency,
        "large {:?} must exceed nano {:?}",
        large.latency,
        nano.latency
    );
}

#[test]
fn long_input_billed_untruncated() {
    let b = common::bridge();
    let g = b.generator();
    let long: String = (0..600).map(|i| format!("w{i} ")).collect();
    let c = g.generate(ModelId::Gpt4oMini, &long, Some(4)).unwrap();
    assert_eq!(c.input_tokens, 600, "billing uses pre-truncation counts");
}
