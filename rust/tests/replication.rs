//! Two-node replication harness: bidirectional churn → anti-entropy
//! round → bit-exact convergence (`replica_fingerprint` as the oracle),
//! symmetric conflict tiebreaks, kill-and-restart mid-sync, the
//! compaction-during-sync race, the version-0 (legacy corpus) upgrade
//! path, and the zero-cost-when-off contract.

mod common;

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use llmbridge::cache::{GetFilter, SyncApplied};
use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::persist::wal::{self, WalOp};
use llmbridge::server::{Server, ServerConfig};
use llmbridge::sync::{run_once, SyncConfig, SyncService};

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "llmbridge_replication_{name}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn node_config(dir: &Path, node: Option<&str>) -> BridgeConfig {
    BridgeConfig {
        data_dir: Some(dir.to_path_buf()),
        node_id: node.map(String::from),
        ..Default::default()
    }
}

/// A durable bridge with a replication identity, sharing the test
/// binary's engine.
fn node_bridge(dir: &Path, node: &str) -> Arc<Bridge> {
    Arc::new(
        Bridge::from_engine(common::bridge().engine().clone(), node_config(dir, Some(node)))
            .unwrap(),
    )
}

/// Accept-only sync listener for `bridge` on an ephemeral port; returns
/// the service (keep it alive) and the address peers dial.
fn listener_for(bridge: &Arc<Bridge>) -> (SyncService, String) {
    let service = SyncService::start(
        bridge.clone(),
        SyncConfig {
            node_id: bridge.cache().replication_node().unwrap().to_string(),
            listen_port: Some(0),
            peer: None,
            // Tests drive rounds explicitly; park the cadence out of the way.
            interval: Duration::from_secs(3600),
        },
    )
    .unwrap();
    let addr = service.listen_addr().unwrap().to_string();
    (service, addr)
}

/// One bidirectional round: `a` dials `b`'s listener.
fn round(a: &Bridge, b: &Arc<Bridge>) -> llmbridge::sync::RoundReport {
    let (_service, addr) = listener_for(b);
    run_once(a, &addr).unwrap()
}

#[test]
fn bidirectional_churn_converges_bit_exact() {
    let (dir_a, dir_b) = (fresh_dir("churn_a"), fresh_dir("churn_b"));
    let a = node_bridge(&dir_a, "node-a");
    let b = node_bridge(&dir_b, "node-b");

    // Disjoint churn on both sides: exact entries, semantic objects, and
    // a remove (tombstone) each.
    for i in 0..6 {
        a.cache()
            .put_exact(&format!("alpha question {i}"), &format!("alpha answer {i}"));
        b.cache()
            .put_exact(&format!("beta question {i}"), &format!("beta answer {i}"));
    }
    a.cache()
        .put_interaction(
            a.generator(),
            "what makes the desert bloom after rain",
            "dormant seeds germinate when moisture arrives",
        )
        .unwrap();
    b.cache()
        .put_interaction(
            b.generator(),
            "why do rivers meander across plains",
            "sediment erosion and deposition bend the channel over time",
        )
        .unwrap();
    a.cache().put_exact("alpha doomed", "soon removed");
    assert!(a.cache().remove_exact("alpha doomed"));

    let report = round(&a, &b);
    assert!(report.shipped > 0 && report.applied > 0, "{report:?}");

    let (fa, fb) = (a.cache().replica_fingerprint(), b.cache().replica_fingerprint());
    assert!(!fa.is_empty());
    assert_eq!(fa, fb, "replicas must be bit-exact after one round");

    // A prompt cached only on A is a *semantic* hit on B, scored
    // bit-identically (the vectors traveled; B never re-embedded).
    let filter = GetFilter::default();
    let query = "what makes the desert bloom after rain";
    let hits_a = a.cache().get(a.generator(), query, &filter).unwrap();
    let hits_b = b.cache().get(b.generator(), query, &filter).unwrap();
    assert!(!hits_b.is_empty(), "cross-node semantic hit expected");
    let view = |hits: &[llmbridge::cache::CacheHit]| -> Vec<(String, String, u64)> {
        hits.iter()
            .map(|h| (h.object.text.clone(), h.object.origin.clone(), h.score.to_bits()))
            .collect()
    };
    assert_eq!(view(&hits_a), view(&hits_b));

    // The tombstone replicated, not just the absence.
    assert_eq!(b.cache().get_exact("alpha doomed"), None);

    // Converged replicas have nothing left to ship.
    let report = round(&a, &b);
    assert_eq!((report.shipped, report.applied, report.stale), (0, 0, 0));
}

#[test]
fn conflict_tiebreak_is_symmetric_and_deterministic() {
    let (dir_a, dir_b) = (fresh_dir("conflict_a"), fresh_dir("conflict_b"));
    let a = node_bridge(&dir_a, "node-a");
    let b = node_bridge(&dir_b, "node-b");

    // Same key written concurrently on both nodes at equal clock values:
    // versions tie, so the lexicographically greater origin must win —
    // on BOTH nodes, regardless of delivery order.
    a.cache().put_exact("contested fact", "answer from a");
    b.cache().put_exact("contested fact", "answer from b");
    round(&a, &b);
    assert_eq!(
        a.cache().get_exact("contested fact").as_deref(),
        Some("answer from b")
    );
    assert_eq!(
        b.cache().get_exact("contested fact").as_deref(),
        Some("answer from b")
    );

    // Higher version beats origin: A overwrites locally (its Lamport
    // clock has observed B's version, so the new stamp is strictly
    // higher) and must now win everywhere — a local overwrite is never
    // silently undone by replication.
    a.cache().put_exact("contested fact", "second thoughts from a");
    round(&a, &b);
    assert_eq!(
        b.cache().get_exact("contested fact").as_deref(),
        Some("second thoughts from a")
    );
    assert_eq!(
        a.cache().replica_fingerprint(),
        b.cache().replica_fingerprint()
    );
}

#[test]
fn kill_and_restart_mid_sync_then_converge() {
    let (dir_a, dir_b) = (fresh_dir("kill_a"), fresh_dir("kill_b"));
    let a = node_bridge(&dir_a, "node-a");

    for i in 0..10 {
        a.cache()
            .put_exact(&format!("durable fact {i}"), &format!("value {i}"));
    }
    a.cache()
        .put_interaction(a.generator(), "how do tides work", "lunar gravity pulls the ocean")
        .unwrap();

    // Simulate a round dying mid-stream: B applies only half the delta
    // (each application journals through B's WAL), then the process dies.
    {
        let b = node_bridge(&dir_b, "node-b");
        let delta = a.cache().sync_delta(&b.cache().sync_hwms());
        assert!(delta.len() >= 4);
        for entry in delta.into_iter().take(4) {
            assert!(matches!(
                b.cache().apply_sync_entry(entry).unwrap(),
                SyncApplied::Applied
            ));
        }
        // Dropped without graceful shutdown: the WAL tail is what's left.
    }

    // Restart: the half-applied entries survived their journaling; the
    // next full round ships only the missing tail and converges.
    let b = node_bridge(&dir_b, "node-b");
    assert!(!b.cache().sync_hwms().is_empty(), "partial apply must survive restart");
    round(&a, &b);
    assert_eq!(
        a.cache().replica_fingerprint(),
        b.cache().replica_fingerprint()
    );
}

#[test]
fn compaction_between_rounds_preserves_convergence() {
    let (dir_a, dir_b) = (fresh_dir("compact_a"), fresh_dir("compact_b"));
    let a = node_bridge(&dir_a, "node-a");
    let b = node_bridge(&dir_b, "node-b");

    for i in 0..5 {
        a.cache()
            .put_exact(&format!("early fact {i}"), &format!("early value {i}"));
    }
    a.cache().put_exact("ephemeral fact", "will be tombstoned");
    round(&a, &b);

    // Each node compacts independently — coordination-free GC. The
    // replicated entries, their stamps, and the tombstone below must all
    // survive the fold into a snapshot.
    assert!(b.compact_persistence().unwrap());
    a.cache().remove_exact("ephemeral fact");
    for i in 0..4 {
        a.cache()
            .put_exact(&format!("late fact {i}"), &format!("late value {i}"));
    }
    assert!(a.compact_persistence().unwrap());
    round(&a, &b);
    assert_eq!(
        a.cache().replica_fingerprint(),
        b.cache().replica_fingerprint()
    );
    assert_eq!(b.cache().get_exact("ephemeral fact"), None);

    // Restart both off their compacted snapshots: state (stamps, floors,
    // tombstones included) restores bit-exactly.
    let fp = a.cache().replica_fingerprint();
    drop(a);
    drop(b);
    let a = node_bridge(&dir_a, "node-a");
    let b = node_bridge(&dir_b, "node-b");
    assert_eq!(a.cache().replica_fingerprint(), fp);
    assert_eq!(b.cache().replica_fingerprint(), fp);
}

#[test]
fn legacy_corpus_adopts_and_replicates() {
    let (dir_a, dir_b) = (fresh_dir("legacy_a"), fresh_dir("legacy_b"));

    // A pre-replication deployment: no node id, legacy WAL records only.
    {
        let legacy = Bridge::from_engine(
            common::bridge().engine().clone(),
            node_config(&dir_a, None),
        )
        .unwrap();
        legacy.cache().put_exact("legacy fact", "legacy answer");
        legacy
            .cache()
            .put_interaction(
                legacy.generator(),
                "what did the old deployment cache",
                "everything it served",
            )
            .unwrap();
    }

    // First boot with a node id: version-0 entries are adopted (fresh own
    // stamps, journaled), so the whole legacy corpus becomes shippable.
    let a = node_bridge(&dir_a, "node-a");
    let hwm = a.cache().sync_hwms();
    assert!(hwm.get("node-a").copied().unwrap_or(0) >= 2, "{hwm:?}");

    let b = node_bridge(&dir_b, "node-b");
    round(&a, &b);
    assert_eq!(
        b.cache().get_exact("legacy fact").as_deref(),
        Some("legacy answer")
    );
    assert_eq!(
        a.cache().replica_fingerprint(),
        b.cache().replica_fingerprint()
    );

    // Adoption itself is WAL-durable: a further restart replays the
    // Adopt records and reaches the same stamped state, issuing no new
    // versions (the clock restarts from the persisted floor).
    let fp = a.cache().replica_fingerprint();
    let clock = a.cache().replication_clock();
    drop(a);
    let a = node_bridge(&dir_a, "node-a");
    assert_eq!(a.cache().replica_fingerprint(), fp);
    assert_eq!(a.cache().replication_clock(), clock);
}

#[test]
fn replication_off_is_zero_cost_and_legacy_wal_shaped() {
    let dir = fresh_dir("off");
    {
        let plain = Bridge::from_engine(
            common::bridge().engine().clone(),
            node_config(&dir, None),
        )
        .unwrap();
        assert_eq!(plain.cache().replication_node(), None);
        plain.cache().put_exact("plain fact", "plain answer");
        plain
            .cache()
            .put_interaction(plain.generator(), "a plain prompt", "a plain response")
            .unwrap();
        plain.cache().put_exact("plain doomed", "x");
        plain.cache().remove_exact("plain doomed");
        assert!(plain.cache().sync_hwms().is_empty());
    }

    // The WAL a replication-off node writes contains only the legacy
    // record catalogue — byte-compatible with every pre-replication
    // reader, no stamps anywhere.
    let wal_path = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .find(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("wal-"))
        })
        .expect("a WAL file");
    let (ops, _report) = wal::recover(&wal_path).unwrap();
    assert!(!ops.is_empty());
    assert!(
        ops.iter().all(|op| !matches!(
            op,
            WalOp::PutExactV { .. }
                | WalOp::PutObjectV { .. }
                | WalOp::RemoveExactV { .. }
                | WalOp::Adopt { .. }
        )),
        "replication off must journal only legacy records"
    );

    // And that WAL restores on a replication-off boot, unchanged.
    let plain = Bridge::from_engine(
        common::bridge().engine().clone(),
        node_config(&dir, None),
    )
    .unwrap();
    assert_eq!(
        plain.cache().get_exact("plain fact").as_deref(),
        Some("plain answer")
    );
    assert_eq!(plain.cache().get_exact("plain doomed"), None);
}

#[test]
fn server_sync_wiring_and_admin_status() {
    let a = Arc::new(
        Bridge::from_engine(
            common::bridge().engine().clone(),
            BridgeConfig {
                node_id: Some("node-a".into()),
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let b = Arc::new(
        Bridge::from_engine(
            common::bridge().engine().clone(),
            BridgeConfig {
                node_id: Some("node-b".into()),
                ..Default::default()
            },
        )
        .unwrap(),
    );
    a.cache().put_exact("fleet fact", "served once, hit twice");

    let server_b = Server::start_with(
        b.clone(),
        "127.0.0.1:0",
        ServerConfig {
            workers: 2,
            admin_bind: Some("127.0.0.1:0".into()),
            sync: Some(SyncConfig {
                node_id: "node-b".into(),
                listen_port: Some(0),
                peer: None,
                interval: Duration::from_secs(3600),
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let sync_addr = server_b.sync_addr().expect("sync listener bound");

    let server_a = Server::start_with(
        a.clone(),
        "127.0.0.1:0",
        ServerConfig {
            workers: 2,
            admin_bind: Some("127.0.0.1:0".into()),
            sync: Some(SyncConfig {
                node_id: "node-a".into(),
                listen_port: None,
                peer: Some(sync_addr.to_string()),
                interval: Duration::from_secs(3600),
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let report = server_a.sync_now().unwrap();
    assert!(report.shipped >= 1, "{report:?}");
    assert_eq!(
        b.cache().get_exact("fleet fact").as_deref(),
        Some("served once, hit twice")
    );

    // /admin/sync reports identity, wiring, and the round that just ran.
    let mut admin = common::HttpClient::connect(server_a.admin_addr.unwrap());
    let (status, j) = admin.get("/admin/sync");
    assert_eq!(status, 200);
    assert_eq!(j.str_of("node").unwrap(), "node-a");
    assert_eq!(j.str_of("peer").unwrap(), sync_addr.to_string());
    assert!(j.get("rounds_ok").and_then(|v| v.as_f64()).unwrap() >= 1.0);
    assert!(j.get("entries_shipped").and_then(|v| v.as_f64()).unwrap() >= 1.0);

    // sync_* counters ride the ordinary metrics surface.
    let mut data = common::HttpClient::connect(server_a.addr);
    let (status, metrics) = data.get("/v1/metrics");
    assert_eq!(status, 200);
    assert!(metrics.to_string().contains("sync_rounds_ok"));

    server_a.stop();
    server_b.stop();

    // An unreplicated server answers the same route with enabled:false.
    let plain = Arc::new(
        Bridge::from_engine(common::bridge().engine().clone(), BridgeConfig::default())
            .unwrap(),
    );
    let server_plain = Server::start_with(
        plain,
        "127.0.0.1:0",
        ServerConfig {
            workers: 2,
            admin_bind: Some("127.0.0.1:0".into()),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut admin = common::HttpClient::connect(server_plain.admin_addr.unwrap());
    let (status, j) = admin.get("/admin/sync");
    assert_eq!(status, 200);
    assert_eq!(j.get("enabled").and_then(|v| v.as_bool()), Some(false));
    server_plain.stop();
}
