//! Paper-shape assertions: the qualitative claims of §2.2 + §5.3 must hold
//! on subsampled replays — who wins, by roughly what factor, where the
//! crossovers fall. The `figures` binary regenerates the full-dataset
//! numbers recorded in EXPERIMENTS.md.

mod common;

use llmbridge::experiments as exp;
use llmbridge::models::pricing::Generation;

const LIMIT: Option<usize> = Some(40);

#[test]
fn fig1_context_cost_grows_superlinearly_and_k1_is_cheap() {
    let bridge = common::private_bridge(Default::default());
    let rows = exp::fig1(&bridge, exp::DEFAULT_SEED, Some(25)).unwrap();
    let tokens: Vec<u64> = rows.iter().map(|r| r.input_tokens).collect();
    // Monotone in k.
    assert!(tokens.windows(2).all(|w| w[0] < w[1]), "{tokens:?}");
    let base = tokens[0] as f64;
    // k=1 is a small constant factor (paper: ~3x)...
    assert!(
        (2.0..6.0).contains(&(tokens[1] as f64 / base)),
        "k=1 ratio {}",
        tokens[1] as f64 / base
    );
    // ...while the full-context conversation blows up (paper: ~55x at 50
    // queries; sublinear to that at 25 queries but still >12x).
    assert!(
        tokens.last().unwrap() / tokens[0] > 12,
        "k=max ratio {}",
        tokens.last().unwrap() / tokens[0]
    );
    // Quality: no-context is worst in the tail; k>=1 close to reference.
    let q0 = exp::percentiles(rows[0].quality_scores.clone(), &[0.2])[0].1;
    let q1 = exp::percentiles(rows[1].quality_scores.clone(), &[0.2])[0].1;
    assert!(q1 > q0 + 1.0, "tail-20% gap: k0={q0:.2} k1={q1:.2}");
}

#[test]
fn fig45_verification_cascade_beats_m1_and_undercuts_m2() {
    for generation in [Generation::Old, Generation::New] {
        let bridge = common::private_bridge(llmbridge::coordinator::BridgeConfig {
            generation,
            ..Default::default()
        });
        let out = exp::fig45(&bridge, exp::DEFAULT_SEED, generation, LIMIT).unwrap();
        let q = |prefix: &str| -> f64 {
            let (_, scores) = out
                .quality
                .iter()
                .find(|(l, _)| l.starts_with(prefix))
                .unwrap();
            exp::mean(scores)
        };
        // Quality: verification > M1-only. The margin is generation-
        // dependent — the paper's own finding is that new-generation cheap
        // models nearly close the gap (Fig 4b), so only the old pool gets
        // a hard margin.
        let margin = if generation == Generation::Old { 0.5 } else { 0.0 };
        assert!(
            q("verification") > q("gpt-") + margin,
            "{generation:?}: verify {} vs m1 {}",
            q("verification"),
            q("gpt-")
        );
        // Cost: M1-only < verification < M2-only.
        let cost = |prefix: &str| {
            out.cost.iter().find(|(l, _)| l.starts_with(prefix)).unwrap().1
        };
        let verify_cost = cost("verification");
        let m2_cost = out.cost.last().unwrap().1;
        assert!(verify_cost > 1.0 && verify_cost < m2_cost);
        // Paper Fig 5a: a substantial reduction vs M2-only (~40%; accept >=20%).
        if generation == Generation::Old {
            let reduction = 1.0 - verify_cost / m2_cost;
            assert!(
                reduction >= 0.20,
                "cost reduction vs M2-only {reduction:.2}"
            );
        }
    }
}

#[test]
fn fig4_new_generation_routes_less_to_m2() {
    let old_bridge = common::private_bridge(llmbridge::coordinator::BridgeConfig {
        generation: Generation::Old,
        ..Default::default()
    });
    let new_bridge = common::private_bridge(Default::default());
    let old = exp::fig45(&old_bridge, exp::DEFAULT_SEED, Generation::Old, Some(80)).unwrap();
    let new = exp::fig45(&new_bridge, exp::DEFAULT_SEED, Generation::New, Some(80)).unwrap();
    // Paper: >60% with old models, ~25% with new — newer cheap models
    // close the gap.
    assert!(
        old.escalation_fraction > new.escalation_fraction + 0.15,
        "old {:.2} vs new {:.2}",
        old.escalation_fraction,
        new.escalation_fraction
    );
    assert!((0.45..=0.85).contains(&old.escalation_fraction));
    assert!((0.10..=0.45).contains(&new.escalation_fraction));
}

#[test]
fn fig6_smart_context_saves_cost_with_bounded_quality_loss() {
    let bridge = common::private_bridge(Default::default());
    let out = exp::fig6(&bridge, exp::DEFAULT_SEED, LIMIT).unwrap();
    let cost = |prefix: &str| {
        out.cost.iter().find(|(l, _)| l.starts_with(prefix)).unwrap().1
    };
    // smart(k=5) is cheaper than last-5; smart(k=1) cheaper than last-1
    // is not guaranteed (two extra nano calls), but must be well under k5.
    assert!(
        cost("smart_context(k=5)") < cost("gpt-4o(k=5)") * 0.85,
        "smart5 {} vs k5 {}",
        cost("smart_context(k=5)"),
        cost("gpt-4o(k=5)")
    );
    // Quality ordering: k0 worst in tail-20%; smart strategies above it.
    let tail = |prefix: &str| {
        let (_, scores) = out
            .quality
            .iter()
            .find(|(l, _)| l.starts_with(prefix))
            .unwrap();
        exp::percentiles(scores.clone(), &[0.2])[0].1
    };
    assert!(
        tail("smart_context(k=5)") > tail("gpt-4o(k=0)"),
        "smart5 tail {} vs k0 tail {}",
        tail("smart_context(k=5)"),
        tail("gpt-4o(k=0)")
    );
    // Fig 6c: decision time is a minority share for most messages.
    for (label, fracs) in &out.decision_time_fraction {
        let p80 = exp::percentiles(fracs.clone(), &[0.8])[0].1;
        assert!(p80 < 0.55, "{label}: p80 decision share {p80:.2}");
    }
}

#[test]
fn fig7_smart_cache_lifts_worst_case_on_factual_queries() {
    let bridge = common::private_bridge(Default::default());
    let out = exp::fig7(&bridge, exp::DEFAULT_SEED, Some(30)).unwrap();
    assert!(out.n_factual >= 10, "need factual queries, got {}", out.n_factual);
    assert!(out.n_cache_used >= 3, "cache used {}", out.n_cache_used);
    let min_of = |set: &[(String, Vec<f64>)], prefix: &str| {
        let (_, scores) = set.iter().find(|(l, _)| l.starts_with(prefix)).unwrap();
        scores.iter().cloned().fold(f64::INFINITY, f64::min)
    };
    // 7a ordering: gpt-4o >> phi-3 on factual queries.
    let mean_of = |prefix: &str| {
        let (_, scores) = out.quality.iter().find(|(l, _)| l.starts_with(prefix)).unwrap();
        exp::mean(scores)
    };
    assert!(
        mean_of("gpt-4o") > mean_of("phi-3-mini") + 1.0,
        "gpt4o {} vs phi {}",
        mean_of("gpt-4o"),
        mean_of("phi-3-mini")
    );
    // 7b: on the cache-used subset the grounded floor beats phi-3 alone
    // by a wide margin (paper: min 4 vs 1 — a 4x lift).
    let smart_min = min_of(&out.cache_used_quality, "smart_cache");
    let phi_min = min_of(&out.cache_used_quality, "phi-3-mini");
    assert!(
        smart_min > phi_min + 1.5,
        "smart min {smart_min:.2} vs phi min {phi_min:.2}"
    );
}
