//! Shared integration-test harness: one engine + bridge per test binary.

use std::sync::{Arc, OnceLock};

use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::models::pricing::Generation;

pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

static BRIDGE: OnceLock<Arc<Bridge>> = OnceLock::new();

/// A shared bridge (new-generation pool, memoized, no prefetch).
pub fn bridge() -> Arc<Bridge> {
    BRIDGE
        .get_or_init(|| {
            Arc::new(
                Bridge::open_with(artifacts_dir(), BridgeConfig::default())
                    .expect("bring up serving backend (pjrt builds: run `make artifacts`)"),
            )
        })
        .clone()
}

/// A private bridge with custom config, sharing the same engine.
pub fn private_bridge(config: BridgeConfig) -> Bridge {
    let shared = bridge();
    Bridge::from_engine(shared.engine().clone(), config).unwrap()
}

#[allow(dead_code)]
pub fn old_gen_config() -> BridgeConfig {
    BridgeConfig {
        generation: Generation::Old,
        ..Default::default()
    }
}

/// A test HTTP/1.1 client that frames responses by `Content-Length`
/// instead of waiting for EOF — required against the evented server,
/// which holds keep-alive connections open, and correct against the
/// threaded server, which closes them. Leftover bytes past one response
/// stay buffered, so pipelined responses read back one at a time.
#[allow(dead_code)]
pub struct HttpClient {
    pub stream: std::net::TcpStream,
    buf: Vec<u8>,
}

#[allow(dead_code)]
impl HttpClient {
    pub fn connect(addr: std::net::SocketAddr) -> HttpClient {
        let stream = std::net::TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(30)))
            .unwrap();
        HttpClient {
            stream,
            buf: Vec::new(),
        }
    }

    pub fn send_raw(&mut self, raw: &[u8]) {
        use std::io::Write;
        self.stream.write_all(raw).unwrap();
    }

    /// One GET round-trip (connection stays usable afterward).
    pub fn get(&mut self, path: &str) -> (u16, llmbridge::util::json::Json) {
        self.send_raw(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes());
        self.read_response()
    }

    /// One POST round-trip (connection stays usable afterward).
    pub fn post(&mut self, path: &str, body: &str) -> (u16, llmbridge::util::json::Json) {
        let (status, _head, json) = self.post_full(path, body);
        (status, json)
    }

    /// One POST round-trip that also returns the raw response header
    /// block, for header assertions (`Retry-After`).
    pub fn post_full(
        &mut self,
        path: &str,
        body: &str,
    ) -> (u16, String, llmbridge::util::json::Json) {
        self.send_raw(
            format!(
                "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        );
        self.read_response_full()
    }

    /// One DELETE round-trip (connection stays usable afterward).
    pub fn delete(&mut self, path: &str) -> (u16, llmbridge::util::json::Json) {
        self.send_raw(format!("DELETE {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes());
        self.read_response()
    }

    /// Read exactly one Content-Length-framed response.
    pub fn read_response(&mut self) -> (u16, llmbridge::util::json::Json) {
        let (status, _head, json) = self.read_response_full();
        (status, json)
    }

    /// [`Self::read_response`], also returning the raw header block.
    pub fn read_response_full(&mut self) -> (u16, String, llmbridge::util::json::Json) {
        use std::io::Read;
        fn find(buf: &[u8], needle: &[u8]) -> Option<usize> {
            buf.windows(needle.len()).position(|w| w == needle)
        }
        let mut tmp = [0u8; 4096];
        let head_end = loop {
            if let Some(p) = find(&self.buf, b"\r\n\r\n") {
                break p + 4;
            }
            let n = self.stream.read(&mut tmp).unwrap();
            assert!(n > 0, "connection closed before response head");
            self.buf.extend_from_slice(&tmp[..n]);
        };
        let head = String::from_utf8(self.buf[..head_end].to_vec()).unwrap();
        let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
        let clen: usize = head
            .lines()
            .find_map(|l| {
                let (k, v) = l.split_once(':')?;
                if k.eq_ignore_ascii_case("content-length") {
                    v.trim().parse().ok()
                } else {
                    None
                }
            })
            .unwrap_or(0);
        while self.buf.len() < head_end + clen {
            let n = self.stream.read(&mut tmp).unwrap();
            assert!(n > 0, "connection closed mid-body");
            self.buf.extend_from_slice(&tmp[..n]);
        }
        let body = String::from_utf8(self.buf[head_end..head_end + clen].to_vec()).unwrap();
        // Keep bytes past this response (pipelined successors) buffered.
        self.buf.drain(..head_end + clen);
        let json = llmbridge::util::json::Json::parse(&body)
            .unwrap_or(llmbridge::util::json::Json::Null);
        (status, head, json)
    }
}
