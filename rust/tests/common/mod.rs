//! Shared integration-test harness: one engine + bridge per test binary.

use std::sync::{Arc, OnceLock};

use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::models::pricing::Generation;

pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

static BRIDGE: OnceLock<Arc<Bridge>> = OnceLock::new();

/// A shared bridge (new-generation pool, memoized, no prefetch).
pub fn bridge() -> Arc<Bridge> {
    BRIDGE
        .get_or_init(|| {
            Arc::new(
                Bridge::open_with(artifacts_dir(), BridgeConfig::default())
                    .expect("bring up serving backend (pjrt builds: run `make artifacts`)"),
            )
        })
        .clone()
}

/// A private bridge with custom config, sharing the same engine.
pub fn private_bridge(config: BridgeConfig) -> Bridge {
    let shared = bridge();
    Bridge::from_engine(shared.engine().clone(), config).unwrap()
}

#[allow(dead_code)]
pub fn old_gen_config() -> BridgeConfig {
    BridgeConfig {
        generation: Generation::Old,
        ..Default::default()
    }
}
