//! Shared integration-test harness: one engine + bridge per test binary.

use std::sync::{Arc, OnceLock};

use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::models::pricing::Generation;
#[allow(unused_imports)]
pub use llmbridge::scenario::http::{HttpConn, HttpError, HttpResponse};

pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

static BRIDGE: OnceLock<Arc<Bridge>> = OnceLock::new();

/// A shared bridge (new-generation pool, memoized, no prefetch).
pub fn bridge() -> Arc<Bridge> {
    BRIDGE
        .get_or_init(|| {
            Arc::new(
                Bridge::open_with(artifacts_dir(), BridgeConfig::default())
                    .expect("bring up serving backend (pjrt builds: run `make artifacts`)"),
            )
        })
        .clone()
}

/// A private bridge with custom config, sharing the same engine.
pub fn private_bridge(config: BridgeConfig) -> Bridge {
    let shared = bridge();
    Bridge::from_engine(shared.engine().clone(), config).unwrap()
}

#[allow(dead_code)]
pub fn old_gen_config() -> BridgeConfig {
    BridgeConfig {
        generation: Generation::Old,
        ..Default::default()
    }
}

/// A test HTTP/1.1 client that frames responses by `Content-Length`
/// instead of waiting for EOF — required against the evented server,
/// which holds keep-alive connections open, and correct against the
/// threaded server, which closes them. Leftover bytes past one response
/// stay buffered, so pipelined responses read back one at a time.
///
/// Transport is [`llmbridge::scenario::http::HttpConn`]: the `try_*`
/// methods surface its typed failures ([`HttpError::Timeout`],
/// [`HttpError::Closed`], [`HttpError::Malformed`]) for tests that
/// exercise misbehaving peers; the unprefixed methods keep the historic
/// panic-on-failure convenience API. A stuck socket fails within the
/// read timeout instead of hanging the test binary.
#[allow(dead_code)]
pub struct HttpClient {
    pub conn: HttpConn,
}

// Field/method access forwards to the connection, so existing tests can
// keep reaching `client.stream` for raw socket surgery.
impl std::ops::Deref for HttpClient {
    type Target = HttpConn;
    fn deref(&self) -> &HttpConn {
        &self.conn
    }
}

impl std::ops::DerefMut for HttpClient {
    fn deref_mut(&mut self) -> &mut HttpConn {
        &mut self.conn
    }
}

#[allow(dead_code)]
impl HttpClient {
    pub fn connect(addr: std::net::SocketAddr) -> HttpClient {
        Self::try_connect(addr, std::time::Duration::from_secs(30)).unwrap()
    }

    /// [`Self::connect`] with a caller-chosen read timeout and typed errors.
    pub fn try_connect(
        addr: std::net::SocketAddr,
        read_timeout: std::time::Duration,
    ) -> Result<HttpClient, HttpError> {
        Ok(HttpClient {
            conn: HttpConn::connect(addr, read_timeout)?,
        })
    }

    pub fn send_raw(&mut self, raw: &[u8]) {
        self.conn.send_raw(raw).unwrap();
    }

    /// One GET round-trip (connection stays usable afterward).
    pub fn get(&mut self, path: &str) -> (u16, llmbridge::util::json::Json) {
        let r = self.try_get(path).unwrap();
        (r.status, parse_json(&r.body))
    }

    /// One GET round-trip with typed transport errors.
    pub fn try_get(&mut self, path: &str) -> Result<HttpResponse, HttpError> {
        self.conn.get(path)
    }

    /// One POST round-trip (connection stays usable afterward).
    pub fn post(&mut self, path: &str, body: &str) -> (u16, llmbridge::util::json::Json) {
        let (status, _head, json) = self.post_full(path, body);
        (status, json)
    }

    /// One POST round-trip with typed transport errors.
    pub fn try_post(&mut self, path: &str, body: &str) -> Result<HttpResponse, HttpError> {
        self.conn.post(path, body)
    }

    /// One POST round-trip that also returns the raw response header
    /// block, for header assertions (`Retry-After`).
    pub fn post_full(
        &mut self,
        path: &str,
        body: &str,
    ) -> (u16, String, llmbridge::util::json::Json) {
        let r = self.try_post(path, body).unwrap();
        (r.status, r.head, parse_json(&r.body))
    }

    /// One DELETE round-trip (connection stays usable afterward).
    pub fn delete(&mut self, path: &str) -> (u16, llmbridge::util::json::Json) {
        self.send_raw(format!("DELETE {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes());
        self.read_response()
    }

    /// Read exactly one Content-Length-framed response.
    pub fn read_response(&mut self) -> (u16, llmbridge::util::json::Json) {
        let (status, _head, json) = self.read_response_full();
        (status, json)
    }

    /// Read one response with typed transport errors.
    pub fn try_read_response(&mut self) -> Result<HttpResponse, HttpError> {
        self.conn.read_response()
    }

    /// [`Self::read_response`], also returning the raw header block.
    pub fn read_response_full(&mut self) -> (u16, String, llmbridge::util::json::Json) {
        let r = self.try_read_response().unwrap();
        (r.status, r.head, parse_json(&r.body))
    }
}

#[allow(dead_code)]
fn parse_json(body: &str) -> llmbridge::util::json::Json {
    llmbridge::util::json::Json::parse(body).unwrap_or(llmbridge::util::json::Json::Null)
}
