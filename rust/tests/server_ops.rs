//! Operational-resilience integration tests (ROADMAP item 2), on both
//! transport backends over real TCP:
//!
//! * panic isolation — an injected handler panic costs one connection a
//!   500 and the server keeps serving (the poisoned-completions-mutex
//!   regression);
//! * the circuit breaker end-to-end — injected generate failures trip it,
//!   requests fast-fail 503 `"reason":"breaker"` with `Retry-After`
//!   while `/health` and the admin port stay responsive, and the
//!   half-open probe restores service after the cooldown;
//! * per-user rate limiting and its `POST /admin/config` hot-reload;
//! * the admin surface: cache stats, journaled invalidation, breaker
//!   snapshot, config validation.
//!
//! Failure injection rides the `LLMBRIDGE_FAILPOINTS=1` gate; the flag
//! only arms `POST /v1/test/panic` and the `params.failpoint` hook, so
//! setting it process-wide here cannot change other behavior.

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::HttpClient;
use llmbridge::coordinator::BridgeConfig;
use llmbridge::ops::BreakerConfig;
use llmbridge::server::{Server, ServerBackend, ServerConfig};

fn enable_failpoints() {
    std::env::set_var("LLMBRIDGE_FAILPOINTS", "1");
}

fn ops_server(backend: ServerBackend, bridge: Arc<llmbridge::coordinator::Bridge>) -> Server {
    Server::start_with(
        bridge,
        "127.0.0.1:0",
        ServerConfig {
            workers: 2,
            backend,
            admin_bind: Some("127.0.0.1:0".into()),
            ..ServerConfig::default()
        },
    )
    .unwrap()
}

fn fixed_body(user: &str, prompt: &str, model: &str, failpoint: bool) -> String {
    let params = if failpoint {
        r#","params":{"failpoint":"generate"}"#
    } else {
        ""
    };
    format!(
        r#"{{"user":"{user}","conversation":"c1","prompt":"{prompt}",
            "service_type":{{"name":"fixed","model":"{model}","cache":"skip"}}{params}}}"#
    )
}

// ---------------------------------------------------------------- panics

/// The PR 8 headline regression: a panicking handler used to poison the
/// completions mutex and take the whole server down with it. Now it must
/// cost exactly one 500 and leave the server serving.
fn panic_leaves_server_serving(backend: ServerBackend) {
    enable_failpoints();
    let bridge = common::bridge();
    let server = Server::start_with(
        bridge.clone(),
        "127.0.0.1:0",
        ServerConfig {
            workers: 2,
            backend,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr;

    let (code, j) = HttpClient::connect(addr).post("/v1/test/panic", "{}");
    assert_eq!(code, 500, "{}", j.to_string());
    assert!(j.str_of("error").unwrap().contains("panicked"));
    assert!(bridge.telemetry().counters.get("server_worker_panics") >= 1);

    // The server is still alive: probes answer and real work completes.
    let (code, _) = HttpClient::connect(addr).get("/health");
    assert_eq!(code, 200);
    let (code, j) = HttpClient::connect(addr).post(
        "/v1/request",
        &fixed_body("panic-after", "still serving?", "gpt-4o-mini", false),
    );
    assert_eq!(code, 200, "{}", j.to_string());
    server.stop();
}

#[test]
fn panic_leaves_server_serving_default_backend() {
    panic_leaves_server_serving(ServerBackend::Auto);
}

#[test]
fn panic_leaves_server_serving_threaded_backend() {
    panic_leaves_server_serving(ServerBackend::Threaded);
}

// --------------------------------------------------------------- breaker

/// Breaker lifecycle over real HTTP: trip on injected generate failures,
/// fast-fail 503 with `Retry-After` while open (probes + admin stay
/// responsive, other models unaffected), recover via the half-open probe.
fn breaker_opens_sheds_and_recovers(backend: ServerBackend) {
    enable_failpoints();
    let bridge = Arc::new(common::private_bridge(BridgeConfig {
        breaker: BreakerConfig {
            threshold: 2,
            cooldown: Duration::from_millis(300),
        },
        ..BridgeConfig::default()
    }));
    let server = ops_server(backend, bridge.clone());
    let addr = server.addr;
    let admin = server.admin_addr.unwrap();

    // Two consecutive infrastructure failures trip the breaker.
    for i in 0..2 {
        let (code, j) = HttpClient::connect(addr).post(
            "/v1/request",
            &fixed_body(&format!("bk-f{i}"), "inject failure", "gpt-4o-mini", true),
        );
        assert_eq!(code, 500, "{}", j.to_string());
    }
    assert!(bridge.telemetry().counters.get("breaker_trips") >= 1);

    // Open: a healthy request fast-fails with the typed 503.
    let (code, head, j) = HttpClient::connect(addr).post_full(
        "/v1/request",
        &fixed_body("bk-shed", "shed me", "gpt-4o-mini", false),
    );
    assert_eq!(code, 503, "{}", j.to_string());
    assert_eq!(j.str_of("reason").unwrap(), "breaker");
    assert!(head.contains("Retry-After:"), "{head}");

    // Liveness and the admin surface keep answering while it sheds.
    let (code, _) = HttpClient::connect(addr).get("/health");
    assert_eq!(code, 200);
    let (code, b) = HttpClient::connect(admin).get("/admin/breaker");
    assert_eq!(code, 200, "{}", b.to_string());
    let line = b.req("models").unwrap().req("gpt-4o-mini").unwrap();
    assert_eq!(line.str_of("state").unwrap(), "open");

    // Per-model isolation: a different model serves normally.
    let (code, j) = HttpClient::connect(addr).post(
        "/v1/request",
        &fixed_body("bk-other", "other model fine", "phi-3-mini", false),
    );
    assert_eq!(code, 200, "{}", j.to_string());

    // Cooldown lapses: the next request is the probe; success recovers.
    std::thread::sleep(Duration::from_millis(350));
    let (code, j) = HttpClient::connect(addr).post(
        "/v1/request",
        &fixed_body("bk-rec", "probe me back to life", "gpt-4o-mini", false),
    );
    assert_eq!(code, 200, "{}", j.to_string());
    assert!(bridge.telemetry().counters.get("breaker_recoveries") >= 1);
    let (_, b) = HttpClient::connect(admin).get("/admin/breaker");
    let line = b.req("models").unwrap().req("gpt-4o-mini").unwrap();
    assert_eq!(line.str_of("state").unwrap(), "closed");

    server.stop();
}

#[test]
fn breaker_opens_sheds_and_recovers_default_backend() {
    breaker_opens_sheds_and_recovers(ServerBackend::Auto);
}

#[test]
fn breaker_opens_sheds_and_recovers_threaded_backend() {
    breaker_opens_sheds_and_recovers(ServerBackend::Threaded);
}

// ---------------------------------------------------- rate + hot reload

/// Rate limiting is off by default, switches on through `POST
/// /admin/config` with no restart, rejects invalid/unknown fields whole,
/// and switches back off — each request seeing one coherent config.
fn rate_limit_hot_reload(backend: ServerBackend) {
    let bridge = Arc::new(common::private_bridge(BridgeConfig::default()));
    let server = ops_server(backend, bridge);
    let addr = server.addr;
    let admin = server.admin_addr.unwrap();

    // Disabled by default: a burst of requests from one user all pass.
    for i in 0..3 {
        let (code, j) = HttpClient::connect(addr).post(
            "/v1/request",
            &fixed_body("rl-u1", &format!("warm {i}"), "gpt-4o-mini", false),
        );
        assert_eq!(code, 200, "{}", j.to_string());
    }

    // Hot-reload a 1-token bucket with a trickle refill.
    let (code, j) = HttpClient::connect(admin).post(
        "/admin/config",
        r#"{"rate_per_sec":0.01,"rate_burst":1}"#,
    );
    assert_eq!(code, 200, "{}", j.to_string());
    assert_eq!(j.get("applied"), Some(&llmbridge::util::json::Json::Bool(true)));

    // First request spends the token; the second sheds with the typed
    // 429 — "rate", not "admission" or "quota" — and a Retry-After.
    let (code, j) = HttpClient::connect(addr).post(
        "/v1/request",
        &fixed_body("rl-u2", "token one", "gpt-4o-mini", false),
    );
    assert_eq!(code, 200, "{}", j.to_string());
    let (code, head, j) = HttpClient::connect(addr).post_full(
        "/v1/request",
        &fixed_body("rl-u2", "token two", "gpt-4o-mini", false),
    );
    assert_eq!(code, 429, "{}", j.to_string());
    assert_eq!(j.str_of("reason").unwrap(), "rate");
    assert!(head.contains("Retry-After:"), "{head}");

    // An unknown field rejects the whole reload — nothing half-applies.
    let (code, _) = HttpClient::connect(admin).post(
        "/admin/config",
        r#"{"rate_per_sec":1000,"bogus_knob":1}"#,
    );
    assert_eq!(code, 400);
    // Still the old config: a fresh user gets exactly one token.
    let (code, _) = HttpClient::connect(addr).post(
        "/v1/request",
        &fixed_body("rl-u3", "one", "gpt-4o-mini", false),
    );
    assert_eq!(code, 200);
    let (code, j) = HttpClient::connect(addr).post(
        "/v1/request",
        &fixed_body("rl-u3", "two", "gpt-4o-mini", false),
    );
    assert_eq!(code, 429, "{}", j.to_string());

    // Switch it back off; the drained user admits again immediately.
    let (code, _) =
        HttpClient::connect(admin).post("/admin/config", r#"{"rate_per_sec":0}"#);
    assert_eq!(code, 200);
    let (code, j) = HttpClient::connect(addr).post(
        "/v1/request",
        &fixed_body("rl-u2", "limits off", "gpt-4o-mini", false),
    );
    assert_eq!(code, 200, "{}", j.to_string());

    server.stop();
}

#[test]
fn rate_limit_hot_reload_default_backend() {
    rate_limit_hot_reload(ServerBackend::Auto);
}

#[test]
fn rate_limit_hot_reload_threaded_backend() {
    rate_limit_hot_reload(ServerBackend::Threaded);
}

// ---------------------------------------------------------- admin surface

fn admin_surface(backend: ServerBackend) {
    let bridge = Arc::new(common::private_bridge(BridgeConfig::default()));
    let server = ops_server(backend, bridge.clone());
    let addr = server.addr;
    let admin = server.admin_addr.unwrap();

    // Admin routes do not exist on the data port.
    let (code, _) = HttpClient::connect(addr).get("/admin/cache");
    assert_eq!(code, 404);

    // Cache stats carry the index tier and entry counts.
    let (code, j) = HttpClient::connect(admin).get("/admin/cache");
    assert_eq!(code, 200, "{}", j.to_string());
    assert!(!j.str_of("tier").unwrap().is_empty());
    assert!(j.get("rows").is_some() && j.get("exact").is_some());

    // Targeted invalidation, key percent-encoded in the query string.
    bridge.cache().put_exact("what is rust?", "a systems language");
    assert!(bridge.cache().get_exact("what is rust?").is_some());
    let (code, j) =
        HttpClient::connect(admin).delete("/admin/cache?key=what%20is%20rust%3F");
    assert_eq!(code, 200, "{}", j.to_string());
    assert_eq!(j.get("removed"), Some(&llmbridge::util::json::Json::Bool(true)));
    assert!(bridge.cache().get_exact("what is rust?").is_none());
    // Idempotent: a second delete reports nothing removed.
    let (_, j) = HttpClient::connect(admin).delete("/admin/cache?key=what%20is%20rust%3F");
    assert_eq!(j.get("removed"), Some(&llmbridge::util::json::Json::Bool(false)));

    // Full clear.
    bridge.cache().put_exact("ephemeral", "entry");
    let (code, j) = HttpClient::connect(admin).delete("/admin/cache");
    assert_eq!(code, 200, "{}", j.to_string());
    assert_eq!(j.get("cleared"), Some(&llmbridge::util::json::Json::Bool(true)));
    assert_eq!(bridge.cache().len_exact(), 0);

    // Probes and metrics ride the admin port too; unknown routes 404.
    let (code, _) = HttpClient::connect(admin).get("/health");
    assert_eq!(code, 200);
    let (code, _) = HttpClient::connect(admin).get("/v1/metrics");
    assert_eq!(code, 200);
    let (code, _) = HttpClient::connect(admin).get("/admin/nope");
    assert_eq!(code, 404);

    server.stop();
}

#[test]
fn admin_surface_default_backend() {
    admin_surface(ServerBackend::Auto);
}

#[test]
fn admin_surface_threaded_backend() {
    admin_surface(ServerBackend::Threaded);
}

// --------------------------------------------------------- badjson reject

fn badjson_is_rejected_inline(backend: ServerBackend) {
    let bridge = Arc::new(common::private_bridge(BridgeConfig::default()));
    let server = ops_server(backend, bridge.clone());
    let addr = server.addr;

    let before = bridge.telemetry().counters.get("server_reject_badjson");
    let (code, j) = HttpClient::connect(addr).post("/v1/request", "{definitely not json");
    assert_eq!(code, 400, "{}", j.to_string());
    assert!(bridge.telemetry().counters.get("server_reject_badjson") > before);
    // The reject is per-request: the same socket keeps working on the
    // keep-alive (evented) path, and a fresh one works on both.
    let (code, j) = HttpClient::connect(addr).post(
        "/v1/request",
        &fixed_body("bj-u", "valid after invalid", "gpt-4o-mini", false),
    );
    assert_eq!(code, 200, "{}", j.to_string());

    server.stop();
}

#[test]
fn badjson_is_rejected_inline_default_backend() {
    badjson_is_rejected_inline(ServerBackend::Auto);
}

#[test]
fn badjson_is_rejected_inline_threaded_backend() {
    badjson_is_rejected_inline(ServerBackend::Threaded);
}

// --------------------------------------------------------- engine timeout

#[test]
fn engine_rpc_timeout_is_configurable() {
    use llmbridge::runtime::EngineHandle;
    let engine = EngineHandle::spawn_deterministic().unwrap();
    assert_eq!(engine.rpc_timeout(), Duration::from_secs(120));
    engine.set_rpc_timeout(Duration::from_secs(3));
    assert_eq!(engine.rpc_timeout(), Duration::from_secs(3));
    // Zero clamps to a nonzero arm — recv_timeout(0) would always fire.
    engine.set_rpc_timeout(Duration::ZERO);
    assert!(engine.rpc_timeout() > Duration::ZERO);
    // A healthy engine still answers under a tight-but-sane timeout.
    engine.set_rpc_timeout(Duration::from_secs(30));
    assert!(!engine.embed_text("timeout smoke").unwrap().is_empty());
    engine.shutdown();
}

#[test]
fn bridge_config_engine_timeout_applies() {
    let bridge = common::private_bridge(BridgeConfig {
        engine_timeout: Some(Duration::from_secs(77)),
        ..BridgeConfig::default()
    });
    assert_eq!(bridge.engine().rpc_timeout(), Duration::from_secs(77));
    // The engine is shared with the rest of the binary — restore it.
    bridge.engine().set_rpc_timeout(Duration::from_secs(120));
}
