//! Crash-recovery test substrate for the persist subsystem (snapshot +
//! WAL): durable-prefix parity against an in-memory oracle under
//! arbitrary WAL cuts, byte-identical restore equivalence across all
//! `GetFilter` shapes on a 5k-entry cache, WAL-corruption fuzzing
//! (truncate vs bit-flip), concurrency regression with the journal wired,
//! and quota/exchange/regenerate survival across restarts.

mod common;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use llmbridge::api::{Request, ServiceType};
use llmbridge::cache::{CacheHit, CachedType, GetFilter};
use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::error::BridgeError;
use llmbridge::models::pricing::ModelId;
use llmbridge::persist::wal::{self, WalOp, WalWriter, WAL_MAGIC};
use llmbridge::util::prop::gen_text;
use llmbridge::util::rng::Rng;

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "llmbridge_persistence_{name}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn persisted_config(dir: &Path) -> BridgeConfig {
    BridgeConfig {
        data_dir: Some(dir.to_path_buf()),
        ..Default::default()
    }
}

/// A durable bridge sharing the test binary's engine.
fn persisted_bridge(dir: &Path) -> Bridge {
    Bridge::from_engine(common::bridge().engine().clone(), persisted_config(dir)).unwrap()
}

/// A fresh, fully in-memory bridge on the same engine (the oracle side).
fn oracle_bridge() -> Bridge {
    Bridge::from_engine(common::bridge().engine().clone(), BridgeConfig::default()).unwrap()
}

fn wal_file(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("wal-{generation}.log"))
}

fn wal_len(dir: &Path, generation: u64) -> u64 {
    std::fs::metadata(wal_file(dir, generation)).unwrap().len()
}

/// Everything observable about a hit list, bit-exact (scores compared by
/// f64 bits — "byte-identical", not approximately equal).
fn fingerprint(hits: &[CacheHit]) -> Vec<(u64, String, String, bool, &'static str, u64)> {
    hits.iter()
        .map(|h| {
            (
                h.object.id,
                h.object.text.clone(),
                h.object.origin.clone(),
                h.object.is_document,
                h.matched_type.as_str(),
                h.score.to_bits(),
            )
        })
        .collect()
}

// ---------------------------------------------------------------------
// Satellite 1: crash-recovery property test — random op sequences, WAL
// cut at arbitrary byte offsets, restore must equal an oracle that saw
// exactly the durable prefix.
// ---------------------------------------------------------------------

enum Op {
    Exact(String, String),
    Interaction(String, String),
}

impl Op {
    fn prompt(&self) -> &str {
        match self {
            Op::Exact(p, _) | Op::Interaction(p, _) => p,
        }
    }

    fn apply(&self, bridge: &Bridge) {
        match self {
            Op::Exact(p, r) => bridge.cache().put_exact(p, r),
            Op::Interaction(p, r) => {
                bridge
                    .cache()
                    .put_interaction(bridge.generator(), p, r)
                    .unwrap();
            }
        }
    }
}

#[test]
fn crash_recovery_matches_durable_prefix_oracle() {
    let dir = fresh_dir("crash");
    let live = persisted_bridge(&dir);
    let mut r = Rng::new(0x51AB);

    // Seeded random op sequence; record the WAL high-water mark after
    // each op — the durable boundary if the process dies right there.
    let mut ops: Vec<(Op, u64)> = Vec::new();
    for i in 0..32 {
        let prompt = format!("{} crash probe {i}", gen_text(&mut r, 5));
        let response = format!("crash answer {i} {}", gen_text(&mut r, 4));
        let op = if r.chance(0.4) {
            Op::Exact(prompt, response)
        } else {
            Op::Interaction(prompt, response)
        };
        op.apply(&live);
        ops.push((op, wal_len(&dir, 0)));
    }
    let final_len = wal_len(&dir, 0);
    assert!(final_len > WAL_MAGIC.len() as u64);

    // Cut offsets: the bare magic, clean op boundaries, arbitrary
    // mid-record bytes, and the uncut file.
    let mut cuts: Vec<u64> = vec![WAL_MAGIC.len() as u64, ops[5].1, ops[20].1, final_len];
    for _ in 0..6 {
        cuts.push(WAL_MAGIC.len() as u64 + r.next_u64() % (final_len - WAL_MAGIC.len() as u64));
    }

    for cut in cuts {
        // "Crash": copy the WAL, truncate at the cut, restore from it.
        let cut_dir = fresh_dir(&format!("crash_cut_{cut}"));
        std::fs::copy(wal_file(&dir, 0), wal_file(&cut_dir, 0)).unwrap();
        std::fs::OpenOptions::new()
            .write(true)
            .open(wal_file(&cut_dir, 0))
            .unwrap()
            .set_len(cut)
            .unwrap();
        let restored = persisted_bridge(&cut_dir);

        // Oracle: an in-memory cache that saw exactly the ops whose
        // records are fully inside the durable prefix.
        let oracle = oracle_bridge();
        for (op, end) in &ops {
            if *end <= cut {
                op.apply(&oracle);
            }
        }

        // Exact-hit parity over every prompt ever issued.
        for (op, _) in &ops {
            assert_eq!(
                restored.cache().get_exact(op.prompt()),
                oracle.cache().get_exact(op.prompt()),
                "exact parity diverged at cut={cut} prompt={:?}",
                op.prompt()
            );
        }
        // Top-k semantic parity (ids, types, bit-exact scores).
        for (qi, (op, _)) in ops.iter().enumerate().step_by(5) {
            let filter = GetFilter {
                types: None,
                min_score: 0.0,
                k: 4,
            };
            let a = restored
                .cache()
                .get(restored.generator(), op.prompt(), &filter)
                .unwrap();
            let b = oracle
                .cache()
                .get(oracle.generator(), op.prompt(), &filter)
                .unwrap();
            assert_eq!(
                fingerprint(&a),
                fingerprint(&b),
                "semantic parity diverged at cut={cut} query #{qi}"
            );
        }
        let _ = std::fs::remove_dir_all(&cut_dir);
    }
}

// ---------------------------------------------------------------------
// Satellite 2: restore equivalence — a 5k-entry cache restarted through
// snapshot + WAL must serve byte-identical hits across filter shapes.
// ---------------------------------------------------------------------

#[test]
fn restore_equivalence_5k_entries_all_filter_shapes() {
    let dir = fresh_dir("equiv");
    let live = persisted_bridge(&dir);
    let mut r = Rng::new(0xE017);

    let mut prompts: Vec<String> = Vec::new();
    for i in 0..2500 {
        let prompt = format!("{} entry {i}", gen_text(&mut r, 4));
        let response = format!("{} detail {i}", gen_text(&mut r, 4));
        live.cache()
            .put_interaction(live.generator(), &prompt, &response)
            .unwrap();
        if i % 250 == 0 {
            live.cache().put_exact(&prompt, &response);
        }
        prompts.push(prompt);
        if i == 1600 {
            // Fold the first 1601 interactions into a snapshot so the
            // restart exercises snapshot restore *plus* WAL-tail replay.
            assert!(live.compact_persistence().unwrap());
        }
    }
    assert_eq!(live.cache().len_keys(), 5000, "5k typed keys in the index");

    let restored = persisted_bridge(&dir);
    assert_eq!(restored.cache().len_objects(), live.cache().len_objects());
    assert_eq!(restored.cache().len_keys(), live.cache().len_keys());

    let type_shapes: [Option<Vec<CachedType>>; 4] = [
        None,
        Some(vec![CachedType::Prompt]),
        Some(vec![CachedType::Response]),
        Some(vec![CachedType::Prompt, CachedType::Response]),
    ];
    let queries: Vec<String> = (0..12)
        .map(|i| prompts[i * 200].clone())
        .chain((0..4).map(|_| gen_text(&mut r, 6)))
        .collect();
    for q in &queries {
        for types in &type_shapes {
            for &min_score in &[0.0, 0.5] {
                // k=16 with a threshold exercises the widening over-fetch
                // loop; its result order must survive the restart too.
                for &k in &[1usize, 4, 16] {
                    let filter = GetFilter {
                        types: types.clone(),
                        min_score,
                        k,
                    };
                    let a = live.cache().get(live.generator(), q, &filter).unwrap();
                    let b = restored
                        .cache()
                        .get(restored.generator(), q, &filter)
                        .unwrap();
                    assert_eq!(
                        fingerprint(&a),
                        fingerprint(&b),
                        "hit divergence: q={q:?} types={types:?} min={min_score} k={k}"
                    );
                }
            }
        }
    }
    for p in prompts.iter().step_by(250) {
        assert_eq!(live.cache().get_exact(p), restored.cache().get_exact(p));
    }
}

// ---------------------------------------------------------------------
// Satellite 3: WAL-corruption fuzzing — truncation always recovers with
// a warning; interior corruption is always a typed error; never a panic,
// never a silent full parse of damaged bytes.
// ---------------------------------------------------------------------

#[test]
fn wal_corruption_fuzz_truncate_vs_bitflip() {
    let dir = fresh_dir("fuzz");
    let path = wal_file(&dir, 0);
    let writer = WalWriter::create(&path).unwrap();
    let mut boundaries = vec![writer.len()];
    for i in 0..6 {
        writer
            .append(&WalOp::PutExact {
                prompt: format!("fuzz prompt {i}"),
                response: format!("fuzz resp {i}"),
            })
            .unwrap();
        boundaries.push(writer.len());
    }
    drop(writer);
    let good = std::fs::read(&path).unwrap();

    // (a) Truncation at EVERY byte offset recovers: no error, no panic,
    // and exactly the fully-durable prefix survives.
    for cut in 0..=good.len() {
        let (ops, valid) = wal::scan(&good[..cut]).unwrap_or_else(|e| {
            panic!("truncation at {cut} must recover, got error: {e}")
        });
        let expect = boundaries.iter().skip(1).filter(|b| **b <= cut as u64).count();
        assert_eq!(ops.len(), expect, "cut={cut}");
        assert!(valid <= cut as u64);
    }

    // (b) A single flipped bit anywhere in the record region is never
    // silently absorbed: either a typed Persist error (checksum/length/
    // decode) or a detected-and-warned truncation — never a clean parse
    // of all 6 records, and never a panic.
    for pos in WAL_MAGIC.len()..good.len() {
        let mut bad = good.clone();
        bad[pos] ^= 0x10;
        match wal::scan(&bad) {
            Ok((ops, _)) => assert!(
                ops.len() < 6,
                "bit flip at byte {pos} was silently absorbed"
            ),
            Err(e) => assert!(matches!(e, BridgeError::Persist(_)), "{e}"),
        }
    }

    // (c) End-to-end: a torn tail boots with the prefix; a payload flip
    // fails boot with BridgeError::Persist (the REST layer maps it 500).
    std::fs::write(&path, &good[..(boundaries[3] + 5) as usize]).unwrap();
    let bridge = persisted_bridge(&dir);
    assert_eq!(
        bridge.cache().get_exact("fuzz prompt 2").as_deref(),
        Some("fuzz resp 2")
    );
    assert_eq!(bridge.cache().get_exact("fuzz prompt 4"), None);
    let stats = bridge.persistence().unwrap().stats();
    assert_eq!(stats.replayed_ops, 3);
    assert!(stats.truncated_bytes > 0, "torn tail must be reported");
    drop(bridge);

    let mut bad = good.clone();
    bad[boundaries[1] as usize + 12 + 3] ^= 0x01; // record 1, payload byte
    std::fs::write(&path, &bad).unwrap();
    let err = Bridge::from_engine(common::bridge().engine().clone(), persisted_config(&dir))
        .unwrap_err();
    let be = err
        .downcast_ref::<BridgeError>()
        .expect("boot failure must stay typed");
    assert!(matches!(be, BridgeError::Persist(_)), "{be}");
    assert_eq!(be.http_status(), 500);
}

// ---------------------------------------------------------------------
// Satellite 4: concurrency regression — 8 threads of mixed PUT/GET with
// the journal wired (plus compactions racing the traffic) keep the
// tests/concurrency.rs invariants, don't deadlock against the 16-way
// shard locks, and everything lands durably.
// ---------------------------------------------------------------------

#[test]
fn wal_concurrent_mixed_ops_no_deadlock_and_all_durable() {
    let dir = fresh_dir("conc");
    let bridge = Arc::new(persisted_bridge(&dir));
    let threads = 8;
    let per_thread = 8;
    std::thread::scope(|s| {
        for t in 0..threads {
            let bridge = bridge.clone();
            s.spawn(move || {
                for i in 0..per_thread {
                    let prompt =
                        format!("durable thread {t} question {i} about subject {}", i % 3);
                    let response = format!("durable answer {t} {i}");
                    bridge
                        .cache()
                        .put_interaction(bridge.generator(), &prompt, &response)
                        .unwrap();
                    bridge.cache().put_exact(&prompt, &response);
                    assert_eq!(
                        bridge.cache().get_exact(&prompt).as_deref(),
                        Some(response.as_str())
                    );
                    let hits = bridge
                        .cache()
                        .get(bridge.generator(), &prompt, &GetFilter::default())
                        .unwrap();
                    assert!(!hits.is_empty(), "semantic lookup starved for {prompt:?}");
                }
            });
        }
        // Compactions racing the writers exercise the gate's exclusive
        // path against the shared-mode mutators.
        let compactor = bridge.clone();
        s.spawn(move || {
            for _ in 0..3 {
                compactor.compact_persistence().unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        });
    });
    // Same count invariants as tests/concurrency.rs.
    assert_eq!(bridge.cache().len_objects(), threads * per_thread);
    assert_eq!(bridge.cache().len_keys(), 2 * threads * per_thread);
    assert_eq!(bridge.cache().len_exact(), threads * per_thread);

    // Everything that happened is durable across a restart.
    drop(bridge);
    let restored = persisted_bridge(&dir);
    assert_eq!(restored.cache().len_objects(), threads * per_thread);
    assert_eq!(restored.cache().len_keys(), 2 * threads * per_thread);
    for t in 0..threads {
        for i in 0..per_thread {
            let prompt = format!("durable thread {t} question {i} about subject {}", i % 3);
            assert_eq!(
                restored.cache().get_exact(&prompt).as_deref(),
                Some(format!("durable answer {t} {i}").as_str())
            );
        }
    }
}

// ---------------------------------------------------------------------
// Admin invalidation durability (PR 8): a `DELETE /admin/cache?key=`
// issued over the admin port journals a RemoveExact through the WAL, so
// the invalidation holds across a restart — and across a compaction
// that folds the WAL into a snapshot.
// ---------------------------------------------------------------------

#[test]
fn admin_invalidation_is_journaled_and_survives_restart() {
    use llmbridge::server::{Server, ServerConfig};

    let dir = fresh_dir("admin_inval");
    let bridge = Arc::new(persisted_bridge(&dir));
    bridge.cache().put_exact("keep me", "kept");
    bridge.cache().put_exact("remove me", "doomed");

    // Invalidate end-to-end over the admin port (percent-encoded key).
    let server = Server::start_with(
        bridge.clone(),
        "127.0.0.1:0",
        ServerConfig {
            workers: 1,
            admin_bind: Some("127.0.0.1:0".into()),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let admin = server.admin_addr.unwrap();
    let (code, j) = common::HttpClient::connect(admin).delete("/admin/cache?key=remove%20me");
    assert_eq!(code, 200, "{}", j.to_string());
    assert_eq!(bridge.cache().get_exact("remove me"), None);
    server.stop(); // graceful: drains and fsyncs the WAL

    // A remove that matched nothing must not have been journaled.
    let len_before = wal_len(&dir, 0);
    assert!(!bridge.cache().remove_exact("never existed"));
    assert_eq!(wal_len(&dir, 0), len_before, "no-op remove is not journaled");
    drop(bridge);

    // Replay order (put, put, remove) reproduces the live state.
    let restored = persisted_bridge(&dir);
    assert_eq!(restored.cache().get_exact("keep me").as_deref(), Some("kept"));
    assert_eq!(restored.cache().get_exact("remove me"), None);

    // The invalidation also survives being folded into a snapshot.
    assert!(restored.compact_persistence().unwrap());
    drop(restored);
    let again = persisted_bridge(&dir);
    assert_eq!(again.cache().get_exact("keep me").as_deref(), Some("kept"));
    assert_eq!(again.cache().get_exact("remove me"), None);
}

// ---------------------------------------------------------------------
// Quota + exchange durability: gated usage and regeneration handles
// survive a restart.
// ---------------------------------------------------------------------

#[test]
fn quotas_and_exchanges_survive_restart() {
    let dir = fresh_dir("quota");
    let bridge = persisted_bridge(&dir);
    let st = ServiceType::UsageBased {
        allowed: vec![ModelId::Gpt4oMini],
        fallback: ModelId::Gpt4oMini,
    };
    let resp = bridge
        .handle(
            Request::new("student-1", "c1", "what is photosynthesis in plants")
                .service_type(st.clone()),
        )
        .unwrap();
    bridge
        .handle(
            Request::new("student-1", "c1", "and how does chlorophyll relate to it")
                .service_type(st),
        )
        .unwrap();
    let usage = bridge.quota_usage("student-1");
    assert!(usage.0 >= 2, "two gated requests reserved: {usage:?}");
    let request_id = resp.metadata.request_id;
    drop(bridge);

    let restored = persisted_bridge(&dir);
    assert_eq!(
        restored.quota_usage("student-1"),
        usage,
        "quota state must survive the restart"
    );
    // The pre-restart exchange is regenerable — not UnknownRequest.
    let regen = restored.regenerate(request_id, None).unwrap();
    assert!(!regen.text.is_empty());
    assert_eq!(regen.metadata.regen_count, 1);
}

// ---------------------------------------------------------------------
// Compaction: size-keyed trigger, generation GC, restart from snapshot.
// ---------------------------------------------------------------------

#[test]
fn compaction_triggers_on_wal_size_and_gcs_old_generation() {
    let dir = fresh_dir("compact");
    let config = BridgeConfig {
        data_dir: Some(dir.clone()),
        compact_wal_bytes: 2048,
        ..Default::default()
    };
    let bridge =
        Bridge::from_engine(common::bridge().engine().clone(), config.clone()).unwrap();
    for i in 0..64 {
        bridge
            .cache()
            .put_exact(&format!("compact probe number {i}"), "resp");
    }
    assert!(wal_len(&dir, 0) > 2048);
    assert!(bridge.maybe_compact().unwrap(), "threshold crossed");
    assert!(dir.join("snap-1").is_dir());
    assert_eq!(
        std::fs::read_to_string(dir.join("CURRENT")).unwrap().trim(),
        "1"
    );
    assert!(!wal_file(&dir, 0).exists(), "old WAL GC'd");
    assert_eq!(wal_len(&dir, 1), WAL_MAGIC.len() as u64, "fresh WAL");
    assert!(!bridge.maybe_compact().unwrap(), "below threshold again");
    drop(bridge);

    let restored = Bridge::from_engine(common::bridge().engine().clone(), config).unwrap();
    for i in 0..64 {
        assert_eq!(
            restored
                .cache()
                .get_exact(&format!("compact probe number {i}"))
                .as_deref(),
            Some("resp")
        );
    }
    assert_eq!(restored.persistence().unwrap().stats().generation, 1);
}

// ---------------------------------------------------------------------
// Guardrail: with no data dir, nothing touches the filesystem and the
// hot path runs exactly as before (the default for tier-1 and benches).
// ---------------------------------------------------------------------

#[test]
fn no_data_dir_means_no_persistence_machinery() {
    let bridge = oracle_bridge();
    assert!(bridge.persistence().is_none());
    assert!(!bridge.maybe_compact().unwrap());
    assert!(!bridge.compact_persistence().unwrap());
    bridge.cache().put_exact("ephemeral probe", "resp");
    assert_eq!(
        bridge.cache().get_exact("ephemeral probe").as_deref(),
        Some("resp")
    );
}

// ---------------------------------------------------------------------
// Adaptive index tier (PR 4): a cache that migrated to the IVF tier
// snapshots its trained state (LBV3) and a kill-and-restore round-trip
// boots already trained — no k-means on the boot path — serving
// bit-identical raw hits. WAL-tail replay then lands in the restored
// IVF tier's cells.
// ---------------------------------------------------------------------

#[test]
fn migrated_cache_restores_without_retraining() {
    use llmbridge::cache::{CacheObject, SemanticCache};
    use llmbridge::util::corpus;
    use llmbridge::vecdb::adaptive::AdaptiveConfig;

    let dim = 16;
    let mut r = Rng::new(0xADA7);
    let centers: Vec<Vec<f32>> = (0..12)
        .map(|_| (0..dim).map(|_| r.normal() as f32 * 6.0).collect())
        .collect();
    let clustered = |r: &mut Rng| -> Vec<f32> {
        let c = r.choice(&centers).clone();
        corpus::perturbed(r, &c, 0.3)
    };
    // Low threshold so 2400 typed keys are enough to migrate; everything
    // else is the production policy.
    let cfg = AdaptiveConfig {
        migrate_threshold: 1500,
        train_sample: 2048,
        kmeans_iters: 3,
        ..AdaptiveConfig::default()
    };
    let cache = SemanticCache::with_index_config(dim, cfg);
    // Populate via the WAL-replay path (synthetic embeddings, engine-free).
    for i in 0..1200u64 {
        let base = i * 3 + 1;
        let keys = vec![
            (base + 1, CachedType::Prompt, clustered(&mut r)),
            (base + 2, CachedType::Response, clustered(&mut r)),
        ];
        cache
            .apply_logged_put(
                CacheObject {
                    id: base,
                    text: format!("text {i}"),
                    origin: format!("origin {i}"),
                    is_document: false,
                },
                &keys,
            )
            .unwrap();
    }
    assert_eq!(cache.index_stats().tier, "flat");
    assert!(cache.maybe_rebuild_index(), "past the threshold: migrates");
    assert!(!cache.maybe_rebuild_index(), "no churn: second call is a no-op");
    let stats = cache.index_stats();
    assert_eq!(stats.tier, "ivf");
    assert!(stats.trained);
    assert_eq!(stats.rows, 2400);

    // Kill-and-restore through the snapshot (vecdb.bin is LBV3 now).
    let dir = fresh_dir("adaptive_snap");
    cache.snapshot_into(&dir).unwrap();
    let restored = SemanticCache::restore_from_dir(&dir, dim).unwrap();
    // Boots already trained, same geometry — the restore path has no
    // k-means to run, so identical stats prove no retraining happened.
    assert_eq!(restored.index_stats(), stats);
    assert!(
        !restored.maybe_rebuild_index(),
        "freshly restored tier is not drift-due"
    );

    // Raw probes are bit-identical: LBV3 restores the exact posting-list
    // layout, so scores round identically.
    for _ in 0..20 {
        let q: Vec<f32> = (0..dim).map(|_| r.normal() as f32).collect();
        let a = cache.search_raw(&q, 6, f32::MIN);
        let b = restored.search_raw(&q, 6, f32::MIN);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
        }
    }

    // A WAL-tail op replayed on top of the restored snapshot inserts into
    // the live IVF tier (nearest trained cell) and is immediately
    // retrievable at base effort.
    let tail_vec = clustered(&mut r);
    restored
        .apply_logged_put(
            CacheObject {
                id: 9001,
                text: "wal tail".into(),
                origin: "tail".into(),
                is_document: false,
            },
            &[(9002, CachedType::Prompt, tail_vec.clone())],
        )
        .unwrap();
    assert_eq!(restored.index_stats().rows, 2401);
    let hits = restored.search_raw(&tail_vec, 1, f32::MIN);
    assert_eq!(hits[0].id, 9002, "replayed row lands in a probed cell");
}

// ---------------------------------------------------------------------
// Quantized index tier (PR 6): a cache past the quantize threshold
// snapshots its i8 tier as LBV4, a kill-and-restore round-trip boots it
// mapped (metadata parsed eagerly, the code region left to fault in)
// serving bit-identical raw hits, WAL-tail replay still lands in the
// restored tier, and a corrupted LBV4 refuses to boot.
// ---------------------------------------------------------------------

#[test]
fn quantized_cache_restores_lbv4_and_rejects_corruption() {
    use llmbridge::cache::{CacheObject, SemanticCache};
    use llmbridge::util::corpus;
    use llmbridge::vecdb::adaptive::AdaptiveConfig;

    let dim = 16;
    let mut r = Rng::new(0x1B44);
    // 2400 typed keys in 600 tight 4-point clusters — past both
    // thresholds, and balanced so score gaps dwarf i8 rounding noise.
    let vecs: Vec<Vec<f32>> = corpus::balanced_clustered_pairs(0x1B44, 600, 4, dim, 6.0, 0.3)
        .into_iter()
        .map(|(_, v)| v)
        .collect();
    let cfg = AdaptiveConfig {
        migrate_threshold: 1500,
        quantize_threshold: 2000,
        train_sample: 2048,
        kmeans_iters: 3,
        ..AdaptiveConfig::default()
    };
    let cache = SemanticCache::with_index_config(dim, cfg);
    for i in 0..1200usize {
        let base = i as u64 * 3 + 1;
        cache
            .apply_logged_put(
                CacheObject {
                    id: base,
                    text: format!("text {i}"),
                    origin: format!("origin {i}"),
                    is_document: false,
                },
                &[
                    (base + 1, CachedType::Prompt, vecs[2 * i].clone()),
                    (base + 2, CachedType::Response, vecs[2 * i + 1].clone()),
                ],
            )
            .unwrap();
    }
    assert!(cache.maybe_rebuild_index(), "2400 keys cross both thresholds");
    let stats = cache.index_stats();
    assert_eq!(stats.tier, "ivf_i8", "rebuild lands on the quantized tier");
    assert_eq!(stats.rows, 2400);
    assert_eq!(stats.vector_bytes, 2400 * (dim + 4), "i8 codes + one f32 scale per row");

    // Kill-and-restore through the snapshot (vecdb.bin is LBV4 now).
    let dir = fresh_dir("quant_snap");
    cache.snapshot_into(&dir).unwrap();
    let restored = SemanticCache::restore_from_dir(&dir, dim).unwrap();
    assert_eq!(restored.index_stats(), stats, "boots trained: same tier, rows, bytes");

    // Raw probes bit-identical: LBV4 restores codes/scales/centroids
    // exactly, so the coarse i8 order and the f32 rescore both round the
    // same way live and restored.
    for _ in 0..20 {
        let q: Vec<f32> = (0..dim).map(|_| r.normal() as f32).collect();
        let a = cache.search_raw(&q, 6, f32::MIN);
        let b = restored.search_raw(&q, 6, f32::MIN);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
        }
    }

    // A WAL-tail op replayed on the restored snapshot inserts into the
    // live quantized tier. The vector sits far from every trained
    // cluster, so its self-match outscores everything by a wide margin
    // even through i8 rounding.
    let tail_vec: Vec<f32> = (0..dim).map(|_| r.normal() as f32 * 6.0).collect();
    restored
        .apply_logged_put(
            CacheObject {
                id: 9001,
                text: "wal tail".into(),
                origin: "tail".into(),
                is_document: false,
            },
            &[(9002, CachedType::Prompt, tail_vec.clone())],
        )
        .unwrap();
    assert_eq!(restored.index_stats().rows, 2401);
    let hits = restored.search_raw(&tail_vec, 1, f32::MIN);
    assert_eq!(hits[0].id, 9002, "replayed row lands in a probed cell");

    // Corruption: flip one metadata byte (inside the ids region) — the
    // eagerly-verified metadata checksum refuses the snapshot at boot
    // instead of serving wrong ids off a mapped region.
    let vecdb = dir.join("vecdb.bin");
    let mut bytes = std::fs::read(&vecdb).unwrap();
    assert_eq!(&bytes[..4], b"LBV4", "snapshot uses the quantized format");
    bytes[52] ^= 0x01;
    std::fs::write(&vecdb, &bytes).unwrap();
    let err = SemanticCache::restore_from_dir(&dir, dim).unwrap_err();
    assert!(
        err.to_string().contains("checksum"),
        "corrupt LBV4 must fail loudly, got: {err}"
    );
}
