//! Concurrency and batching coverage for the sharded cache and the
//! batched engine RPC path: mixed multi-threaded cache traffic must not
//! deadlock and must land consistent counts, and `embed_batch` must be
//! bit-identical to serial `embed_text`.

mod common;

use llmbridge::cache::GetFilter;

/// N threads doing mixed put_interaction / get / get_exact against one
/// cache: no deadlock, no lost writes, retrievable results.
#[test]
fn cache_concurrent_mixed_ops_no_deadlock() {
    let bridge = common::bridge();
    let objects_before = bridge.cache().len_objects();
    let keys_before = bridge.cache().len_keys();
    let threads = 4;
    let per_thread = 10;
    std::thread::scope(|s| {
        for t in 0..threads {
            let bridge = bridge.clone();
            s.spawn(move || {
                for i in 0..per_thread {
                    let prompt =
                        format!("concurrency thread {t} question {i} about subject {}", i % 3);
                    let response = format!("concurrency answer {t} {i}");
                    bridge
                        .cache()
                        .put_interaction(bridge.generator(), &prompt, &response)
                        .unwrap();
                    bridge.cache().put_exact(&prompt, &response);
                    assert_eq!(
                        bridge.cache().get_exact(&prompt).as_deref(),
                        Some(response.as_str())
                    );
                    let hits = bridge
                        .cache()
                        .get(bridge.generator(), &prompt, &GetFilter::default())
                        .unwrap();
                    assert!(!hits.is_empty(), "semantic lookup starved for {prompt:?}");
                }
            });
        }
    });
    // Each put_interaction adds one object and two keys (prompt+response).
    assert_eq!(
        bridge.cache().len_objects(),
        objects_before + threads * per_thread
    );
    assert_eq!(
        bridge.cache().len_keys(),
        keys_before + 2 * threads * per_thread
    );
}

/// Batched embeds return in input order, coalesce duplicates, and match
/// the single-text path exactly (same executable, same window).
#[test]
fn embed_batch_matches_single_and_coalesces() {
    let bridge = common::bridge();
    let engine = bridge.engine();
    let texts = [
        "alpha beta gamma",
        "delta epsilon zeta",
        "alpha beta gamma", // duplicate of [0]: single-flight slot
    ];
    let batch = engine.embed_batch(&texts).unwrap();
    assert_eq!(batch.len(), 3);
    let single = engine.embed_text("alpha beta gamma").unwrap();
    assert_eq!(batch[0], single);
    assert_eq!(batch[0], batch[2]);
    assert_ne!(batch[0], batch[1]);
    assert_eq!(engine.embed_batch(&[]).unwrap().len(), 0);
}

/// Concurrent embed_text callers exercise the engine's drain-and-coalesce
/// wave loop; identical texts from different threads must agree.
#[test]
fn concurrent_embeds_consistent() {
    let bridge = common::bridge();
    let baseline = bridge.engine().embed_text("shared probe text").unwrap();
    std::thread::scope(|s| {
        for t in 0..8 {
            let bridge = bridge.clone();
            let baseline = baseline.clone();
            s.spawn(move || {
                for i in 0..5 {
                    let shared = bridge.engine().embed_text("shared probe text").unwrap();
                    assert_eq!(shared, baseline);
                    let own = bridge
                        .engine()
                        .embed_text(&format!("private probe {t} {i}"))
                        .unwrap();
                    assert_eq!(own.len(), bridge.engine().embed_dim());
                }
            });
        }
    });
}
