//! REST server integration: real TCP round-trips against the bridge.

mod common;

use std::io::{Read, Write};
use std::net::TcpStream;

use llmbridge::server::Server;
use llmbridge::util::json::Json;

fn http_post(addr: std::net::SocketAddr, path: &str, body: &str) -> (u16, Json) {
    let mut s = TcpStream::connect(addr).unwrap();
    let msg = format!(
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(msg.as_bytes()).unwrap();
    read_response(s)
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> (u16, Json) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
        .unwrap();
    read_response(s)
}

fn read_response(mut s: TcpStream) -> (u16, Json) {
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let status: u16 = buf.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("{}");
    (status, Json::parse(body).unwrap())
}

#[test]
fn full_rest_round_trip() {
    let bridge = common::bridge();
    let server = Server::start(bridge, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr;

    // Health.
    let (code, j) = http_get(addr, "/health");
    assert_eq!(code, 200);
    assert_eq!(j.str_of("status").unwrap(), "ok");

    // A cost-type request.
    let (code, j) = http_post(
        addr,
        "/v1/request",
        r#"{"user":"rest-u1","conversation":"c1","prompt":"hello from http",
            "service_type":{"name":"cost"}}"#,
    );
    assert_eq!(code, 200, "{}", j.to_string());
    assert!(!j.str_of("text").unwrap().is_empty());
    let meta = j.req("metadata").unwrap();
    assert_eq!(meta.str_of("service_type").unwrap(), "cost");
    let rid = meta.str_of("request_id").unwrap();

    // Regenerate it with an explicit better service type.
    let (code, j2) = http_post(
        addr,
        "/v1/regenerate",
        &format!(r#"{{"request_id":"{rid}","service_type":{{"name":"quality"}}}}"#),
    );
    assert_eq!(code, 200, "{}", j2.to_string());
    assert_eq!(
        j2.req("metadata").unwrap().str_of("service_type").unwrap(),
        "quality"
    );

    // Metrics include our request counters.
    let (code, m) = http_get(addr, "/v1/metrics");
    assert_eq!(code, 200);
    assert!(m.req("counters").unwrap().get("requests").is_some());

    // Malformed body -> 400.
    let (code, _) = http_post(addr, "/v1/request", "{not json");
    assert_eq!(code, 400);

    // Unknown route -> 404.
    let (code, _) = http_get(addr, "/nope");
    assert_eq!(code, 404);

    server.stop();
}

#[test]
fn concurrent_clients_same_user_are_serialized() {
    let bridge = common::bridge();
    let server = Server::start(bridge, "127.0.0.1:0", 4).unwrap();
    let addr = server.addr;
    let mut handles = vec![];
    for i in 0..6 {
        handles.push(std::thread::spawn(move || {
            http_post(
                addr,
                "/v1/request",
                &format!(
                    r#"{{"user":"fifo-u","conversation":"c1",
                        "prompt":"concurrent question {i}",
                        "service_type":{{"name":"cost"}}}}"#
                ),
            )
        }));
    }
    for h in handles {
        let (code, _) = h.join().unwrap();
        assert_eq!(code, 200);
    }
    server.stop();
}
