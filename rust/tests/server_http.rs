//! REST server integration: real TCP round-trips against the bridge,
//! exercised on **both** transport paths — the evented epoll loop (the
//! Linux default) and the portable threaded fallback — to pin that they
//! serve identical routes with identical semantics.

mod common;

use common::HttpClient;
use llmbridge::server::{Server, ServerBackend, ServerConfig};
use llmbridge::util::json::Json;

fn http_post(addr: std::net::SocketAddr, path: &str, body: &str) -> (u16, Json) {
    HttpClient::connect(addr).post(path, body)
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> (u16, Json) {
    HttpClient::connect(addr).get(path)
}

fn server_on(backend: ServerBackend, workers: usize) -> Server {
    Server::start_with(
        common::bridge(),
        "127.0.0.1:0",
        ServerConfig {
            workers,
            backend,
            ..ServerConfig::default()
        },
    )
    .unwrap()
}

fn rest_round_trip(server: Server) {
    let addr = server.addr;

    // Health and readiness.
    let (code, j) = http_get(addr, "/health");
    assert_eq!(code, 200);
    assert_eq!(j.str_of("status").unwrap(), "ok");
    let (code, j) = http_get(addr, "/ready");
    assert_eq!(code, 200, "{}", j.to_string());
    assert_eq!(j.str_of("status").unwrap(), "ready");
    assert_eq!(j.str_of("restore").unwrap(), "complete");

    // A cost-type request.
    let (code, j) = http_post(
        addr,
        "/v1/request",
        r#"{"user":"rest-u1","conversation":"c1","prompt":"hello from http",
            "service_type":{"name":"cost"}}"#,
    );
    assert_eq!(code, 200, "{}", j.to_string());
    assert!(!j.str_of("text").unwrap().is_empty());
    let meta = j.req("metadata").unwrap();
    assert_eq!(meta.str_of("service_type").unwrap(), "cost");
    let rid = meta.str_of("request_id").unwrap();

    // Regenerate it with an explicit better service type.
    let (code, j2) = http_post(
        addr,
        "/v1/regenerate",
        &format!(r#"{{"request_id":"{rid}","service_type":{{"name":"quality"}}}}"#),
    );
    assert_eq!(code, 200, "{}", j2.to_string());
    assert_eq!(
        j2.req("metadata").unwrap().str_of("service_type").unwrap(),
        "quality"
    );

    // Metrics include our request counters.
    let (code, m) = http_get(addr, "/v1/metrics");
    assert_eq!(code, 200);
    assert!(m.req("counters").unwrap().get("requests").is_some());

    // Malformed body -> 400.
    let (code, _) = http_post(addr, "/v1/request", "{not json");
    assert_eq!(code, 400);

    // Unknown route -> 404.
    let (code, _) = http_get(addr, "/nope");
    assert_eq!(code, 404);

    server.stop();
}

#[test]
fn full_rest_round_trip_default_backend() {
    rest_round_trip(server_on(ServerBackend::Auto, 2));
}

#[test]
fn full_rest_round_trip_threaded_backend() {
    rest_round_trip(server_on(ServerBackend::Threaded, 2));
}

/// The paper's per-user serialization guarantee (SQS FIFO semantics):
/// concurrent requests from one user all succeed, processed one at a
/// time in queue order.
fn same_user_serialized(server: Server) {
    let addr = server.addr;
    let mut handles = vec![];
    for i in 0..6 {
        handles.push(std::thread::spawn(move || {
            http_post(
                addr,
                "/v1/request",
                &format!(
                    r#"{{"user":"fifo-u","conversation":"c1",
                        "prompt":"concurrent question {i}",
                        "service_type":{{"name":"cost"}}}}"#
                ),
            )
        }));
    }
    for h in handles {
        let (code, j) = h.join().unwrap();
        assert_eq!(code, 200, "{}", j.to_string());
    }
    server.stop();
}

#[test]
fn concurrent_clients_same_user_are_serialized_default_backend() {
    same_user_serialized(server_on(ServerBackend::Auto, 4));
}

#[test]
fn concurrent_clients_same_user_are_serialized_threaded_backend() {
    same_user_serialized(server_on(ServerBackend::Threaded, 4));
}
