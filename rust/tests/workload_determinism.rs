//! Workload determinism: the same seed must produce the *same bytes* of
//! synthetic traffic in two separate OS processes — for the two seed
//! workloads and for every scenario trace in the standing matrix. The
//! open-loop scenario numbers (`BENCH_scenarios.json`) are only
//! comparable across machines and runs because the traffic itself is
//! reproducible; a regression to process-seeded state (map iteration
//! order, ASLR-derived hashes, clocks) would show up here as a
//! fingerprint diff. Same cross-process idiom as
//! `tests/backend_determinism.rs`: drive the real `llmbridge trace`
//! binary via `CARGO_BIN_EXE_llmbridge` and diff stdout byte for byte.

use llmbridge::scenario::{default_matrix, ArrivalProcess, Trace};
use llmbridge::util::fnv1a;

fn run_trace(seed: &str) -> String {
    let exe = env!("CARGO_BIN_EXE_llmbridge");
    let out = std::process::Command::new(exe)
        .args(["trace", "--seed", seed])
        .output()
        .expect("spawn `llmbridge trace`");
    assert!(
        out.status.success(),
        "trace failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).unwrap()
}

#[test]
fn same_seed_same_bytes_across_processes() {
    let first = run_trace("42");
    let second = run_trace("42");
    assert_eq!(first, second, "two processes must print identical fingerprints");

    // One line per workload plus one per matrix scenario.
    let lines: Vec<&str> = first.lines().collect();
    assert!(lines.iter().any(|l| l.starts_with("whatsapp 42 ")), "{first}");
    assert!(lines.iter().any(|l| l.starts_with("classroom 42 ")), "{first}");
    assert!(lines.iter().any(|l| l.starts_with("corpus ")), "{first}");
    for sc in default_matrix() {
        assert!(
            lines
                .iter()
                .any(|l| l.starts_with(&format!("scenario {} ", sc.name))),
            "missing scenario line for {}: {first}",
            sc.name
        );
    }
}

#[test]
fn different_seed_different_trace() {
    // The fingerprints are not constants: a different seed must move the
    // *hash field* of every seeded line (the printed seed is excluded
    // from the comparison; the static corpus hash must stay put).
    let a = run_trace("42");
    let b = run_trace("43");
    let hash = |out: &str, prefix: &str| -> String {
        out.lines()
            .find(|l| l.starts_with(prefix))
            .unwrap_or_else(|| panic!("no line starting with {prefix}"))
            .split_whitespace()
            .last()
            .unwrap()
            .to_string()
    };
    assert_ne!(hash(&a, "whatsapp"), hash(&b, "whatsapp"));
    assert_ne!(hash(&a, "classroom"), hash(&b, "classroom"));
    assert_eq!(hash(&a, "corpus"), hash(&b, "corpus"), "corpus is seed-free");
    // Scenario traces re-seed per name; a new seed moves each fingerprint.
    for sc in default_matrix() {
        let prefix = format!("scenario {} ", sc.name);
        let field = |out: &str| -> String {
            out.lines()
                .find(|l| l.starts_with(&prefix))
                .unwrap_or_else(|| panic!("no line for {}", sc.name))
                .split_whitespace()
                .nth(2)
                .unwrap()
                .to_string()
        };
        assert_ne!(field(&a), field(&b), "scenario {} trace ignored the seed", sc.name);
    }
}

#[test]
fn binary_fingerprint_matches_in_process_generation() {
    // Non-vacuous: this (third) process regenerates one scenario trace
    // with the same parameters the CLI uses and must land on the very
    // fingerprint the binary printed.
    let out = run_trace("42");
    let sc = &default_matrix()[0];
    let trace = Trace::generate(
        42u64 ^ fnv1a(sc.name.as_bytes()),
        &sc.tenants,
        &ArrivalProcess::Poisson { rps: 80.0 },
        std::time::Duration::from_secs(1),
    );
    let expect = format!(
        "scenario {} {:016x} {}",
        sc.name,
        trace.fingerprint,
        trace.events.len()
    );
    assert!(
        out.lines().any(|l| l.starts_with(&expect)),
        "binary output must contain `{expect}`:\n{out}"
    );
}
