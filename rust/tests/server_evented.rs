//! Evented server integration: the epoll readiness loop under adversarial
//! clients (dribblers, pipeliners, oversized frames), connection churn,
//! 1k+ concurrent keep-alive connections, induced overload (admission
//! 429s), and graceful drain. Linux-only — the loop itself is.

#![cfg(target_os = "linux")]

mod common;

use std::io::Read;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use common::HttpClient;
use llmbridge::server::{Server, ServerBackend, ServerConfig};

fn evented_server(config: ServerConfig) -> Server {
    Server::start_with(
        common::bridge(),
        "127.0.0.1:0",
        ServerConfig {
            backend: ServerBackend::Evented,
            ..config
        },
    )
    .unwrap()
}

#[test]
fn dribbled_request_byte_at_a_time_is_served() {
    let server = evented_server(ServerConfig::default());
    let mut c = HttpClient::connect(server.addr);
    for b in b"GET /health HTTP/1.1\r\nHost: x\r\n\r\n" {
        c.send_raw(&[*b]);
    }
    let (code, j) = c.read_response();
    assert_eq!(code, 200);
    assert_eq!(j.str_of("status").unwrap(), "ok");
    server.stop();
}

#[test]
fn pipelined_requests_on_one_keepalive_connection() {
    let server = evented_server(ServerConfig::default());
    let mut c = HttpClient::connect(server.addr);
    // Two requests in a single write: responses must come back in order
    // on the same socket, and the connection must stay usable.
    c.send_raw(
        b"GET /health HTTP/1.1\r\nHost: x\r\n\r\n\
          GET /ready HTTP/1.1\r\nHost: x\r\n\r\n",
    );
    let (code, j) = c.read_response();
    assert_eq!(code, 200);
    assert_eq!(j.str_of("status").unwrap(), "ok");
    let (code, j) = c.read_response();
    assert_eq!(code, 200);
    assert_eq!(j.str_of("status").unwrap(), "ready");
    // Third request on the same connection (keep-alive reuse).
    let (code, _) = c.get("/health");
    assert_eq!(code, 200);
    server.stop();
}

#[test]
fn oversized_head_rejected_with_400_not_a_hung_worker() {
    let server = evented_server(ServerConfig::default());
    let mut c = HttpClient::connect(server.addr);
    c.send_raw(b"GET / HTTP/1.1\r\nX-Pad: ");
    c.send_raw(&vec![b'a'; 70 * 1024]); // > MAX_HEAD_BYTES, no terminator
    let (code, _) = c.read_response();
    assert_eq!(code, 400);
    // The stream is unframeable: the server must close, not hang.
    let mut rest = Vec::new();
    c.stream.read_to_end(&mut rest).unwrap();
    server.stop();
}

#[test]
fn oversized_declared_body_rejected_with_413_before_body_arrives() {
    let server = evented_server(ServerConfig::default());
    let mut c = HttpClient::connect(server.addr);
    // Declare 5 MiB (> MAX_BODY_BYTES) but never send it — the limit
    // must fire on the declaration, not after buffering.
    c.send_raw(b"POST /v1/request HTTP/1.1\r\nContent-Length: 5242880\r\n\r\n");
    let (code, j) = c.read_response();
    assert_eq!(code, 413, "{}", j.to_string());
    server.stop();
}

#[test]
fn connection_open_close_churn_1k() {
    let server = evented_server(ServerConfig {
        workers: 2,
        ..ServerConfig::default()
    });
    for i in 0..1000 {
        let mut c = HttpClient::connect(server.addr);
        let (code, _) = c.get("/health");
        assert_eq!(code, 200, "churn iteration {i}");
        // Dropped here: the loop reaps the connection via RDHUP.
    }
    server.stop();
}

#[test]
fn thousand_concurrent_keepalive_connections() {
    let server = evented_server(ServerConfig {
        workers: 8,
        ..ServerConfig::default()
    });
    const CONNS: usize = 1100; // > the 1024-connection acceptance floor
    let mut clients: Vec<HttpClient> = (0..CONNS)
        .map(|_| HttpClient::connect(server.addr))
        .collect();
    // Two request rounds over the same sockets: every connection is
    // concurrently open, and round two is pure keep-alive reuse.
    for round in 0..2 {
        for (i, c) in clients.iter_mut().enumerate() {
            let (code, _) = c.get("/health");
            assert_eq!(code, 200, "round {round}, conn {i}");
        }
    }
    let (code, m) = HttpClient::connect(server.addr).get("/v1/metrics");
    assert_eq!(code, 200);
    let reuse = m
        .req("counters")
        .unwrap()
        .get("server_keepalive_reuse")
        .and_then(|j| match j {
            llmbridge::util::json::Json::Num(n) => Some(*n as usize),
            _ => None,
        })
        .unwrap_or(0);
    assert!(reuse >= CONNS, "expected ≥{CONNS} keep-alive reuses, saw {reuse}");
    server.stop();
}

#[test]
fn concurrent_same_user_keepalive_connections_all_succeed() {
    // scaling_8v1 shape: 8 connections hammering one user stay
    // serialized by the FIFO substrate and all succeed.
    let server = evented_server(ServerConfig {
        workers: 4,
        ..ServerConfig::default()
    });
    let addr = server.addr;
    let mut handles = vec![];
    for i in 0..8 {
        handles.push(std::thread::spawn(move || {
            let mut c = HttpClient::connect(addr);
            c.post(
                "/v1/request",
                &format!(
                    r#"{{"user":"ka-fifo-u","conversation":"c1",
                        "prompt":"keepalive concurrent {i}",
                        "service_type":{{"name":"cost"}}}}"#
                ),
            )
        }));
    }
    for h in handles {
        let (code, j) = h.join().unwrap();
        assert_eq!(code, 200, "{}", j.to_string());
    }
    server.stop();
}

#[test]
fn overload_sheds_admission_429_while_health_stays_up() {
    // One worker, watermark 1: the first dispatched request saturates
    // the server; the concurrent rest must shed with admission 429s —
    // never hang, never touch the bridge.
    let server = evented_server(ServerConfig {
        workers: 1,
        shed_watermark: 1,
        ..ServerConfig::default()
    });
    let addr = server.addr;
    const CLIENTS: usize = 64;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let ok = Arc::new(AtomicUsize::new(0));
    let shed = Arc::new(AtomicUsize::new(0));
    let mut handles = vec![];
    for i in 0..CLIENTS {
        let barrier = barrier.clone();
        let ok = ok.clone();
        let shed = shed.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = HttpClient::connect(addr);
            barrier.wait();
            let (code, j) = c.post(
                "/v1/request",
                &format!(
                    r#"{{"user":"ov-u{i}","conversation":"c1",
                        "prompt":"overload probe {i}",
                        "service_type":{{"name":"cost"}}}}"#
                ),
            );
            match code {
                200 => {
                    ok.fetch_add(1, Ordering::Relaxed);
                }
                429 => {
                    // Admission shed, not a user quota 429.
                    assert_eq!(j.str_of("reason").unwrap(), "admission");
                    shed.fetch_add(1, Ordering::Relaxed);
                    // Shedding is per-request: the keep-alive connection
                    // survives and the probe route still answers.
                    let (hcode, _) = c.get("/health");
                    assert_eq!(hcode, 200, "probe must bypass admission control");
                }
                other => panic!("unexpected status {other}: {}", j.to_string()),
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let (ok, shed) = (ok.load(Ordering::Relaxed), shed.load(Ordering::Relaxed));
    assert_eq!(ok + shed, CLIENTS);
    assert!(ok >= 1, "at least the first request must be served");
    assert!(shed >= 1, "watermark 1 under {CLIENTS} concurrent clients must shed");
    // Queue depth stayed bounded: the shed counter surfaced in telemetry.
    let (code, m) = HttpClient::connect(addr).get("/v1/metrics");
    assert_eq!(code, 200);
    assert!(
        m.req("counters").unwrap().get("server_shed_admission").is_some(),
        "shed counter must surface in /v1/metrics"
    );
    server.stop();
}

#[test]
fn max_conns_ceiling_sheds_new_connections() {
    let server = evented_server(ServerConfig {
        max_conns: 2,
        ..ServerConfig::default()
    });
    // Fill both slots (a round-trip proves each is registered).
    let mut c1 = HttpClient::connect(server.addr);
    assert_eq!(c1.get("/health").0, 200);
    let mut c2 = HttpClient::connect(server.addr);
    assert_eq!(c2.get("/health").0, 200);
    // The third connection is answered 429 at accept and closed.
    let mut c3 = HttpClient::connect(server.addr);
    let (code, j) = c3.read_response();
    assert_eq!(code, 429);
    assert_eq!(j.str_of("reason").unwrap(), "admission");
    let mut rest = Vec::new();
    c3.stream.read_to_end(&mut rest).unwrap();
    // Existing connections are unaffected.
    assert_eq!(c1.get("/health").0, 200);
    server.stop();
}

#[test]
fn graceful_stop_drains_inflight_and_refuses_new_connections() {
    let server = evented_server(ServerConfig {
        workers: 2,
        drain_deadline: Duration::from_secs(5),
        ..ServerConfig::default()
    });
    let addr = server.addr;
    // Put a real request in flight, then stop while it may still be
    // dispatched: drain must deliver its response before shutdown.
    let mut c = HttpClient::connect(addr);
    c.send_raw(
        format!(
            "POST /v1/request HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            r#"{"user":"drain-u","conversation":"c1","prompt":"drain me","service_type":{"name":"cost"}}"#.len(),
            r#"{"user":"drain-u","conversation":"c1","prompt":"drain me","service_type":{"name":"cost"}}"#
        )
        .as_bytes(),
    );
    std::thread::sleep(Duration::from_millis(150)); // let the loop dispatch it
    let t0 = Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "stop() must respect the drain deadline"
    );
    let (code, j) = c.read_response();
    assert_eq!(code, 200, "in-flight request must drain: {}", j.to_string());
    // The listener is gone: new connections are refused.
    assert!(std::net::TcpStream::connect(addr).is_err());
}

#[test]
fn ready_probe_reports_ready_then_unreachable_after_stop() {
    let server = evented_server(ServerConfig::default());
    let (code, j) = HttpClient::connect(server.addr).get("/ready");
    assert_eq!(code, 200);
    assert_eq!(j.str_of("status").unwrap(), "ready");
    assert!(server.ready());
    let addr = server.addr;
    server.stop();
    assert!(std::net::TcpStream::connect(addr).is_err());
}
