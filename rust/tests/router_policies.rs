//! Router parity tests: every `ServiceType` lowers to the expected policy
//! and routes to the same model the pre-refactor `pick_model` /
//! `cascade_models` / `escalate` code chose, across both generations and
//! the regeneration-escalation path. Pure pool math — no engine needed.

use llmbridge::api::{CachePolicy, ServiceType};
use llmbridge::context::Filter;
use llmbridge::models::pricing::{Generation, ModelId};
use llmbridge::router::{
    cascade_models, escalate, lower, RoutePlan, RoutingPolicy,
};

fn single_pick(st: &ServiceType, generation: Generation, requested: Option<&str>) -> ModelId {
    let policy = lower(st, generation, 0);
    match policy.routing.route(requested).unwrap() {
        RoutePlan::Single { model, .. } => model,
        other => panic!("{st:?} routed to {other:?}, expected a single model"),
    }
}

#[test]
fn every_service_type_routes_like_the_monolith() {
    use Generation::{New, Old};
    // (service type, generation, requested model param, pre-refactor pick)
    let table: Vec<(ServiceType, Generation, Option<&str>, ModelId)> = vec![
        (
            ServiceType::Fixed {
                model: ModelId::Llama38b,
                cache: CachePolicy::Skip,
                context_k: 0,
            },
            New,
            None,
            ModelId::Llama38b,
        ),
        // §3.2 quality: "the most expensive model".
        (ServiceType::Quality, Old, None, ModelId::Gpt4),
        (ServiceType::Quality, New, None, ModelId::SonarHugeOnline),
        // §3.2 cost: "the cheapest model" (first of the 0.10 price tie).
        (ServiceType::Cost, Old, None, ModelId::Gpt35Turbo),
        (ServiceType::Cost, New, None, ModelId::Phi3Mini),
        // smart_context answers with the generation's flagship.
        (
            ServiceType::SmartContext {
                k: 5,
                model: ModelId::Claude3Haiku,
            },
            Old,
            None,
            ModelId::Gpt4,
        ),
        (
            ServiceType::SmartContext {
                k: 5,
                model: ModelId::Claude3Haiku,
            },
            New,
            None,
            ModelId::Gpt4o,
        ),
        (
            ServiceType::SmartCache {
                model: ModelId::Phi3Mini,
            },
            New,
            None,
            ModelId::Phi3Mini,
        ),
        // §5.1 latency-first hardcoded Claude Haiku; the latency-class
        // policy re-derives it from the pool (decode-budget floor, then
        // capability).
        (ServiceType::LatencyFirst, New, None, ModelId::Claude3Haiku),
        (ServiceType::LatencyFirst, Old, None, ModelId::Claude3Haiku),
        // §5.2 usage_based: requested-if-allowed, else fallback.
        (
            ServiceType::UsageBased {
                allowed: vec![ModelId::Gpt4oMini, ModelId::Phi3Mini],
                fallback: ModelId::Gpt4oMini,
            },
            New,
            Some("phi-3-mini"),
            ModelId::Phi3Mini,
        ),
        (
            ServiceType::UsageBased {
                allowed: vec![ModelId::Gpt4oMini, ModelId::Phi3Mini],
                fallback: ModelId::Gpt4oMini,
            },
            New,
            Some("gpt-4"),
            ModelId::Gpt4oMini,
        ),
        (
            ServiceType::UsageBased {
                allowed: vec![ModelId::Gpt4oMini, ModelId::Phi3Mini],
                fallback: ModelId::Gpt4oMini,
            },
            New,
            None,
            ModelId::Gpt4oMini,
        ),
    ];
    for (st, generation, requested, expected) in &table {
        assert_eq!(
            single_pick(st, *generation, *requested),
            *expected,
            "{st:?} / {generation:?} / requested={requested:?}"
        );
    }
}

#[test]
fn model_selector_lowers_to_the_cascade_models_resolution() {
    for generation in [Generation::Old, Generation::New] {
        let st = ServiceType::ModelSelector {
            threshold: 8.0,
            m1: None,
            m2: None,
            verifier: None,
        };
        let plan = lower(&st, generation, 0).routing.route(None).unwrap();
        let (m1, m2, verifier) = cascade_models(generation, None, None, None).unwrap();
        assert_eq!(
            plan,
            RoutePlan::Cascade {
                m1,
                m2,
                verifier,
                threshold: 8.0
            },
            "{generation:?}"
        );
    }
    // §5.3 pinned config passes through untouched.
    let st = ServiceType::ModelSelector {
        threshold: 7.5,
        m1: Some(ModelId::Gpt35Turbo),
        m2: Some(ModelId::Gpt4),
        verifier: Some(ModelId::Claude3Opus),
    };
    match lower(&st, Generation::Old, 0).routing.route(None).unwrap() {
        RoutePlan::Cascade {
            m1, m2, verifier, threshold,
        } => {
            assert_eq!(
                (m1, m2, verifier, threshold),
                (ModelId::Gpt35Turbo, ModelId::Gpt4, ModelId::Claude3Opus, 7.5)
            );
        }
        other => panic!("expected cascade, got {other:?}"),
    }
}

#[test]
fn lowering_shapes_match_the_monolith_contract() {
    let g = Generation::New;
    // quality: all context; cost: none; model_selector: last-5 (§3.2);
    // usage_based: last-3 + quota; latency_first: last-1.
    assert_eq!(lower(&ServiceType::Quality, g, 0).context, Filter::All);
    assert_eq!(lower(&ServiceType::Cost, g, 0).context, Filter::None);
    let ms = lower(&ServiceType::default(), g, 0);
    assert_eq!(ms.context, Filter::LastK(5));
    assert!(!ms.quota);
    let ub = lower(
        &ServiceType::UsageBased {
            allowed: vec![ModelId::Phi3Mini],
            fallback: ModelId::Phi3Mini,
        },
        g,
        0,
    );
    assert_eq!(ub.context, Filter::LastK(3));
    assert!(ub.quota);
    assert_eq!(lower(&ServiceType::LatencyFirst, g, 0).context, Filter::LastK(1));
    // smart_context: delegated filter normally, plain last-k on regen.
    let sc = ServiceType::SmartContext {
        k: 4,
        model: ModelId::Claude3Haiku,
    };
    assert_eq!(
        lower(&sc, g, 0).context,
        Filter::smart_last_k(4, ModelId::Claude3Haiku)
    );
    assert_eq!(lower(&sc, g, 1).context, Filter::LastK(4));
    // Every type except Fixed{cache: Skip} consults the exact cache.
    assert!(lower(&ServiceType::Quality, g, 0).cache.exact);
    assert!(lower(&ServiceType::LatencyFirst, g, 0).cache.exact);
}

#[test]
fn regen_escalation_matches_the_monolith() {
    use Generation::{New, Old};
    // Same-type regeneration nudges (§3.2/§3.3), old and new generations.
    let cases: Vec<(ServiceType, Generation, ServiceType)> = vec![
        (
            ServiceType::ModelSelector {
                threshold: 8.0,
                m1: None,
                m2: Some(ModelId::Gpt4),
                verifier: None,
            },
            Old,
            ServiceType::Fixed {
                model: ModelId::Gpt4,
                cache: CachePolicy::Skip,
                context_k: 5,
            },
        ),
        (
            ServiceType::ModelSelector {
                threshold: 8.0,
                m1: None,
                m2: None,
                verifier: None,
            },
            New,
            ServiceType::Fixed {
                model: ModelId::Gpt4o,
                cache: CachePolicy::Skip,
                context_k: 5,
            },
        ),
        (
            ServiceType::SmartContext {
                k: 1,
                model: ModelId::Claude3Haiku,
            },
            New,
            ServiceType::Fixed {
                model: ModelId::Gpt4o,
                cache: CachePolicy::Skip,
                context_k: 5,
            },
        ),
        (
            ServiceType::SmartContext {
                k: 7,
                model: ModelId::Claude3Haiku,
            },
            Old,
            ServiceType::Fixed {
                model: ModelId::Gpt4,
                cache: CachePolicy::Skip,
                context_k: 7,
            },
        ),
        (
            ServiceType::SmartCache {
                model: ModelId::Phi3Mini,
            },
            New,
            ServiceType::default(),
        ),
        (ServiceType::Cost, New, ServiceType::Quality),
        (ServiceType::Cost, Old, ServiceType::Quality),
        (
            ServiceType::LatencyFirst,
            New,
            ServiceType::Fixed {
                model: ModelId::Gpt4o,
                cache: CachePolicy::Skip,
                context_k: 5,
            },
        ),
        // Types with no escalation rule pass through unchanged.
        (ServiceType::Quality, New, ServiceType::Quality),
        (
            ServiceType::UsageBased {
                allowed: vec![ModelId::Phi3Mini],
                fallback: ModelId::Phi3Mini,
            },
            New,
            ServiceType::UsageBased {
                allowed: vec![ModelId::Phi3Mini],
                fallback: ModelId::Phi3Mini,
            },
        ),
    ];
    for (st, generation, expected) in &cases {
        assert_eq!(
            escalate(st, *generation),
            *expected,
            "{st:?} / {generation:?}"
        );
    }
}

#[test]
fn new_service_type_is_one_lowering_entry() {
    // The Budget type exists only in api + router — the coordinator never
    // names it. Its policy must still route sensibly.
    let st = ServiceType::Budget {
        max_usd_per_mtok_in: 1.0,
    };
    let p = lower(&st, Generation::New, 0);
    assert!(matches!(p.routing, RoutingPolicy::BudgetCap { .. }));
    assert_eq!(
        single_pick(&st, Generation::New, None),
        ModelId::Gemini20Flash
    );
    // Its regen nudge relaxes the ceiling entirely.
    assert_eq!(escalate(&st, Generation::New), ServiceType::Quality);
}
