//! Conversation history persisted in the KV store (the paper keeps it in
//! DynamoDB). A message is a prompt-response pair (§3.4).

use anyhow::Result;

use crate::kvstore::KvStore;
use crate::util::json::Json;

/// One conversation turn.
#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    pub prompt: String,
    pub response: String,
    /// Which pool model produced the response (cross-model context effects,
    /// §5.1 "in-context learning" discussion).
    pub model: String,
    /// Response carried grounded citations (the Gemini hallucination-
    /// contagion anecdote in §5.1).
    pub grounded_citations: bool,
    /// Logical timestamp (message index within the conversation).
    pub seq: u64,
}

impl Message {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prompt", Json::str(self.prompt.clone())),
            ("response", Json::str(self.response.clone())),
            ("model", Json::str(self.model.clone())),
            ("grounded_citations", Json::Bool(self.grounded_citations)),
            ("seq", Json::num(self.seq as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Message> {
        Ok(Message {
            prompt: j.str_of("prompt")?,
            response: j.str_of("response")?,
            model: j.str_of("model")?,
            grounded_citations: j
                .get("grounded_citations")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            seq: j.f64_of("seq")? as u64,
        })
    }

    /// Serialized form included in an LLM input.
    pub fn render(&self) -> String {
        format!("user: {}\nassistant: {}", self.prompt, self.response)
    }
}

/// History store over the KV substrate, keyed `hist:{user}:{conversation}`.
pub struct HistoryStore<'a> {
    kv: &'a KvStore,
}

impl<'a> HistoryStore<'a> {
    pub fn new(kv: &'a KvStore) -> HistoryStore<'a> {
        HistoryStore { kv }
    }

    fn key(user: &str, conversation: &str) -> String {
        format!("hist:{user}:{conversation}")
    }

    pub fn get(&self, user: &str, conversation: &str) -> Vec<Message> {
        self.kv
            .get(&Self::key(user, conversation))
            .and_then(|j| {
                j.as_arr().map(|arr| {
                    arr.iter()
                        .filter_map(|m| Message::from_json(m).ok())
                        .collect()
                })
            })
            .unwrap_or_default()
    }

    pub fn append(&self, user: &str, conversation: &str, mut msg: Message) {
        self.kv.update(&Self::key(user, conversation), |old| {
            let mut arr = old
                .and_then(|j| j.as_arr().map(|a| a.to_vec()))
                .unwrap_or_default();
            msg.seq = arr.len() as u64;
            arr.push(msg.to_json());
            Json::Arr(arr)
        });
    }

    /// Replace the most recent message (regeneration, §5.1: "the initial
    /// response is removed from the context").
    pub fn replace_last(&self, user: &str, conversation: &str, msg: Message) {
        self.kv.update(&Self::key(user, conversation), |old| {
            let mut arr = old
                .and_then(|j| j.as_arr().map(|a| a.to_vec()))
                .unwrap_or_default();
            let seq = arr.len().saturating_sub(1) as u64;
            let mut msg = msg.clone();
            msg.seq = seq;
            if arr.is_empty() {
                arr.push(msg.to_json());
            } else {
                let last = arr.len() - 1;
                arr[last] = msg.to_json();
            }
            Json::Arr(arr)
        });
    }

    pub fn len(&self, user: &str, conversation: &str) -> usize {
        self.get(user, conversation).len()
    }

    pub fn clear(&self, user: &str, conversation: &str) {
        self.kv.delete(&Self::key(user, conversation));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(p: &str, r: &str) -> Message {
        Message {
            prompt: p.into(),
            response: r.into(),
            model: "gpt-4o-mini".into(),
            grounded_citations: false,
            seq: 0,
        }
    }

    #[test]
    fn append_and_get_ordered() {
        let kv = KvStore::new();
        let h = HistoryStore::new(&kv);
        h.append("u", "c", msg("q1", "a1"));
        h.append("u", "c", msg("q2", "a2"));
        let msgs = h.get("u", "c");
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].prompt, "q1");
        assert_eq!(msgs[1].seq, 1);
    }

    #[test]
    fn conversations_isolated() {
        let kv = KvStore::new();
        let h = HistoryStore::new(&kv);
        h.append("u", "c1", msg("q1", "a1"));
        h.append("u", "c2", msg("q2", "a2"));
        assert_eq!(h.len("u", "c1"), 1);
        assert_eq!(h.get("u", "c2")[0].prompt, "q2");
    }

    #[test]
    fn replace_last_for_regeneration() {
        let kv = KvStore::new();
        let h = HistoryStore::new(&kv);
        h.append("u", "c", msg("q1", "first answer"));
        h.replace_last("u", "c", msg("q1", "better answer"));
        let msgs = h.get("u", "c");
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].response, "better answer");
    }

    #[test]
    fn message_json_roundtrip() {
        let m = msg("hello \"world\"", "line\nbreak");
        let back = Message::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn render_format() {
        assert_eq!(msg("q", "a").render(), "user: q\nassistant: a");
    }
}
