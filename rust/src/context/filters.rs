//! The context filter grammar of Table 3.
//!
//! `Filter([Message], prompt) -> [Message]` — each filter narrows which
//! history messages ride along with the prompt. Filters compose:
//!
//! * `Pipeline([f1, f2])` — apply f2 to f1's output
//!   (Table 3 row 2: `[LastK(5), SmartContext]`).
//! * `Union([a, b])` — union of both selections
//!   (Table 3 row 3: `[[LastK(4), SmartContext], LastK(1)]` — always keep
//!   one message even if SmartContext says none).
//!
//! `SmartContext` and `Summarize` delegate to a low-cost LLM: those calls
//! are *real* pool completions (cost + latency measured), while the
//! correctness of the delegated decision follows the calibrated classifier
//! model (DESIGN.md §Substitutions).

use anyhow::Result;

use super::history::Message;
use crate::models::generator::{Completion, Generator};
use crate::models::pricing::ModelId;
use crate::models::quality::{classify, QueryTraits};
use crate::vecdb::Metric;

/// Execution context shared by filters.
pub struct FilterCtx<'a> {
    pub generator: &'a Generator,
    pub traits: &'a QueryTraits,
}

/// Outcome of running a filter tree.
#[derive(Debug, Default)]
pub struct Selection {
    /// Indices into the original message slice, ascending.
    pub indices: Vec<usize>,
    /// A synthetic replacement message (Summarize).
    pub synthetic: Option<Message>,
    /// Delegated LLM calls made while filtering (billed to the request).
    pub llm_calls: Vec<Completion>,
    /// SmartContext explicitly decided "no context needed".
    pub decided_no_context: bool,
}

impl Selection {
    /// Materialize the selected messages.
    pub fn messages(&self, all: &[Message]) -> Vec<Message> {
        if let Some(s) = &self.synthetic {
            return vec![s.clone()];
        }
        self.indices.iter().map(|&i| all[i].clone()).collect()
    }

    /// Context sufficiency for the quality model: 1.0 when the immediately
    /// preceding turn is present (what anaphoric follow-ups need), 0.5 when
    /// only older turns are, 0.8 for a summary, 0 for nothing.
    pub fn sufficiency(&self, total: usize) -> f64 {
        if self.synthetic.is_some() {
            return 0.8;
        }
        if total == 0 {
            return 1.0; // nothing to miss
        }
        if self.indices.contains(&(total - 1)) {
            1.0
        } else if !self.indices.is_empty() {
            0.5
        } else {
            0.0
        }
    }
}

/// The filter grammar (Table 3).
#[derive(Clone, Debug, PartialEq)]
pub enum Filter {
    /// All history (window packing happens downstream).
    All,
    /// No history.
    None,
    /// The k most recent messages.
    LastK(usize),
    /// LLM decides whether context is needed at all (§3.4). Invoked twice;
    /// context is dropped only if *both* calls deem the prompt standalone
    /// (cuts false positives).
    SmartContext { model: ModelId },
    /// Messages with embedding similarity > threshold to the prompt,
    /// most-similar first, at most `max`.
    Similar { threshold: f64, max: usize },
    /// LLM compresses the selected history into one synthetic message.
    Summarize { model: ModelId },
    /// f_{i+1} applied to f_i's output.
    Pipeline(Vec<Filter>),
    /// Union of selections (dedup, ascending order).
    Union(Vec<Filter>),
}

impl Filter {
    /// Table 3 row 2: `[LastK(k), SmartContext]`.
    pub fn smart_last_k(k: usize, model: ModelId) -> Filter {
        Filter::Pipeline(vec![Filter::LastK(k), Filter::SmartContext { model }])
    }

    /// Table 3 row 3: `[[LastK(k-1), SmartContext], LastK(1)]`.
    pub fn smart_with_floor(k: usize, model: ModelId) -> Filter {
        Filter::Union(vec![
            Filter::smart_last_k(k.saturating_sub(1), model),
            Filter::LastK(1),
        ])
    }

    pub fn apply(
        &self,
        msgs: &[Message],
        prompt: &str,
        cx: &FilterCtx,
    ) -> Result<Selection> {
        self.apply_to(&(0..msgs.len()).collect::<Vec<_>>(), msgs, prompt, cx)
    }

    fn apply_to(
        &self,
        current: &[usize],
        all: &[Message],
        prompt: &str,
        cx: &FilterCtx,
    ) -> Result<Selection> {
        match self {
            Filter::All => Ok(Selection {
                indices: current.to_vec(),
                ..Default::default()
            }),
            Filter::None => Ok(Selection::default()),
            Filter::LastK(k) => {
                let start = current.len().saturating_sub(*k);
                Ok(Selection {
                    indices: current[start..].to_vec(),
                    ..Default::default()
                })
            }
            Filter::SmartContext { model } => {
                if current.is_empty() {
                    return Ok(Selection::default());
                }
                // Two real context-LLM calls (kept short: label-style
                // output), per §3.4's double-check protocol.
                let mut calls = Vec::new();
                let last = &all[*current.last().unwrap()];
                let classify_input = format!(
                    "does this prompt need the previous conversation? \
                     previous: {} current: {}",
                    last.prompt, prompt
                );
                let cap = model.spec().capability;
                let mut votes_standalone = 0;
                let truth_standalone = !cx.traits.requires_context;
                for attempt in 0..2u32 {
                    calls.push(cx.generator.classify_call(*model, &classify_input)?);
                    if classify(truth_standalone, cap, &cx.traits.id, attempt) {
                        votes_standalone += 1;
                    }
                }
                if votes_standalone == 2 {
                    Ok(Selection {
                        llm_calls: calls,
                        decided_no_context: true,
                        ..Default::default()
                    })
                } else {
                    Ok(Selection {
                        indices: current.to_vec(),
                        llm_calls: calls,
                        ..Default::default()
                    })
                }
            }
            Filter::Similar { threshold, max } => {
                if current.is_empty() {
                    return Ok(Selection::default());
                }
                let engine = cx.generator.engine();
                let q = engine.embed_text(prompt)?;
                let mut scored: Vec<(usize, f32)> = Vec::new();
                for &i in current {
                    let e = engine.embed_text(&all[i].prompt)?;
                    let s = Metric::Cosine.score(&q, &e);
                    if s as f64 > *threshold {
                        scored.push((i, s));
                    }
                }
                scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                scored.truncate(*max);
                let mut indices: Vec<usize> = scored.into_iter().map(|(i, _)| i).collect();
                indices.sort_unstable();
                Ok(Selection {
                    indices,
                    ..Default::default()
                })
            }
            Filter::Summarize { model } => {
                if current.is_empty() {
                    return Ok(Selection::default());
                }
                let joined: String = current
                    .iter()
                    .map(|&i| all[i].render())
                    .collect::<Vec<_>>()
                    .join("\n");
                let call = cx.generator.generate(
                    *model,
                    &format!("summarize this conversation briefly:\n{joined}"),
                    Some(24),
                )?;
                // The synthetic summary keeps head words of each turn so
                // downstream lexical signals (embeddings) survive.
                let gist: String = current
                    .iter()
                    .flat_map(|&i| {
                        crate::runtime::tokenizer::words(&all[i].prompt)
                            .into_iter()
                            .take(4)
                    })
                    .collect::<Vec<_>>()
                    .join(" ");
                let synthetic = Message {
                    prompt: "summary of earlier conversation".to_string(),
                    response: format!("{gist} {}", call.text),
                    model: model.as_str().to_string(),
                    grounded_citations: false,
                    seq: all.len() as u64,
                };
                Ok(Selection {
                    indices: current.to_vec(),
                    synthetic: Some(synthetic),
                    llm_calls: vec![call],
                    ..Default::default()
                })
            }
            Filter::Pipeline(stages) => {
                let mut sel = Selection {
                    indices: current.to_vec(),
                    ..Default::default()
                };
                for stage in stages {
                    let mut next = stage.apply_to(&sel.indices, all, prompt, cx)?;
                    next.llm_calls = {
                        let mut calls = std::mem::take(&mut sel.llm_calls);
                        calls.extend(next.llm_calls);
                        calls
                    };
                    next.decided_no_context |= sel.decided_no_context;
                    if next.synthetic.is_none() {
                        next.synthetic = sel.synthetic.take();
                    }
                    sel = next;
                }
                Ok(sel)
            }
            Filter::Union(branches) => {
                let mut indices: Vec<usize> = Vec::new();
                let mut calls = Vec::new();
                let mut synthetic = None;
                let mut all_decided_none = !branches.is_empty();
                for b in branches {
                    let s = b.apply_to(current, all, prompt, cx)?;
                    for i in s.indices {
                        if !indices.contains(&i) {
                            indices.push(i);
                        }
                    }
                    calls.extend(s.llm_calls);
                    all_decided_none &= s.decided_no_context;
                    if synthetic.is_none() {
                        synthetic = s.synthetic;
                    }
                }
                indices.sort_unstable();
                Ok(Selection {
                    indices,
                    synthetic,
                    llm_calls: calls,
                    decided_no_context: all_decided_none,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msgs(n: usize) -> Vec<Message> {
        (0..n)
            .map(|i| Message {
                prompt: format!("question {i}"),
                response: format!("answer {i}"),
                model: "m".into(),
                grounded_citations: false,
                seq: i as u64,
            })
            .collect()
    }

    // Engine-free filters can be tested by constructing Selection directly
    // through apply_to via a FilterCtx with a dangling generator is not
    // possible; instead pure filters are tested through a tiny harness that
    // never touches the generator.
    fn pure_apply(f: &Filter, n: usize) -> Selection {
        // Safety: the filters under test (LastK/All/None/Pipeline/Union of
        // those) never dereference cx.generator.
        let all = msgs(n);
        let indices: Vec<usize> = (0..n).collect();
        pure_apply_to(f, &indices, &all)
    }

    fn pure_apply_to(f: &Filter, current: &[usize], all: &[Message]) -> Selection {
        match f {
            Filter::All => Selection {
                indices: current.to_vec(),
                ..Default::default()
            },
            Filter::None => Selection::default(),
            Filter::LastK(k) => {
                let start = current.len().saturating_sub(*k);
                Selection {
                    indices: current[start..].to_vec(),
                    ..Default::default()
                }
            }
            Filter::Pipeline(stages) => {
                let mut sel = Selection {
                    indices: current.to_vec(),
                    ..Default::default()
                };
                for s in stages {
                    sel = pure_apply_to(s, &sel.indices, all);
                }
                sel
            }
            Filter::Union(branches) => {
                let mut indices = Vec::new();
                for b in branches {
                    for i in pure_apply_to(b, current, all).indices {
                        if !indices.contains(&i) {
                            indices.push(i);
                        }
                    }
                }
                indices.sort_unstable();
                Selection {
                    indices,
                    ..Default::default()
                }
            }
            _ => unreachable!("pure harness only covers engine-free filters"),
        }
    }

    #[test]
    fn last_k_takes_tail() {
        let s = pure_apply(&Filter::LastK(3), 10);
        assert_eq!(s.indices, vec![7, 8, 9]);
        let s = pure_apply(&Filter::LastK(20), 5);
        assert_eq!(s.indices, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn pipeline_composes() {
        let f = Filter::Pipeline(vec![Filter::LastK(5), Filter::LastK(2)]);
        let s = pure_apply(&f, 10);
        assert_eq!(s.indices, vec![8, 9]);
    }

    #[test]
    fn union_dedups_and_sorts() {
        let f = Filter::Union(vec![Filter::LastK(1), Filter::LastK(3)]);
        let s = pure_apply(&f, 10);
        assert_eq!(s.indices, vec![7, 8, 9]);
    }

    #[test]
    fn sufficiency_levels() {
        let sel = Selection {
            indices: vec![9],
            ..Default::default()
        };
        assert_eq!(sel.sufficiency(10), 1.0);
        let sel = Selection {
            indices: vec![0],
            ..Default::default()
        };
        assert_eq!(sel.sufficiency(10), 0.5);
        let sel = Selection::default();
        assert_eq!(sel.sufficiency(10), 0.0);
        assert_eq!(sel.sufficiency(0), 1.0);
    }

    #[test]
    fn table3_constructors() {
        assert_eq!(
            Filter::smart_last_k(5, ModelId::Claude3Haiku),
            Filter::Pipeline(vec![
                Filter::LastK(5),
                Filter::SmartContext {
                    model: ModelId::Claude3Haiku
                }
            ])
        );
        // smart_with_floor always yields at least the most recent message.
        let f = Filter::smart_with_floor(5, ModelId::Claude3Haiku);
        if let Filter::Union(branches) = &f {
            assert_eq!(branches.len(), 2);
            assert_eq!(branches[1], Filter::LastK(1));
        } else {
            panic!("expected union");
        }
    }

    #[test]
    fn selection_messages_materialize() {
        let all = msgs(4);
        let sel = Selection {
            indices: vec![1, 3],
            ..Default::default()
        };
        let picked = sel.messages(&all);
        assert_eq!(picked.len(), 2);
        assert_eq!(picked[1].prompt, "question 3");
    }
}
