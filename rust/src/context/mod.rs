//! Context manager (paper §3.4): tracks conversation history and selects
//! which past messages accompany each prompt via a composable filter
//! grammar (Table 3).
//!
//! Keeping context in the proxy lets LLMBridge (a) optimize exactly what
//! context is sent — the LLM analog of HTTP compression — and (b) support
//! iterative regeneration without the app resending context.

pub mod filters;
pub mod history;

pub use filters::{Filter, FilterCtx, Selection};
pub use history::{HistoryStore, Message};
