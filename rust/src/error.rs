//! Typed request-path errors.
//!
//! The proxy used to surface every failure as a stringly `anyhow::Error`,
//! and the REST layer guessed HTTP status codes by substring-matching the
//! message ("quota" → 429). `BridgeError` replaces that: each variant
//! carries exactly what the caller needs and maps to one status code, so
//! new failure modes get a status by construction, not by grep.

use std::fmt;

/// Everything `Bridge::handle` / `Bridge::regenerate` can fail with.
#[derive(Debug)]
pub enum BridgeError {
    /// The per-user quota gate rejected the request (§5.2 classroom caps).
    QuotaExceeded { user: String },
    /// `regenerate` was asked about an exchange the proxy never served.
    UnknownRequest(u64),
    /// The caller sent something unparseable or unknown (bad JSON, unknown
    /// model id, unknown service type).
    BadRequest(String),
    /// Engine / runtime failure — nothing the caller did wrong.
    Internal(anyhow::Error),
    /// Durable-state failure: snapshot/WAL corruption detected at boot or
    /// compaction (torn *tails* are tolerated and never reach here; this
    /// is interior corruption or an unreadable data dir).
    Persist(String),
}

impl BridgeError {
    /// The HTTP status the REST layer serves for this error.
    pub fn http_status(&self) -> u16 {
        match self {
            BridgeError::QuotaExceeded { .. } => 429,
            BridgeError::UnknownRequest(_) => 404,
            BridgeError::BadRequest(_) => 400,
            BridgeError::Internal(_) => 500,
            BridgeError::Persist(_) => 500,
        }
    }

    pub fn bad_request(msg: impl Into<String>) -> BridgeError {
        BridgeError::BadRequest(msg.into())
    }
}

impl fmt::Display for BridgeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BridgeError::QuotaExceeded { user } => {
                write!(f, "quota exceeded for user {user}")
            }
            BridgeError::UnknownRequest(id) => write!(f, "unknown request id {id:x}"),
            BridgeError::BadRequest(msg) => write!(f, "{msg}"),
            // `{:#}` keeps the anyhow context chain in one line.
            BridgeError::Internal(e) => write!(f, "{e:#}"),
            BridgeError::Persist(msg) => write!(f, "persistence: {msg}"),
        }
    }
}

impl std::error::Error for BridgeError {}

impl From<anyhow::Error> for BridgeError {
    fn from(e: anyhow::Error) -> BridgeError {
        BridgeError::Internal(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_mapping() {
        assert_eq!(BridgeError::QuotaExceeded { user: "u".into() }.http_status(), 429);
        assert_eq!(BridgeError::UnknownRequest(7).http_status(), 404);
        assert_eq!(BridgeError::bad_request("nope").http_status(), 400);
        assert_eq!(
            BridgeError::Internal(anyhow::anyhow!("boom")).http_status(),
            500
        );
        assert_eq!(BridgeError::Persist("bad wal".into()).http_status(), 500);
    }

    #[test]
    fn persist_display_names_the_subsystem() {
        let e = BridgeError::Persist("wal checksum mismatch in record 3".into());
        assert!(e.to_string().contains("persistence"));
        assert!(e.to_string().contains("checksum"));
    }

    #[test]
    fn display_preserves_quota_message() {
        // The CLI and logs still read like the old anyhow messages.
        let e = BridgeError::QuotaExceeded { user: "student-1".into() };
        assert_eq!(e.to_string(), "quota exceeded for user student-1");
    }

    #[test]
    fn anyhow_interop_both_ways() {
        // Stages `?` anyhow errors into BridgeError...
        let be: BridgeError = anyhow::anyhow!("engine died").into();
        assert!(matches!(be, BridgeError::Internal(_)));
        // ...and application code `?`s BridgeError back into anyhow.
        let ae: anyhow::Error = BridgeError::UnknownRequest(0xAB).into();
        assert!(ae.to_string().contains("ab"));
    }
}
