//! Typed request-path errors.
//!
//! The proxy used to surface every failure as a stringly `anyhow::Error`,
//! and the REST layer guessed HTTP status codes by substring-matching the
//! message ("quota" → 429). `BridgeError` replaces that: each variant
//! carries exactly what the caller needs and maps to one status code, so
//! new failure modes get a status by construction, not by grep.

use std::fmt;
use std::time::Duration;

/// Marker payload the engine attaches to an expired RPC: typed so the
/// pipeline can `downcast_ref` it out of the `anyhow` chain and map it
/// to [`BridgeError::UpstreamTimeout`] (503) instead of a generic 500.
#[derive(Debug)]
pub struct EngineTimeout {
    pub timeout: Duration,
}

impl fmt::Display for EngineTimeout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "engine rpc timed out after {:?}", self.timeout)
    }
}

impl std::error::Error for EngineTimeout {}

/// Everything `Bridge::handle` / `Bridge::regenerate` can fail with.
#[derive(Debug)]
pub enum BridgeError {
    /// The per-user quota gate rejected the request (§5.2 classroom caps).
    QuotaExceeded { user: String },
    /// `regenerate` was asked about an exchange the proxy never served.
    UnknownRequest(u64),
    /// The caller sent something unparseable or unknown (bad JSON, unknown
    /// model id, unknown service type).
    BadRequest(String),
    /// Engine / runtime failure — nothing the caller did wrong.
    Internal(anyhow::Error),
    /// Durable-state failure: snapshot/WAL corruption detected at boot or
    /// compaction (torn *tails* are tolerated and never reach here; this
    /// is interior corruption or an unreadable data dir).
    Persist(String),
    /// The model's circuit breaker is open: the backend has failed
    /// repeatedly and requests fast-fail until the cooldown lapses.
    BreakerOpen {
        model: String,
        retry_after_secs: u64,
    },
    /// The engine RPC expired (`--engine-timeout-secs`): the backend is
    /// hung, not wrong — retryable, and counted against the breaker.
    UpstreamTimeout { secs: u64 },
}

impl BridgeError {
    /// The HTTP status the REST layer serves for this error.
    pub fn http_status(&self) -> u16 {
        match self {
            BridgeError::QuotaExceeded { .. } => 429,
            BridgeError::UnknownRequest(_) => 404,
            BridgeError::BadRequest(_) => 400,
            BridgeError::Internal(_) => 500,
            BridgeError::Persist(_) => 500,
            BridgeError::BreakerOpen { .. } => 503,
            BridgeError::UpstreamTimeout { .. } => 503,
        }
    }

    /// Machine-readable shed reason for the response body, so clients can
    /// tell the three 429s (admission/rate/quota) and two 503s
    /// (breaker/timeout) apart without parsing prose.
    pub fn reason(&self) -> Option<&'static str> {
        match self {
            BridgeError::QuotaExceeded { .. } => Some("quota"),
            BridgeError::BreakerOpen { .. } => Some("breaker"),
            BridgeError::UpstreamTimeout { .. } => Some("timeout"),
            _ => None,
        }
    }

    /// `Retry-After` header value, when this error implies one.
    pub fn retry_after_secs(&self) -> Option<u64> {
        match self {
            BridgeError::BreakerOpen {
                retry_after_secs, ..
            } => Some((*retry_after_secs).max(1)),
            _ => None,
        }
    }

    pub fn bad_request(msg: impl Into<String>) -> BridgeError {
        BridgeError::BadRequest(msg.into())
    }
}

impl fmt::Display for BridgeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BridgeError::QuotaExceeded { user } => {
                write!(f, "quota exceeded for user {user}")
            }
            BridgeError::UnknownRequest(id) => write!(f, "unknown request id {id:x}"),
            BridgeError::BadRequest(msg) => write!(f, "{msg}"),
            // `{:#}` keeps the anyhow context chain in one line.
            BridgeError::Internal(e) => write!(f, "{e:#}"),
            BridgeError::Persist(msg) => write!(f, "persistence: {msg}"),
            BridgeError::BreakerOpen {
                model,
                retry_after_secs,
            } => write!(
                f,
                "circuit breaker open for model {model} (retry in {retry_after_secs}s)"
            ),
            BridgeError::UpstreamTimeout { secs } => {
                write!(f, "upstream engine timed out after {secs}s")
            }
        }
    }
}

impl std::error::Error for BridgeError {}

impl From<anyhow::Error> for BridgeError {
    fn from(e: anyhow::Error) -> BridgeError {
        // An expired engine RPC carries a typed marker: surface it as a
        // retryable 503 rather than an opaque Internal 500.
        if let Some(t) = e.downcast_ref::<EngineTimeout>() {
            return BridgeError::UpstreamTimeout {
                secs: t.timeout.as_secs(),
            };
        }
        BridgeError::Internal(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_mapping() {
        assert_eq!(BridgeError::QuotaExceeded { user: "u".into() }.http_status(), 429);
        assert_eq!(BridgeError::UnknownRequest(7).http_status(), 404);
        assert_eq!(BridgeError::bad_request("nope").http_status(), 400);
        assert_eq!(
            BridgeError::Internal(anyhow::anyhow!("boom")).http_status(),
            500
        );
        assert_eq!(BridgeError::Persist("bad wal".into()).http_status(), 500);
        assert_eq!(
            BridgeError::BreakerOpen { model: "m".into(), retry_after_secs: 3 }.http_status(),
            503
        );
        assert_eq!(BridgeError::UpstreamTimeout { secs: 30 }.http_status(), 503);
    }

    #[test]
    fn reasons_distinguish_shed_classes() {
        assert_eq!(
            BridgeError::QuotaExceeded { user: "u".into() }.reason(),
            Some("quota")
        );
        let open = BridgeError::BreakerOpen { model: "m".into(), retry_after_secs: 7 };
        assert_eq!(open.reason(), Some("breaker"));
        assert_eq!(open.retry_after_secs(), Some(7));
        assert_eq!(BridgeError::UpstreamTimeout { secs: 1 }.reason(), Some("timeout"));
        assert_eq!(BridgeError::bad_request("x").reason(), None);
        assert_eq!(BridgeError::bad_request("x").retry_after_secs(), None);
    }

    #[test]
    fn engine_timeout_downcasts_to_503() {
        let anyhow_err = anyhow::Error::new(EngineTimeout {
            timeout: std::time::Duration::from_secs(30),
        });
        let be: BridgeError = anyhow_err.into();
        assert!(matches!(be, BridgeError::UpstreamTimeout { secs: 30 }));
        assert_eq!(be.http_status(), 503);
    }

    #[test]
    fn persist_display_names_the_subsystem() {
        let e = BridgeError::Persist("wal checksum mismatch in record 3".into());
        assert!(e.to_string().contains("persistence"));
        assert!(e.to_string().contains("checksum"));
    }

    #[test]
    fn display_preserves_quota_message() {
        // The CLI and logs still read like the old anyhow messages.
        let e = BridgeError::QuotaExceeded { user: "student-1".into() };
        assert_eq!(e.to_string(), "quota exceeded for user student-1");
    }

    #[test]
    fn anyhow_interop_both_ways() {
        // Stages `?` anyhow errors into BridgeError...
        let be: BridgeError = anyhow::anyhow!("engine died").into();
        assert!(matches!(be, BridgeError::Internal(_)));
        // ...and application code `?`s BridgeError back into anyhow.
        let ae: anyhow::Error = BridgeError::UnknownRequest(0xAB).into();
        assert!(ae.to_string().contains("ab"));
    }
}
