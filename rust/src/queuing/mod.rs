//! Per-user FIFO queue substrate — stand-in for the paper's AWS SQS FIFO
//! queues (§4): "To ensure requests are processed in the expected order we
//! use a per-user FIFO queue. Every incoming request goes through this
//! queue, and is only removed from the queue when a response has been sent."
//!
//! Semantics: each `group` (user) has an ordered queue; at most one message
//! per group is in flight at a time. `pop` hands out the head of some group
//! that has no in-flight message; `ack` completes it (removing it) and
//! unblocks the group; `nack` returns it to the head for redelivery.
//!
//! Delivery across groups is round-robin fair: the scan for the next
//! ready group starts strictly *after* the last-delivered group (wrapping),
//! so a continuously-refilled lexicographically-early group can never
//! starve a later one — a first-ready scan over the `BTreeMap` would
//! (see `no_ready_group_starves_under_multi_group_churn`).

use std::collections::{BTreeMap, VecDeque};
use std::ops::Bound::{Excluded, Unbounded};
use std::sync::{Condvar, Mutex};

#[derive(Clone, Debug, PartialEq)]
pub struct QueuedMessage<T> {
    pub id: u64,
    pub group: String,
    pub payload: T,
}

struct GroupQueue<T> {
    messages: VecDeque<QueuedMessage<T>>,
    in_flight: bool,
}

struct Inner<T> {
    groups: BTreeMap<String, GroupQueue<T>>,
    next_id: u64,
    closed: bool,
    /// Last group a message was delivered from; the next scan starts
    /// strictly after it (wrapping) so delivery rotates across groups.
    /// May name a since-removed group — `range` handles that fine.
    cursor: Option<String>,
}

impl<T> Inner<T> {
    /// The next group with a ready head, rotating from the cursor.
    fn next_ready(&self) -> Option<String> {
        fn ready<T>(g: &GroupQueue<T>) -> bool {
            !g.in_flight && !g.messages.is_empty()
        }
        if let Some(cur) = &self.cursor {
            if let Some((k, _)) = self
                .groups
                .range::<String, _>((Excluded(cur), Unbounded))
                .find(|(_, g)| ready(g))
            {
                return Some(k.clone());
            }
        }
        self.groups
            .iter()
            .find(|(_, g)| ready(g))
            .map(|(k, _)| k.clone())
    }
}

/// Multi-group FIFO with per-group exclusive delivery.
pub struct FifoQueue<T> {
    inner: Mutex<Inner<T>>,
    cond: Condvar,
}

impl<T> Default for FifoQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> FifoQueue<T> {
    pub fn new() -> Self {
        FifoQueue {
            inner: Mutex::new(Inner {
                groups: BTreeMap::new(),
                next_id: 1,
                closed: false,
                cursor: None,
            }),
            cond: Condvar::new(),
        }
    }

    /// Enqueue a payload for a group; returns the message id.
    pub fn push(&self, group: &str, payload: T) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner
            .groups
            .entry(group.to_string())
            .or_insert_with(|| GroupQueue {
                messages: VecDeque::new(),
                in_flight: false,
            })
            .messages
            .push_back(QueuedMessage {
                id,
                group: group.to_string(),
                payload,
            });
        self.cond.notify_one();
        id
    }

    /// Bounded enqueue — the backpressure primitive of the evented
    /// server's admission control. Refuses (returning the payload to the
    /// caller, who sheds with a 429) when the group already holds `cap`
    /// messages including the in-flight one, so one user's burst can
    /// never grow their queue without bound while the per-user
    /// serialization guarantee drains it one request at a time.
    pub fn push_bounded(&self, group: &str, payload: T, cap: usize) -> Result<u64, T> {
        let mut inner = self.inner.lock().unwrap();
        if inner.groups.get(group).map_or(0, |g| g.messages.len()) >= cap {
            return Err(payload);
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner
            .groups
            .entry(group.to_string())
            .or_insert_with(|| GroupQueue {
                messages: VecDeque::new(),
                in_flight: false,
            })
            .messages
            .push_back(QueuedMessage {
                id,
                group: group.to_string(),
                payload,
            });
        self.cond.notify_one();
        Ok(id)
    }

    /// Queued (including in-flight) messages in one group.
    pub fn group_len(&self, group: &str) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.groups.get(group).map_or(0, |g| g.messages.len())
    }

    /// Blocking pop: returns the next deliverable message, or None if the
    /// queue is closed and fully drained.
    pub fn pop(&self) -> Option<QueuedMessage<T>>
    where
        T: Clone,
    {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(group) = inner.next_ready() {
                inner.cursor = Some(group.clone());
                let g = inner.groups.get_mut(&group).unwrap();
                g.in_flight = true;
                return g.messages.front().cloned();
            }
            if inner.closed {
                return None;
            }
            inner = self.cond.wait(inner).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<QueuedMessage<T>>
    where
        T: Clone,
    {
        let mut inner = self.inner.lock().unwrap();
        let candidate = inner.next_ready();
        candidate.map(|group| {
            inner.cursor = Some(group.clone());
            let g = inner.groups.get_mut(&group).unwrap();
            g.in_flight = true;
            g.messages.front().cloned().unwrap()
        })
    }

    /// Complete an in-flight message: remove it and unblock its group.
    pub fn ack(&self, msg_id: u64, group: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let Some(g) = inner.groups.get_mut(group) else {
            return false;
        };
        if !g.in_flight || g.messages.front().map(|m| m.id) != Some(msg_id) {
            return false;
        }
        g.messages.pop_front();
        g.in_flight = false;
        if g.messages.is_empty() {
            inner.groups.remove(group);
        }
        self.cond.notify_all();
        true
    }

    /// Return an in-flight message to the head of its group (redelivery).
    pub fn nack(&self, msg_id: u64, group: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let Some(g) = inner.groups.get_mut(group) else {
            return false;
        };
        if !g.in_flight || g.messages.front().map(|m| m.id) != Some(msg_id) {
            return false;
        }
        g.in_flight = false;
        self.cond.notify_all();
        true
    }

    /// Total queued (including in-flight) messages.
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.groups.values().map(|g| g.messages.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: blocked `pop`s return None once drained.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_group() {
        let q = FifoQueue::new();
        q.push("u1", 1);
        q.push("u1", 2);
        let m1 = q.try_pop().unwrap();
        assert_eq!(m1.payload, 1);
        // Second message of the same group must be blocked until ack.
        assert!(q.try_pop().is_none());
        assert!(q.ack(m1.id, "u1"));
        let m2 = q.try_pop().unwrap();
        assert_eq!(m2.payload, 2);
    }

    #[test]
    fn groups_independent() {
        let q = FifoQueue::new();
        q.push("u1", 1);
        q.push("u2", 2);
        let a = q.try_pop().unwrap();
        let b = q.try_pop().unwrap();
        assert_ne!(a.group, b.group);
    }

    #[test]
    fn nack_redelivers_same_message() {
        let q = FifoQueue::new();
        q.push("u1", 7);
        let m = q.try_pop().unwrap();
        assert!(q.nack(m.id, "u1"));
        let again = q.try_pop().unwrap();
        assert_eq!(again.id, m.id);
    }

    #[test]
    fn ack_wrong_id_rejected() {
        let q = FifoQueue::new();
        q.push("u1", 7);
        let m = q.try_pop().unwrap();
        assert!(!q.ack(m.id + 999, "u1"));
        assert!(!q.ack(m.id, "u2"));
        assert!(q.ack(m.id, "u1"));
    }

    #[test]
    fn push_bounded_sheds_at_cap_including_in_flight() {
        let q = FifoQueue::new();
        assert!(q.push_bounded("u1", 1, 2).is_ok());
        assert!(q.push_bounded("u1", 2, 2).is_ok());
        // At cap: the payload comes back to the caller.
        assert_eq!(q.push_bounded("u1", 3, 2), Err(3));
        assert_eq!(q.group_len("u1"), 2);
        // Other groups have their own budget.
        assert!(q.push_bounded("u2", 9, 2).is_ok());
        // In-flight still counts toward the cap...
        let m = q.try_pop().unwrap();
        assert_eq!(q.push_bounded(&m.group, 4, 2), Err(4));
        // ...and acking frees a slot.
        assert!(q.ack(m.id, &m.group));
        assert!(q.push_bounded("u1", 4, 2).is_ok());
    }

    #[test]
    fn close_drains_blocked_pops() {
        let q: Arc<FifoQueue<u32>> = Arc::new(FifoQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn no_ready_group_starves_under_multi_group_churn() {
        // Adversarial schedule for a first-ready scan: the delivered
        // group is refilled *before* it is acked, so it is ready again
        // by the next pop. Without the rotation cursor, the
        // lexicographically first group would be delivered every single
        // time and the others would starve forever; with it, delivery
        // must visit every ready group once per rotation.
        let q = FifoQueue::new();
        let groups = ["alpha", "beta", "gamma", "zeta"];
        for g in groups {
            q.push(g, 0);
        }
        let mut counts: BTreeMap<String, u32> = BTreeMap::new();
        let rounds = 40u32;
        for step in 0..rounds {
            let m = q.pop().unwrap();
            q.push(&m.group, step + 1);
            q.ack(m.id, &m.group);
            *counts.entry(m.group).or_insert(0) += 1;
        }
        for g in groups {
            let served = counts.get(g).copied().unwrap_or(0);
            let fair_share = rounds / groups.len() as u32;
            assert!(
                served >= fair_share - 1,
                "group {g} served {served}/{rounds} (fair share {fair_share}): starved"
            );
        }
    }

    #[test]
    fn nack_redelivers_at_head_in_order() {
        // A nacked message comes back *at the head*: the group's FIFO
        // order survives redelivery, and later messages stay blocked
        // behind it until it is finally acked.
        let q = FifoQueue::new();
        q.push("u1", 10);
        q.push("u1", 20);
        q.push("u1", 30);
        let first = q.try_pop().unwrap();
        assert_eq!(first.payload, 10);
        assert!(q.nack(first.id, "u1"));
        let mut drained = Vec::new();
        while let Some(m) = q.try_pop() {
            drained.push((m.id, m.payload));
            q.ack(m.id, "u1");
        }
        assert_eq!(
            drained,
            vec![(first.id, 10), (first.id + 1, 20), (first.id + 2, 30)],
            "redelivery must replay the nacked head first, then the rest in order"
        );
    }

    #[test]
    fn close_racing_concurrent_pops_drains_then_none() {
        // close() must not drop queued work: consumers racing the close
        // drain every message exactly once, then every blocked pop
        // returns None.
        let q: Arc<FifoQueue<u32>> = Arc::new(FifoQueue::new());
        let seen = Arc::new(Mutex::new(Vec::<u32>::new()));
        let mut handles = vec![];
        for _ in 0..4 {
            let q = q.clone();
            let seen = seen.clone();
            handles.push(std::thread::spawn(move || {
                while let Some(m) = q.pop() {
                    seen.lock().unwrap().push(m.payload);
                    q.ack(m.id, &m.group);
                }
            }));
        }
        for i in 0..200u32 {
            q.push(&format!("g{}", i % 7), i);
            if i == 100 {
                // Let consumers race the producer mid-stream.
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        q.close();
        for h in handles {
            h.join().unwrap();
        }
        let mut seen = seen.lock().unwrap().clone();
        seen.sort_unstable();
        assert_eq!(
            seen,
            (0..200).collect::<Vec<_>>(),
            "every message delivered exactly once before pops observed None"
        );
        assert!(q.is_empty());
        assert!(q.pop().is_none(), "post-drain pop returns None immediately");
    }

    #[test]
    fn concurrent_consumers_preserve_group_order() {
        let q: Arc<FifoQueue<u32>> = Arc::new(FifoQueue::new());
        for i in 0..100 {
            q.push("u1", i);
            q.push("u2", 1000 + i);
        }
        q.close();
        let seen = Arc::new(Mutex::new(Vec::<(String, u32)>::new()));
        let mut handles = vec![];
        for _ in 0..4 {
            let q = q.clone();
            let seen = seen.clone();
            handles.push(std::thread::spawn(move || {
                while let Some(m) = q.pop() {
                    seen.lock().unwrap().push((m.group.clone(), m.payload));
                    q.ack(m.id, &m.group);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let seen = seen.lock().unwrap();
        let u1: Vec<u32> = seen.iter().filter(|(g, _)| g == "u1").map(|(_, p)| *p).collect();
        let u2: Vec<u32> = seen.iter().filter(|(g, _)| g == "u2").map(|(_, p)| *p).collect();
        assert_eq!(u1, (0..100).collect::<Vec<_>>());
        assert_eq!(u2, (1000..1100).collect::<Vec<_>>());
    }
}
