//! Deterministic PRNG substrate: SplitMix64 core with uniform/normal/choice
//! helpers. Every stochastic decision in the simulation layer derives from
//! an explicit seed so whole benchmark runs are bit-reproducible.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Derive an independent child stream (stable, order-sensitive).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(s)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a reference from a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

/// One-shot uniform [0,1) from a seed — for hash-derived decisions where
/// constructing a stream is overkill.
pub fn unit_from_seed(seed: u64) -> f64 {
    Rng::new(seed).f64()
}

/// One stateless SplitMix64 step: `split_mix(k) == Rng::new(k).next_u64()`
/// by construction, so keyed hashing (the deterministic runtime backend)
/// and the stream PRNG can never diverge.
pub fn split_mix(key: u64) -> u64 {
    Rng::new(key).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(1);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
