//! Shared synthetic-corpus generators for benches and tests.
//!
//! Cached prompts cluster by topic, so every vecdb bench/test wants the
//! same workload shape: points scattered around well-separated centers.
//! This module is the single home of that generator — `benches/hotpath.rs`
//! (up to the million-row tier), the in-crate vecdb tests, and the
//! persistence integration suite all call it instead of carrying copies.
//! Deterministic for a given seed, so corpora are reproducible across
//! processes and PRs.

use crate::util::rng::Rng;

/// Row-major clustered corpus: `n` points of dimension `dim` around
/// `centers` centers. Center coordinates are drawn from N(0, spread²),
/// each point is its center plus per-coordinate N(0, noise²) jitter.
/// Memory is the only scale limit — `n = 1_000_000, dim = 64` is ~256 MB.
pub fn clustered_rows(
    seed: u64,
    n: usize,
    dim: usize,
    centers: usize,
    spread: f32,
    noise: f32,
) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let cs: Vec<Vec<f32>> = (0..centers.max(1))
        .map(|_| (0..dim).map(|_| rng.normal() as f32 * spread).collect())
        .collect();
    let mut rows = Vec::with_capacity(n * dim);
    for _ in 0..n {
        let c = &cs[rng.below(cs.len())];
        rows.extend(c.iter().map(|x| x + rng.normal() as f32 * noise));
    }
    rows
}

/// [`clustered_rows`] as `(id, vector)` pairs with ids `0..n` — the shape
/// the index tests insert from.
pub fn clustered_pairs(
    seed: u64,
    n: usize,
    dim: usize,
    centers: usize,
    spread: f32,
    noise: f32,
) -> Vec<(u64, Vec<f32>)> {
    clustered_rows(seed, n, dim, centers, spread, noise)
        .chunks(dim)
        .enumerate()
        .map(|(i, row)| (i as u64, row.to_vec()))
        .collect()
}

/// Balanced clustered corpus: exactly `per_cluster` points around each of
/// `clusters` centers, ids sequential in generation order (cluster `c`
/// owns ids `c*per_cluster..(c+1)*per_cluster`).
///
/// Recall gates against exact f32 ground truth want this shape rather
/// than [`clustered_pairs`]: with `per_cluster == k`, the true top-k of a
/// query near a center is the *entire* cluster — membership is separated
/// from every other point by a wide score gap, so the assertion measures
/// whether the index finds the right neighborhood instead of how it
/// tie-breaks near-equal neighbors (which quantization legitimately
/// reorders within its error bound).
pub fn balanced_clustered_pairs(
    seed: u64,
    clusters: usize,
    per_cluster: usize,
    dim: usize,
    spread: f32,
    noise: f32,
) -> Vec<(u64, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(clusters * per_cluster);
    for _ in 0..clusters {
        let center: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * spread).collect();
        for _ in 0..per_cluster {
            let v: Vec<f32> = center
                .iter()
                .map(|x| x + rng.normal() as f32 * noise)
                .collect();
            out.push((out.len() as u64, v));
        }
    }
    out
}

/// A query near `base`: per-coordinate N(0, noise²) perturbation — recall
/// probes are corpus points nudged off their stored position.
pub fn perturbed(rng: &mut Rng, base: &[f32], noise: f32) -> Vec<f32> {
    base.iter().map(|x| x + rng.normal() as f32 * noise).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sized() {
        let a = clustered_rows(42, 100, 16, 8, 8.0, 0.4);
        let b = clustered_rows(42, 100, 16, 8, 8.0, 0.4);
        assert_eq!(a.len(), 100 * 16);
        assert_eq!(a, b);
        let c = clustered_rows(43, 100, 16, 8, 8.0, 0.4);
        assert_ne!(a, c);
    }

    #[test]
    fn pairs_match_rows() {
        let rows = clustered_rows(7, 50, 8, 4, 8.0, 0.4);
        let pairs = clustered_pairs(7, 50, 8, 4, 8.0, 0.4);
        assert_eq!(pairs.len(), 50);
        assert_eq!(pairs[0].0, 0);
        assert_eq!(pairs[49].0, 49);
        for (i, (_, v)) in pairs.iter().enumerate() {
            assert_eq!(&rows[i * 8..(i + 1) * 8], &v[..]);
        }
    }

    #[test]
    fn balanced_is_deterministic_and_grouped() {
        let a = balanced_clustered_pairs(11, 20, 4, 8, 8.0, 0.4);
        let b = balanced_clustered_pairs(11, 20, 4, 8, 8.0, 0.4);
        assert_eq!(a.len(), 80);
        assert_eq!(a, b);
        assert_eq!(a[79].0, 79);
        // Points 4c..4c+4 share a cluster: pairwise distance within a
        // cluster is noise-scale, far below the spread-scale centers.
        for c in 0..20 {
            for m in 1..4 {
                let d2: f32 = a[c * 4].1.iter().zip(&a[c * 4 + m].1)
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(d2 < 8.0 * 8.0, "cluster {c} member {m} strayed: {d2}");
            }
        }
    }

    #[test]
    fn perturbed_stays_near_base() {
        let mut rng = Rng::new(9);
        let base = vec![1.0f32; 32];
        let q = perturbed(&mut rng, &base, 0.1);
        assert_eq!(q.len(), 32);
        let d2: f32 = q.iter().zip(&base).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!(d2 < 32.0 * 0.1 * 0.1 * 16.0, "perturbation too large: {d2}");
    }
}
