//! Criterion-style micro-benchmark harness substrate.
//!
//! Used by the `rust/benches/*` targets (all `harness = false`): warmup,
//! timed iterations, and a stats line with mean / p50 / p99. Honors
//! `LLMBRIDGE_BENCH_FAST=1` to shrink iteration counts in CI.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} {:>8} iters  mean {:>12?}  p50 {:>12?}  p99 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p99, self.min
        );
    }
}

pub fn fast_mode() -> bool {
    std::env::var("LLMBRIDGE_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    let iters = if fast_mode() { iters.div_ceil(10).max(3) } else { iters };
    let warmup = if fast_mode() { warmup.min(1) } else { warmup };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p99: samples[(iters * 99 / 100).min(iters - 1)],
        min: samples[0],
        max: samples[iters - 1],
    };
    res.print();
    res
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept here so benches read uniformly).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", 1, 16, || {
            black_box(1 + 1);
        });
        assert_eq!(r.iters, if fast_mode() { 3.max(16_usize.div_ceil(10)) } else { 16 });
        assert!(r.p50 <= r.p99);
        assert!(r.min <= r.p50);
    }
}
