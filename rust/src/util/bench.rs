//! Criterion-style micro-benchmark harness substrate.
//!
//! Used by the `rust/benches/*` targets (all `harness = false`): warmup,
//! timed iterations, and a stats line with mean / p50 / p99. Honors
//! `LLMBRIDGE_BENCH_FAST=1` to shrink iteration counts in CI.

use std::time::{Duration, Instant};

use crate::util::json::Json;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} {:>8} iters  mean {:>12?}  p50 {:>12?}  p99 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p99, self.min
        );
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iters", Json::num(self.iters as f64)),
            ("mean_us", Json::num(self.mean.as_micros() as f64)),
            ("p50_us", Json::num(self.p50.as_micros() as f64)),
            ("p99_us", Json::num(self.p99.as_micros() as f64)),
            ("min_us", Json::num(self.min.as_micros() as f64)),
            ("max_us", Json::num(self.max.as_micros() as f64)),
        ])
    }
}

/// Collects a bench binary's results and writes them as one JSON object —
/// the machine-readable side of the perf trajectory (`BENCH_*.json` at the
/// repo root; see `scripts/bench.sh` and ROADMAP.md §Perf trajectory).
#[derive(Default)]
pub struct BenchReport {
    entries: Vec<(String, Json)>,
}

impl BenchReport {
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    /// Record a completed [`BenchResult`] under its bench name.
    pub fn record(&mut self, r: &BenchResult) {
        self.entries.push((r.name.clone(), r.to_json()));
    }

    /// Record an arbitrary named JSON value (e.g. throughput summaries).
    pub fn push(&mut self, name: &str, value: Json) {
        self.entries.push((name.to_string(), value));
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.entries.iter().cloned().collect())
    }

    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Write to the path named by env var `var`, if set and non-empty
    /// (how `scripts/bench.sh` routes each bench's JSON to the repo root).
    pub fn write_env(&self, var: &str) {
        if let Ok(path) = std::env::var(var) {
            if !path.is_empty() {
                if let Err(e) = self.write(std::path::Path::new(&path)) {
                    eprintln!("bench report write {path}: {e}");
                }
            }
        }
    }
}

pub fn fast_mode() -> bool {
    std::env::var("LLMBRIDGE_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// CI smoke mode (`scripts/bench.sh --smoke`): single timed iteration, no
/// warmup — the run proves the bench harness works and emits populated
/// JSON, not that the numbers are stable. Benches also shrink their
/// corpus sizes under this flag.
pub fn smoke_mode() -> bool {
    std::env::var("LLMBRIDGE_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    let (warmup, iters) = if smoke_mode() {
        (0, 1)
    } else if fast_mode() {
        (warmup.min(1), iters.div_ceil(10).max(3))
    } else {
        (warmup, iters)
    };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p99: samples[(iters * 99 / 100).min(iters - 1)],
        min: samples[0],
        max: samples[iters - 1],
    };
    res.print();
    res
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept here so benches read uniformly).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", 1, 16, || {
            black_box(1 + 1);
        });
        assert_eq!(r.iters, if fast_mode() { 3.max(16_usize.div_ceil(10)) } else { 16 });
        assert!(r.p50 <= r.p99);
        assert!(r.min <= r.p50);
    }

    #[test]
    fn bench_report_roundtrips_as_json() {
        let r = bench("report_probe", 0, 4, || {
            black_box(2 + 2);
        });
        let mut report = BenchReport::new();
        report.record(&r);
        report.push("custom", Json::obj(vec![("rps", Json::num(123.0))]));
        let parsed = Json::parse(&report.to_json().to_string()).unwrap();
        assert!(parsed.get("report_probe").is_some());
        assert_eq!(
            parsed.get("custom").unwrap().f64_of("rps").unwrap(),
            123.0
        );
        assert!(parsed.get("report_probe").unwrap().f64_of("iters").unwrap() >= 1.0);
    }
}
