//! Property-testing substrate (proptest is not available offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` for each; on failure it panics with the failing case's
//! seed so the exact input is reproducible with `forall_one`.

use super::rng::Rng;

/// Run `prop` over `cases` random inputs produced by `gen`.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed on case {case} (seed {case_seed:#x}): {input:?}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn forall_one<T: std::fmt::Debug>(
    case_seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(case_seed);
    let input = gen(&mut rng);
    assert!(prop(&input), "property failed: {input:?}");
}

/// Random ASCII word of length 1..=max_len.
pub fn gen_word(rng: &mut Rng, max_len: usize) -> String {
    let len = 1 + rng.below(max_len.max(1));
    (0..len)
        .map(|_| (b'a' + rng.below(26) as u8) as char)
        .collect()
}

/// Random sentence of 1..=max_words words.
pub fn gen_text(rng: &mut Rng, max_words: usize) -> String {
    let n = 1 + rng.below(max_words.max(1));
    (0..n)
        .map(|_| gen_word(rng, 9))
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(1, 50, |r| r.below(100), |&n| n < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 50, |r| r.below(100), |&n| n < 50);
    }

    #[test]
    fn gen_text_nonempty() {
        forall(3, 50, |r| gen_text(r, 12), |t| !t.is_empty());
    }
}
