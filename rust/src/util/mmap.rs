//! Minimal read-only file mapping — raw `mmap(2)`/`munmap(2)` through the
//! C runtime std already links on unix, honoring the anyhow-only
//! dependency policy (no `memmap2`/`libc` crates).
//!
//! The one consumer is the LBV4 snapshot loader: the vector-code region of
//! a million-row index is mapped, not read, so `restore_from_dir` returns
//! before the codes are resident and first queries fault pages in on
//! demand. Maps are whole-file from offset 0 — region offsets are plain
//! slice arithmetic on [`MmapRegion::as_bytes`], which sidesteps
//! page-alignment rules across 4k/16k/64k-page systems.
//!
//! Caveat (inherent to file mappings): truncating the snapshot file while
//! a map is live turns later faults into SIGBUS. Snapshot files are
//! replace-by-rename, never truncated in place, so the window does not
//! arise in this codebase.

use std::fs::File;
use std::os::raw::c_void;
use std::os::unix::io::AsRawFd;

use anyhow::{bail, Result};

mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    // The 64-bit unix ABI this crate targets (x86_64/aarch64 linux + mac)
    // has `off_t == i64`; 32-bit targets without large-file offsets would
    // need `mmap64` instead.
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// A whole-file, read-only, private mapping. Dropping it unmaps.
pub struct MmapRegion {
    ptr: *mut c_void,
    len: usize,
}

// Safety: the mapping is PROT_READ + MAP_PRIVATE for its whole lifetime —
// immutable shared bytes, like an `Arc<[u8]>` whose storage is the page
// cache. No interior mutability, no aliasing writes.
unsafe impl Send for MmapRegion {}
unsafe impl Sync for MmapRegion {}

impl MmapRegion {
    /// Map the whole of `file` read-only. Nothing is read at map time; the
    /// kernel faults pages in as [`MmapRegion::as_bytes`] is dereferenced.
    pub fn map_file(file: &File) -> Result<MmapRegion> {
        let len = file.metadata()?.len();
        if len == 0 {
            bail!("mmap: refusing to map an empty file");
        }
        let len = usize::try_from(len)
            .map_err(|_| anyhow::anyhow!("mmap: {len}-byte file exceeds the address space"))?;
        // Safety: fd is a live file we hold open; the kernel validates the
        // request and we check for MAP_FAILED (-1) below.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            bail!("mmap of {len} bytes failed");
        }
        Ok(MmapRegion { ptr, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The mapped bytes (page-faulted on first touch).
    pub fn as_bytes(&self) -> &[u8] {
        // Safety: ptr..ptr+len is a live PROT_READ mapping owned by self;
        // the borrow cannot outlive the unmap in Drop.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        // Safety: unmapping exactly the region this value mapped; the
        // result is ignored because failure leaves us no recovery beyond
        // leaking the mapping.
        unsafe {
            sys::munmap(self.ptr, self.len);
        }
    }
}

impl std::fmt::Debug for MmapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapRegion").field("len", &self.len).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn maps_file_contents_and_unmaps() {
        let path = std::env::temp_dir().join(format!("llmbridge_mmap_{}", std::process::id()));
        let payload: Vec<u8> = (0..10_000u32).flat_map(|i| i.to_le_bytes()).collect();
        {
            let mut f = File::create(&path).unwrap();
            f.write_all(&payload).unwrap();
            f.sync_all().unwrap();
        }
        {
            let f = File::open(&path).unwrap();
            let map = MmapRegion::map_file(&f).unwrap();
            assert_eq!(map.len(), payload.len());
            assert!(!map.is_empty());
            assert_eq!(map.as_bytes(), &payload[..]);
        }
        // Map dropped; the file is independently removable.
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_empty_file() {
        let path = std::env::temp_dir().join(format!("llmbridge_mmap_e_{}", std::process::id()));
        File::create(&path).unwrap().sync_all().unwrap();
        let f = File::open(&path).unwrap();
        assert!(MmapRegion::map_file(&f).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
