//! Minimal epoll + wakeup-pipe shim — raw `epoll_create1(2)` /
//! `epoll_ctl(2)` / `epoll_wait(2)` / `pipe2(2)` through the C runtime
//! std already links on Linux, honoring the anyhow-only dependency
//! policy (no `libc`/`mio` crates). Same pattern as [`crate::util::mmap`].
//!
//! The one consumer is the evented server loop
//! (`crate::server` — `rust/src/server/evloop.rs`): one `Epoll` instance
//! multiplexes the listener, a [`WakePipe`] (worker → loop doorbell), and
//! every live connection. The shim is deliberately tiny: level-triggered
//! only (no `EPOLLET`), one `u64` token per fd, and interest masks built
//! from [`INTEREST_READ`]/[`INTEREST_WRITE`].
//!
//! Non-Linux builds compile the server's portable threaded fallback and
//! never reference this module (`#[cfg(target_os = "linux")]` in
//! `util/mod.rs`).

use std::os::raw::{c_int, c_void};
use std::os::unix::io::RawFd;

use anyhow::{bail, Result};

mod sys {
    use std::os::raw::{c_int, c_void};

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const O_NONBLOCK: c_int = 0o4000;
    pub const O_CLOEXEC: c_int = 0o2000000;

    /// The kernel's `struct epoll_event`. x86_64 is the one ABI where it
    /// is packed (12 bytes); everywhere else it has natural alignment.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn pipe2(fds: *mut c_int, flags: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
    }
}

/// Interest: readable (EPOLLIN). Hangup/error are always reported.
pub const INTEREST_READ: u32 = sys::EPOLLIN;
/// Interest: writable (EPOLLOUT).
pub const INTEREST_WRITE: u32 = sys::EPOLLOUT;

/// One readiness report from [`Epoll::wait`], decoded from the raw mask.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token registered with the fd.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// EPOLLHUP / EPOLLERR / EPOLLRDHUP — the connection is done for.
    pub hangup: bool,
}

fn os_err(what: &str) -> anyhow::Error {
    anyhow::anyhow!("{what}: {}", std::io::Error::last_os_error())
}

/// A level-triggered epoll instance. Dropping it closes the epoll fd
/// (registered fds are merely de-watched, not closed).
pub struct Epoll {
    fd: c_int,
}

// Safety: the epoll fd is just an int; epoll_ctl/epoll_wait are
// thread-safe per POSIX. The server uses it from one loop thread anyway.
unsafe impl Send for Epoll {}
unsafe impl Sync for Epoll {}

impl Epoll {
    pub fn new() -> Result<Epoll> {
        // Safety: plain syscall, result checked below.
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(os_err("epoll_create1"));
        }
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, interest: u32, token: u64) -> Result<()> {
        let mut ev = sys::EpollEvent {
            // Always watch for peer hangup so half-closed keep-alive
            // connections are reaped without a read() round.
            events: interest | sys::EPOLLRDHUP,
            data: token,
        };
        // Safety: `ev` outlives the call; the kernel copies it.
        let rc = unsafe { sys::epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc < 0 {
            bail!("epoll_ctl(op={op}, fd={fd}): {}", std::io::Error::last_os_error());
        }
        Ok(())
    }

    /// Start watching `fd` with `interest`, reporting `token` on events.
    pub fn add(&self, fd: RawFd, interest: u32, token: u64) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Change the interest mask (state transitions of the conn machine).
    pub fn modify(&self, fd: RawFd, interest: u32, token: u64) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Stop watching `fd`. Closing an fd de-watches it implicitly; this
    /// is for fds that stay open (the listener during drain).
    pub fn delete(&self, fd: RawFd) -> Result<()> {
        let mut ev = sys::EpollEvent { events: 0, data: 0 };
        // Safety: pre-2.6.9 kernels require a non-null event even for DEL.
        let rc = unsafe { sys::epoll_ctl(self.fd, sys::EPOLL_CTL_DEL, fd, &mut ev) };
        if rc < 0 {
            return Err(os_err("epoll_ctl(DEL)"));
        }
        Ok(())
    }

    /// Wait up to `timeout_ms` (-1 = forever), appending decoded events
    /// into `out` (cleared first). EINTR retries with the same timeout.
    pub fn wait(&self, out: &mut Vec<Event>, max_events: usize, timeout_ms: i32) -> Result<()> {
        out.clear();
        let cap = max_events.clamp(1, 4096);
        let mut raw = vec![sys::EpollEvent { events: 0, data: 0 }; cap];
        loop {
            // Safety: `raw` is a live buffer of `cap` events.
            let n = unsafe { sys::epoll_wait(self.fd, raw.as_mut_ptr(), cap as c_int, timeout_ms) };
            if n < 0 {
                if std::io::Error::last_os_error().kind() == std::io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(os_err("epoll_wait"));
            }
            for ev in raw.iter().take(n as usize) {
                let mask = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: mask & sys::EPOLLIN != 0,
                    writable: mask & sys::EPOLLOUT != 0,
                    hangup: mask & (sys::EPOLLHUP | sys::EPOLLERR | sys::EPOLLRDHUP) != 0,
                });
            }
            return Ok(());
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        // Safety: closing the fd this value owns; nothing to do on error.
        unsafe {
            sys::close(self.fd);
        }
    }
}

impl std::fmt::Debug for Epoll {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Epoll").field("fd", &self.fd).finish()
    }
}

/// A nonblocking self-pipe: worker threads [`WakePipe::wake`] after
/// publishing a completion, the event loop watches the read end and
/// [`WakePipe::drain`]s it. Writes coalesce (a full pipe is already a
/// pending wakeup, so EAGAIN is success).
pub struct WakePipe {
    r: c_int,
    w: c_int,
}

// Safety: read(2)/write(2) on distinct ends are thread-safe; both ends
// are O_NONBLOCK so neither side can block under contention.
unsafe impl Send for WakePipe {}
unsafe impl Sync for WakePipe {}

impl WakePipe {
    pub fn new() -> Result<WakePipe> {
        let mut fds = [0 as c_int; 2];
        // Safety: fds is a live 2-slot buffer; result checked.
        let rc = unsafe { sys::pipe2(fds.as_mut_ptr(), sys::O_NONBLOCK | sys::O_CLOEXEC) };
        if rc < 0 {
            return Err(os_err("pipe2"));
        }
        Ok(WakePipe { r: fds[0], w: fds[1] })
    }

    /// The fd to register with [`Epoll::add`] under `INTEREST_READ`.
    pub fn read_fd(&self) -> RawFd {
        self.r
    }

    /// Ring the doorbell. Failure modes (EAGAIN = pipe already full) all
    /// mean "a wakeup is already pending", so the result is ignored.
    pub fn wake(&self) {
        let b = [1u8];
        // Safety: writing one byte from a live stack buffer.
        unsafe {
            sys::write(self.w, b.as_ptr() as *const c_void, 1);
        }
    }

    /// Swallow every pending doorbell byte (call on read-readiness).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            // Safety: reading into a live stack buffer.
            let n = unsafe { sys::read(self.r, buf.as_mut_ptr() as *mut c_void, buf.len()) };
            if n <= 0 {
                return;
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        // Safety: closing the two fds this value owns.
        unsafe {
            sys::close(self.r);
            sys::close(self.w);
        }
    }
}

impl std::fmt::Debug for WakePipe {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WakePipe").field("r", &self.r).field("w", &self.w).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn wake_pipe_levels_and_drains() {
        let ep = Epoll::new().unwrap();
        let pipe = WakePipe::new().unwrap();
        ep.add(pipe.read_fd(), INTEREST_READ, 7).unwrap();
        let mut evs = Vec::new();

        // Quiet pipe: no events within the timeout.
        ep.wait(&mut evs, 8, 0).unwrap();
        assert!(evs.is_empty());

        // Multiple wakes coalesce into one readable report.
        pipe.wake();
        pipe.wake();
        ep.wait(&mut evs, 8, 1000).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].token, 7);
        assert!(evs[0].readable);

        // Drained pipe goes quiet again (level-triggered).
        pipe.drain();
        ep.wait(&mut evs, 8, 0).unwrap();
        assert!(evs.is_empty());
    }

    #[test]
    fn socket_readable_and_interest_modify() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), INTEREST_READ, 42).unwrap();
        let mut evs = Vec::new();

        ep.wait(&mut evs, 8, 0).unwrap();
        assert!(evs.is_empty(), "idle socket must not be readable");

        client.write_all(b"ping").unwrap();
        ep.wait(&mut evs, 8, 1000).unwrap();
        assert!(evs.iter().any(|e| e.token == 42 && e.readable));

        // Interest swapped to write-only: pending bytes stop reporting,
        // an idle socket's send buffer reports writable.
        ep.modify(server.as_raw_fd(), INTEREST_WRITE, 42).unwrap();
        ep.wait(&mut evs, 8, 1000).unwrap();
        assert!(evs.iter().any(|e| e.token == 42 && e.writable && !e.readable));

        // Peer close surfaces as hangup alongside readability.
        ep.modify(server.as_raw_fd(), INTEREST_READ, 42).unwrap();
        let mut sink = [0u8; 16];
        let mut s = &server;
        let _ = s.read(&mut sink); // consume "ping" so only EOF remains
        drop(client);
        ep.wait(&mut evs, 8, 1000).unwrap();
        assert!(evs.iter().any(|e| e.token == 42 && e.hangup));

        ep.delete(server.as_raw_fd()).unwrap();
        ep.wait(&mut evs, 8, 0).unwrap();
        assert!(evs.is_empty());
    }
}
