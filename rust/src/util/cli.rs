//! Tiny CLI argument substrate (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--k=5", "trailing"]);
        assert_eq!(a.positional, vec!["serve", "trailing"]);
        assert_eq!(a.usize_or("port", 0), 8080);
        assert_eq!(a.usize_or("k", 0), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_at_end() {
        let a = parse(&["--full"]);
        assert!(a.flag("full"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("artifacts", "artifacts"), "artifacts");
        assert_eq!(a.f64_or("threshold", 8.0), 8.0);
    }
}
