//! Minimal JSON substrate (parser + writer) — serde is not available
//! offline. Supports the full JSON grammar; numbers are f64 (adequate for
//! manifests, API bodies and metrics).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects preserve key order via BTreeMap (deterministic
/// output, which the snapshot tests rely on).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn str_of(&self, key: &str) -> Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("key '{key}' not a string"))?
            .to_string())
    }

    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow!("key '{key}' not a number"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        Ok(self.f64_of(key)? as usize)
    }

    // ------------------------------------------------------------ writing

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.pos += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)?,
                                        16,
                                    )?;
                                    self.pos += 6;
                                    0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00)
                                } else {
                                    bail!("unpaired surrogate");
                                }
                            } else {
                                code
                            };
                            out.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape '\\{}'", esc as char),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                b => {
                    // Multi-byte UTF-8: copy raw continuation bytes.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let slice = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated utf-8"))?;
                    out.push_str(std::str::from_utf8(slice)?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\n"],"c":{"d":-2.5e2}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.f64_of("a").unwrap(), 1.0);
        assert_eq!(v.req("c").unwrap().f64_of("d").unwrap(), -250.0);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café 😀 ünïcödé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café 😀 ünïcödé");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
