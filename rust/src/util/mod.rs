//! Utility substrates built from scratch (the crate's only dependency is
//! `anyhow`; `xla` only under `--features pjrt`): JSON, deterministic
//! PRNG, CLI parsing, a criterion-style bench harness, a property-testing
//! helper, shared bench/test corpus generators, a raw-syscall mmap shim
//! for the snapshot cold-boot path, and a raw-syscall epoll shim for the
//! evented server loop.

pub mod bench;
pub mod cli;
pub mod corpus;
#[cfg(target_os = "linux")]
pub mod epoll;
pub mod json;
#[cfg(unix)]
pub mod mmap;
pub mod prop;
pub mod rng;

/// Whether test failpoints are armed (`LLMBRIDGE_FAILPOINTS=1`). Gates
/// the panic-injection route and the generate-failure param used by the
/// resilience regression tests; callers check it only after a cheap
/// path/param match so normal traffic never reads the environment.
pub fn failpoints_enabled() -> bool {
    std::env::var("LLMBRIDGE_FAILPOINTS").map(|v| v == "1").unwrap_or(false)
}

/// FNV-1a 64-bit hash — the same function the tokenizer uses for word ids
/// and the simulation layer uses for deterministic per-event seeds.
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Combine several hashable items into one deterministic seed.
pub fn seed_of(parts: &[&str]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for p in parts {
        h ^= fnv1a(p.as_bytes());
        h = h.wrapping_mul(0x0000_0100_0000_01B3).rotate_left(17);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_canonical_vectors() {
        assert_eq!(fnv1a(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_F739_67E8);
    }

    #[test]
    fn seed_of_order_sensitive() {
        assert_ne!(seed_of(&["a", "b"]), seed_of(&["b", "a"]));
        assert_eq!(seed_of(&["a", "b"]), seed_of(&["a", "b"]));
    }
}
