//! A minimal keep-alive HTTP/1.1 client with *typed* failure modes.
//!
//! The scenario driver holds hundreds of keep-alive connections against a
//! server it is deliberately overloading, tripping, and reconfiguring —
//! so every way a roundtrip can die must come back as a value, never a
//! hang or a panic: a stuck socket is [`HttpError::Timeout`] (bounded by
//! the connect-time read timeout), a mid-response drop is
//! [`HttpError::Closed`], garbage is [`HttpError::Malformed`]. The test
//! harness's `tests/common` HttpClient layers its panicking convenience
//! API over this same type.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Typed transport/protocol failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HttpError {
    /// No bytes arrived within the read timeout; the operand names the
    /// phase ("connect", "headers", "body").
    Timeout(&'static str),
    /// The peer closed the connection mid-phase.
    Closed(&'static str),
    /// The bytes that did arrive are not a parseable HTTP/1.1 response.
    Malformed(String),
    /// Any other socket error.
    Io(String),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Timeout(phase) => write!(f, "read timeout during {phase}"),
            HttpError::Closed(phase) => write!(f, "connection closed during {phase}"),
            HttpError::Malformed(m) => write!(f, "malformed response: {m}"),
            HttpError::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl std::error::Error for HttpError {}

/// A parsed response.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    /// The raw header block (status line + headers, no trailing CRLFCRLF),
    /// kept for header assertions (`Retry-After`, `Connection`).
    pub head: String,
    pub body: String,
    /// The server sent `Connection: close` — reconnect before reusing.
    pub close: bool,
}

/// One keep-alive connection. Leftover bytes past the current response
/// stay buffered, so back-to-back roundtrips never lose data.
pub struct HttpConn {
    pub stream: TcpStream,
    buf: Vec<u8>,
}

impl HttpConn {
    /// Connect with a bounded read timeout (every later read inherits it).
    pub fn connect(addr: SocketAddr, read_timeout: Duration) -> Result<HttpConn, HttpError> {
        let stream = TcpStream::connect_timeout(&addr, read_timeout.max(Duration::from_secs(1)))
            .map_err(|e| map_io(e, "connect"))?;
        stream
            .set_read_timeout(Some(read_timeout))
            .map_err(|e| map_io(e, "connect"))?;
        stream.set_nodelay(true).ok();
        Ok(HttpConn {
            stream,
            buf: Vec::new(),
        })
    }

    pub fn post(&mut self, path: &str, body: &str) -> Result<HttpResponse, HttpError> {
        let raw = format!(
            "POST {path} HTTP/1.1\r\nHost: scenario\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.send_raw(raw.as_bytes())?;
        self.read_response()
    }

    pub fn get(&mut self, path: &str) -> Result<HttpResponse, HttpError> {
        let raw = format!("GET {path} HTTP/1.1\r\nHost: scenario\r\n\r\n");
        self.send_raw(raw.as_bytes())?;
        self.read_response()
    }

    /// Write raw bytes (a hand-built request, or a deliberately broken one).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<(), HttpError> {
        self.stream.write_all(bytes).map_err(|e| map_io(e, "send"))
    }

    /// Read one full response (head + `Content-Length` body).
    pub fn read_response(&mut self) -> Result<HttpResponse, HttpError> {
        // Head.
        let head_end = loop {
            if let Some(pos) = find_head_end(&self.buf) {
                break pos;
            }
            self.fill("headers")?;
        };
        let head = String::from_utf8_lossy(&self.buf[..head_end]).to_string();
        let status = parse_status(&head)?;
        let close = head
            .to_ascii_lowercase()
            .contains("connection: close");
        let content_length = parse_content_length(&head)?;

        // Body.
        let body_start = head_end + 4;
        while self.buf.len() < body_start + content_length {
            self.fill("body")?;
        }
        let body =
            String::from_utf8_lossy(&self.buf[body_start..body_start + content_length]).to_string();
        self.buf.drain(..body_start + content_length);
        Ok(HttpResponse {
            status,
            head,
            body,
            close,
        })
    }

    fn fill(&mut self, phase: &'static str) -> Result<(), HttpError> {
        let mut tmp = [0u8; 16 * 1024];
        match self.stream.read(&mut tmp) {
            Ok(0) => Err(HttpError::Closed(phase)),
            Ok(n) => {
                self.buf.extend_from_slice(&tmp[..n]);
                Ok(())
            }
            Err(e) => Err(map_io_phase(e, phase)),
        }
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn parse_status(head: &str) -> Result<u16, HttpError> {
    let line = head.lines().next().unwrap_or("");
    line.split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| HttpError::Malformed(format!("status line '{line}'")))
}

fn parse_content_length(head: &str) -> Result<usize, HttpError> {
    for line in head.lines().skip(1) {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                return value
                    .trim()
                    .parse()
                    .map_err(|_| HttpError::Malformed(format!("content-length '{value}'")));
            }
        }
    }
    Err(HttpError::Malformed("no content-length".into()))
}

fn map_io(e: std::io::Error, phase: &'static str) -> HttpError {
    map_io_phase(e, phase)
}

fn map_io_phase(e: std::io::Error, phase: &'static str) -> HttpError {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => HttpError::Timeout(phase),
        ErrorKind::UnexpectedEof | ErrorKind::ConnectionReset | ErrorKind::BrokenPipe
        | ErrorKind::ConnectionAborted => HttpError::Closed(phase),
        _ => HttpError::Io(e.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpListener;

    fn serve_once(payload: &'static [u8], shutdown_after: bool) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // Consume the request head so the client's send completes.
            let mut sink = [0u8; 4096];
            let _ = s.read(&mut sink);
            s.write_all(payload).unwrap();
            if shutdown_after {
                let _ = s.shutdown(std::net::Shutdown::Both);
            } else {
                // Hold the connection open, sending nothing more.
                std::thread::sleep(Duration::from_secs(5));
            }
        });
        addr
    }

    #[test]
    fn read_timeout_is_typed_not_a_hang() {
        // Headers promise 10 body bytes; none ever arrive.
        let addr = serve_once(
            b"HTTP/1.1 200 OK\r\nContent-Length: 10\r\nConnection: keep-alive\r\n\r\n",
            false,
        );
        let mut c = HttpConn::connect(addr, Duration::from_millis(200)).unwrap();
        let t0 = std::time::Instant::now();
        let err = c.get("/x").unwrap_err();
        assert_eq!(err, HttpError::Timeout("body"));
        assert!(t0.elapsed() < Duration::from_secs(3), "did not hang");
    }

    #[test]
    fn mid_response_drop_is_typed() {
        // Half the promised body, then a hard close.
        let addr = serve_once(
            b"HTTP/1.1 200 OK\r\nContent-Length: 10\r\nConnection: keep-alive\r\n\r\nhello",
            true,
        );
        let mut c = HttpConn::connect(addr, Duration::from_secs(2)).unwrap();
        assert_eq!(c.get("/x").unwrap_err(), HttpError::Closed("body"));
    }

    #[test]
    fn garbage_is_malformed() {
        let addr = serve_once(b"NOT HTTP AT ALL\r\n\r\n", true);
        let mut c = HttpConn::connect(addr, Duration::from_secs(2)).unwrap();
        assert!(matches!(c.get("/x"), Err(HttpError::Malformed(_))));
    }

    #[test]
    fn keep_alive_roundtrips_and_close_flag() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut sink = [0u8; 4096];
            let _ = s.read(&mut sink);
            // Two pipelined responses in one write: the client must not
            // lose the second one's bytes.
            s.write_all(
                b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\nConnection: keep-alive\r\n\r\nok\
                  HTTP/1.1 429 Too Many Requests\r\nContent-Length: 4\r\nConnection: close\r\n\r\nshed",
            )
            .unwrap();
        });
        let mut c = HttpConn::connect(addr, Duration::from_secs(2)).unwrap();
        let r1 = c.get("/a").unwrap();
        assert_eq!((r1.status, r1.body.as_str(), r1.close), (200, "ok", false));
        let r2 = c.read_response().unwrap();
        assert_eq!((r2.status, r2.body.as_str(), r2.close), (429, "shed", true));
    }
}
