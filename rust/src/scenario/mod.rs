//! Open-loop scenario engine: trace-driven traffic against the real HTTP
//! server, with a declarative scenario matrix and invariant-checked live
//! reconfiguration (ROADMAP Open item 5).
//!
//! The two seed workloads replay closed-loop — each client waits for its
//! previous response before sending the next request — which makes a
//! melting server *reduce* its own offered load and hide queueing
//! collapse. This engine is open-loop: [`arrivals::ArrivalProcess`]
//! fixes every request's send time up front (homogeneous Poisson or a
//! diurnal-burst cycle), [`traffic::Trace`] binds each arrival to a
//! tenant/user/service-type/prompt draw (heavy-tailed prompt lengths via
//! [`traffic::bounded_pareto`]), and [`runner::run_scenario`] drives the
//! schedule over keep-alive connections, measuring every latency from
//! the *scheduled* arrival — so shed decisions and queue growth appear
//! in p99 instead of silently stretching the clock (no coordinated
//! omission; the `run_open_loop` idiom from `benches/throughput.rs`
//! generalized to traces, tenants, and both server backends).
//!
//! The standing matrix ([`runner::default_matrix`]) covers underload,
//! diurnal-burst overload with shedding, a tripped per-model breaker,
//! cache-cold vs cache-warm, two-node replication, and the live
//! reconfiguration drill: `POST /admin/config {"generation": ...}` swaps
//! the model pool under load while an invariant checker classifies every
//! response by the generations of its `metadata.models_used` — a mixed
//! response would mean a half-applied config and fails the suite
//! ([`runner::InvariantReport`]). Results are reported per scenario as
//! p50/p99, cost per 1k requests, cache hit rate, shed rate by reason,
//! and SLO violations during the cutover window
//! ([`runner::ScenarioOutcome`]) — `benches/scenarios.rs` writes them to
//! `BENCH_scenarios.json`, and `tests/scenarios.rs` CI-gates the whole
//! matrix in smoke mode on both server backends.
//!
//! Everything stochastic forks from one seed ([`crate::util::rng::Rng`]),
//! so a trace is byte-reproducible across processes
//! (`tests/workload_determinism.rs` diffs fingerprints via the
//! `llmbridge trace` subcommand).

pub mod arrivals;
pub mod http;
pub mod runner;
pub mod traffic;

pub use arrivals::ArrivalProcess;
pub use http::{HttpConn, HttpError, HttpResponse};
pub use runner::{
    calibrate_rps, default_matrix, run_matrix, run_scenario, ArrivalShape, InvariantReport,
    ReconfigSpec, RunOptions, Scenario, ScenarioOutcome,
};
pub use traffic::{
    bounded_pareto, cacheable_tenants, delegated_tenants, standard_tenants, tenants_fingerprint,
    TenantSpec, Trace, TraceEvent,
};
