//! Scenario definitions and the open-loop runner.
//!
//! A [`Scenario`] is declarative: an arrival shape (as multiples of the
//! machine's *calibrated* closed-loop capacity, so the same matrix
//! stresses a laptop and a CI runner equally), a tenant set, and the
//! fault/operation to exercise (shed watermark, breaker trip, cache
//! warm-up, two-node sync, live reconfiguration). [`run_scenario`] builds
//! a fresh [`Bridge`] + [`Server`] per scenario, generates the
//! deterministic [`Trace`], and drives it over keep-alive connections
//! with scheduled-arrival latency accounting — each request's latency is
//! measured from its *scheduled* send time, so server-induced queueing
//! shows up in p99 instead of silently stretching the load clock
//! (the `run_open_loop` idiom from `benches/throughput.rs`, generalized).
//!
//! **The reconfiguration invariant.** The `reconfig` scenario swaps the
//! model-pool generation via `POST /admin/config {"generation": ...}`
//! mid-run. Every 200 response is classified by the generations of its
//! `metadata.models_used`: with generation-delegated tenants, a response
//! must be *entirely* old-pool or *entirely* new-pool. A single response
//! mixing the two would mean a request observed a half-applied config —
//! [`InvariantReport::mixed`] counts exactly that and must be zero
//! (asserted by `tests/scenarios.rs`), while `old_only`/`new_only` both
//! being positive proves the cutover actually happened under load.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{Bridge, BridgeConfig};
use crate::models::pricing::{Generation, ModelId};
use crate::runtime::EngineHandle;
use crate::server::{Server, ServerBackend, ServerConfig};
use crate::util::json::Json;

use super::arrivals::ArrivalProcess;
use super::http::{HttpConn, HttpError, HttpResponse};
use super::traffic::{TenantSpec, Trace};

/// Arrival shape in multiples of calibrated closed-loop capacity.
#[derive(Clone, Debug)]
pub enum ArrivalShape {
    Poisson { mult: f64 },
    DiurnalBurst { base_mult: f64, peak_mult: f64 },
}

/// Live-reconfiguration step: POST `body` to `/admin/config` at
/// `at_frac` of the run, then watch SLO compliance in a window of
/// `window_frac` around the cutover.
#[derive(Clone, Debug)]
pub struct ReconfigSpec {
    pub at_frac: f64,
    pub window_frac: f64,
    pub body: String,
}

/// One declarative scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub shape: ArrivalShape,
    pub tenants: Vec<TenantSpec>,
    /// Pre-seed the exact cache with every trace prompt.
    pub warm_cache: bool,
    /// Trip this model's breaker open before traffic starts.
    pub trip_breaker: Option<ModelId>,
    /// Swap config under load.
    pub reconfig: Option<ReconfigSpec>,
    /// Replicate node A's cache to a fresh node B after the run.
    pub two_node: bool,
    /// Override the server's shed watermark (`None` = default 512).
    pub shed_watermark: Option<usize>,
    pub slo_ms: u64,
    pub start_generation: Generation,
}

impl Scenario {
    fn base(name: &'static str, shape: ArrivalShape, tenants: Vec<TenantSpec>) -> Scenario {
        Scenario {
            name,
            shape,
            tenants,
            warm_cache: false,
            trip_breaker: None,
            reconfig: None,
            two_node: false,
            shed_watermark: None,
            slo_ms: 250,
            start_generation: Generation::New,
        }
    }
}

/// The standing matrix: every operational regime the proxy claims to
/// handle, each CI-gated in smoke mode (`tests/scenarios.rs`) and
/// measured at full size by `benches/scenarios.rs`.
pub fn default_matrix() -> Vec<Scenario> {
    use super::traffic::{cacheable_tenants, delegated_tenants, standard_tenants};
    vec![
        Scenario::base(
            "underload",
            ArrivalShape::Poisson { mult: 0.5 },
            standard_tenants(),
        ),
        Scenario {
            shed_watermark: Some(1),
            ..Scenario::base(
                "overload_shed",
                ArrivalShape::DiurnalBurst {
                    base_mult: 0.5,
                    peak_mult: 4.0,
                },
                standard_tenants(),
            )
        },
        Scenario {
            trip_breaker: Some(ModelId::SonarHugeOnline),
            ..Scenario::base(
                "breaker_trip",
                ArrivalShape::Poisson { mult: 0.5 },
                standard_tenants(),
            )
        },
        Scenario::base(
            "cache_cold",
            ArrivalShape::Poisson { mult: 0.5 },
            cacheable_tenants(),
        ),
        Scenario {
            warm_cache: true,
            ..Scenario::base(
                "cache_warm",
                ArrivalShape::Poisson { mult: 0.5 },
                cacheable_tenants(),
            )
        },
        Scenario {
            warm_cache: true,
            two_node: true,
            ..Scenario::base(
                "two_node_sync",
                ArrivalShape::Poisson { mult: 0.5 },
                cacheable_tenants(),
            )
        },
        Scenario {
            start_generation: Generation::Old,
            reconfig: Some(ReconfigSpec {
                at_frac: 0.4,
                window_frac: 0.15,
                body: r#"{"generation":"new"}"#.into(),
            }),
            ..Scenario::base(
                "reconfig",
                ArrivalShape::Poisson { mult: 0.7 },
                delegated_tenants(),
            )
        },
    ]
}

/// Runner knobs shared by every scenario in one invocation.
#[derive(Clone, Debug)]
pub struct RunOptions {
    pub backend: ServerBackend,
    /// Reduced-corpus mode for CI: shorter runs, capped event counts.
    pub smoke: bool,
    pub seed: u64,
}

impl RunOptions {
    pub fn new(backend: ServerBackend, smoke: bool) -> RunOptions {
        RunOptions {
            backend,
            smoke,
            seed: 0x5eed_0010,
        }
    }

    fn duration(&self) -> Duration {
        if self.smoke {
            Duration::from_millis(1000)
        } else {
            Duration::from_secs(5)
        }
    }

    fn conns(&self) -> usize {
        if self.smoke {
            6
        } else {
            8
        }
    }

    fn max_events(&self) -> usize {
        if self.smoke {
            240
        } else {
            4000
        }
    }

    fn min_events(&self) -> usize {
        if self.smoke {
            60
        } else {
            400
        }
    }

    fn calibration_requests(&self) -> usize {
        if self.smoke {
            60
        } else {
            200
        }
    }

    fn read_timeout(&self) -> Duration {
        Duration::from_secs(5)
    }
}

/// Old-or-new classification of one response's `models_used`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum GenClass {
    /// No billed models (pure cache hit) — trivially consistent.
    CacheOnly,
    Old,
    New,
    /// Models from both generations in one response: the invariant
    /// violation the reconfig scenario exists to rule out.
    Mixed,
}

/// Per-response snapshot-consistency tally for the reconfig scenario.
#[derive(Clone, Copy, Debug, Default)]
pub struct InvariantReport {
    pub checked: u64,
    pub old_only: u64,
    pub new_only: u64,
    pub cache_only: u64,
    /// Must be zero: responses mixing old- and new-generation models.
    pub mixed: u64,
}

/// Everything a scenario run measured.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    pub name: String,
    pub offered_rps: f64,
    pub scheduled: u64,
    pub served: u64,
    pub shed: u64,
    pub transport_errors: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub slo_ms: u64,
    pub slo_violations: u64,
    pub cost_per_1k_usd: f64,
    pub cache_hit_rate: f64,
    pub shed_by_reason: BTreeMap<String, u64>,
    pub invariant: Option<InvariantReport>,
    pub cutover_slo_violations: Option<u64>,
    pub reconfig_applied: Option<bool>,
    pub sync_applied: Option<u64>,
}

impl ScenarioOutcome {
    pub fn shed_rate(&self) -> f64 {
        if self.scheduled == 0 {
            0.0
        } else {
            self.shed as f64 / self.scheduled as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("offered_rps", Json::num(self.offered_rps)),
            ("scheduled", Json::num(self.scheduled as f64)),
            ("served", Json::num(self.served as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("shed_rate", Json::num(self.shed_rate())),
            ("transport_errors", Json::num(self.transport_errors as f64)),
            ("p50_us", Json::num(self.p50_us as f64)),
            ("p99_us", Json::num(self.p99_us as f64)),
            ("slo_ms", Json::num(self.slo_ms as f64)),
            ("slo_violations", Json::num(self.slo_violations as f64)),
            ("cost_per_1k_usd", Json::num(self.cost_per_1k_usd)),
            ("cache_hit_rate", Json::num(self.cache_hit_rate)),
            (
                "shed_by_reason",
                Json::obj(
                    self.shed_by_reason
                        .iter()
                        .map(|(k, v)| (k.as_str(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
        ];
        if let Some(inv) = &self.invariant {
            pairs.push((
                "invariant",
                Json::obj(vec![
                    ("checked", Json::num(inv.checked as f64)),
                    ("old_only", Json::num(inv.old_only as f64)),
                    ("new_only", Json::num(inv.new_only as f64)),
                    ("cache_only", Json::num(inv.cache_only as f64)),
                    ("mixed", Json::num(inv.mixed as f64)),
                ]),
            ));
        }
        if let Some(v) = self.cutover_slo_violations {
            pairs.push(("cutover_slo_violations", Json::num(v as f64)));
        }
        if let Some(ok) = self.reconfig_applied {
            pairs.push(("reconfig_applied", Json::Bool(ok)));
        }
        if let Some(n) = self.sync_applied {
            pairs.push(("sync_applied", Json::num(n as f64)));
        }
        Json::obj(pairs)
    }
}

/// One record per scheduled request.
struct Sample {
    /// Scheduled offset from trace start.
    at: Duration,
    /// Measured from the *scheduled* send time.
    lat_us: u64,
    /// HTTP status; 0 = transport error.
    status: u16,
    reason: Option<String>,
    cost_usd: f64,
    cache_hit: bool,
    gen: GenClass,
}

/// A keep-alive connection that transparently reconnects after a
/// `Connection: close` (the threaded backend closes after every request)
/// or a typed transport error.
struct Client {
    addr: std::net::SocketAddr,
    timeout: Duration,
    conn: Option<HttpConn>,
}

impl Client {
    fn new(addr: std::net::SocketAddr, timeout: Duration) -> Client {
        Client {
            addr,
            timeout,
            conn: None,
        }
    }

    fn post(&mut self, path: &str, body: &str) -> Result<HttpResponse, HttpError> {
        if self.conn.is_none() {
            self.conn = Some(HttpConn::connect(self.addr, self.timeout)?);
        }
        let conn = self.conn.as_mut().expect("connected above");
        match conn.post(path, body) {
            Ok(resp) => {
                if resp.close {
                    self.conn = None;
                }
                Ok(resp)
            }
            Err(e) => {
                self.conn = None;
                Err(e)
            }
        }
    }
}

/// Measure closed-loop capacity (req/s) for this backend so scenario
/// rates scale to the machine: a couple of connections issuing cheap
/// `cost`-type requests back to back against a default-tuned server.
pub fn calibrate_rps(engine: &EngineHandle, opts: &RunOptions) -> Result<f64> {
    let bridge = Arc::new(Bridge::from_engine(
        engine.clone(),
        BridgeConfig::default(),
    )?);
    let server = Server::start_with(
        bridge,
        "127.0.0.1:0",
        ServerConfig {
            workers: 2,
            backend: opts.backend,
            ..ServerConfig::default()
        },
    )?;
    let addr = server.addr;
    let per_conn = opts.calibration_requests();
    let timeout = opts.read_timeout();
    let t0 = Instant::now();
    let total: usize = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|c| {
                s.spawn(move || {
                    let mut client = Client::new(addr, timeout);
                    let mut done = 0usize;
                    for i in 0..per_conn {
                        let body = format!(
                            r#"{{"user":"cal-{c}","conversation":"cal","prompt":"calibration probe {c}-{i}","service_type":{{"name":"cost"}},"update_context":false}}"#
                        );
                        if client.post("/v1/request", &body).is_ok() {
                            done += 1;
                        }
                    }
                    done
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let elapsed = t0.elapsed().as_secs_f64().max(1e-3);
    server.stop();
    if total == 0 {
        bail!("calibration served no requests");
    }
    Ok(total as f64 / elapsed)
}

/// Run every scenario with one shared calibration. The usual entry point
/// for the bench and the smoke suite.
pub fn run_matrix(
    engine: &EngineHandle,
    scenarios: &[Scenario],
    opts: &RunOptions,
) -> Result<Vec<ScenarioOutcome>> {
    let base_rps = calibrate_rps(engine, opts)?;
    scenarios
        .iter()
        .map(|sc| run_scenario(engine, sc, opts, base_rps))
        .collect()
}

/// Run one scenario against a fresh bridge + server.
pub fn run_scenario(
    engine: &EngineHandle,
    sc: &Scenario,
    opts: &RunOptions,
    base_rps: f64,
) -> Result<ScenarioOutcome> {
    let duration = opts.duration();
    let arrivals = build_arrivals(sc, opts, base_rps, duration);

    let trace = Trace::generate(
        opts.seed ^ crate::util::fnv1a(sc.name.as_bytes()),
        &sc.tenants,
        &arrivals,
        duration,
    );

    let bridge_config = BridgeConfig {
        generation: sc.start_generation,
        node_id: if sc.two_node {
            Some("scn-a".to_string())
        } else {
            None
        },
        breaker: crate::ops::BreakerConfig {
            // Long cooldown: a manually tripped breaker must stay open
            // for the whole run instead of half-open-probing shut.
            cooldown: Duration::from_secs(120),
            ..crate::ops::BreakerConfig::default()
        },
        ..BridgeConfig::default()
    };
    let bridge = Arc::new(Bridge::from_engine(engine.clone(), bridge_config)?);

    if sc.warm_cache {
        for prompt in trace.unique_prompts() {
            bridge.cache().put_exact(prompt, "warm: prefetched answer");
        }
    }
    if let Some(model) = sc.trip_breaker {
        let threshold = bridge.breaker().config().threshold;
        for _ in 0..threshold {
            bridge.breaker().record_failure(model.as_str());
        }
    }

    // Node A's sync listener, when replicating.
    let mut sync_service = if sc.two_node {
        Some(crate::sync::SyncService::start(
            bridge.clone(),
            crate::sync::SyncConfig {
                node_id: "scn-a".to_string(),
                listen_port: Some(0),
                peer: None,
                interval: Duration::from_secs(3600),
            },
        )?)
    } else {
        None
    };

    let server = Server::start_with(
        bridge.clone(),
        "127.0.0.1:0",
        ServerConfig {
            workers: 2,
            shed_watermark: sc.shed_watermark.unwrap_or(512),
            backend: opts.backend,
            admin_bind: Some("127.0.0.1:0".to_string()),
            ..ServerConfig::default()
        },
    )?;
    let addr = server.addr;
    let admin_addr = server
        .admin_addr
        .context("admin listener required for scenarios")?;

    // Drive the trace: round-robin events over keep-alive connections,
    // each sent at its scheduled offset.
    let conns = opts.conns();
    let timeout = opts.read_timeout();
    let reconfig_applied = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let mut samples: Vec<Sample> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..conns {
            let events = &trace.events;
            handles.push(s.spawn(move || {
                let mut client = Client::new(addr, timeout);
                let mut out = Vec::new();
                for ev in events.iter().skip(c).step_by(conns) {
                    let sched = t0 + ev.at;
                    let now = Instant::now();
                    if sched > now {
                        std::thread::sleep(sched - now);
                    }
                    let result = client.post("/v1/request", &ev.body);
                    let lat_us = Instant::now().duration_since(sched).as_micros() as u64;
                    out.push(classify(ev.at, lat_us, result));
                }
                out
            }));
        }
        if let Some(rc) = &sc.reconfig {
            let body = rc.body.clone();
            let at = duration.mul_f64(rc.at_frac);
            let applied = reconfig_applied.clone();
            handles.push(s.spawn(move || {
                let sched = t0 + at;
                let now = Instant::now();
                if sched > now {
                    std::thread::sleep(sched - now);
                }
                let mut admin = Client::new(admin_addr, timeout);
                if let Ok(resp) = admin.post("/admin/config", &body) {
                    if resp.status == 200 {
                        applied.store(true, Ordering::Release);
                    }
                }
                Vec::new()
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("driver thread"))
            .collect()
    });
    samples.sort_by_key(|s| s.at);

    // After the run: replicate node A's corpus to a fresh node B and
    // count the entries B applied.
    let sync_applied = if sc.two_node {
        let listen = await_listen_addr(sync_service.as_ref().expect("two_node sync service"))?;
        let bridge_b = Bridge::from_engine(
            engine.clone(),
            BridgeConfig {
                generation: sc.start_generation,
                node_id: Some("scn-b".to_string()),
                ..BridgeConfig::default()
            },
        )?;
        let report = crate::sync::run_once(&bridge_b, &listen.to_string())?;
        Some(report.applied as u64)
    } else {
        None
    };

    if let Some(svc) = sync_service.as_mut() {
        svc.stop();
    }
    server.stop();

    Ok(summarize(
        sc,
        &samples,
        duration,
        sc.reconfig.as_ref(),
        reconfig_applied.load(Ordering::Acquire),
        sync_applied,
    ))
}

fn build_arrivals(
    sc: &Scenario,
    opts: &RunOptions,
    base_rps: f64,
    duration: Duration,
) -> ArrivalProcess {
    let horizon = duration.as_secs_f64();
    let raw = match sc.shape {
        ArrivalShape::Poisson { mult } => ArrivalProcess::Poisson {
            rps: base_rps * mult,
        },
        ArrivalShape::DiurnalBurst {
            base_mult,
            peak_mult,
        } => ArrivalProcess::DiurnalBurst {
            base_rps: base_rps * base_mult,
            peak_rps: base_rps * peak_mult,
            period: duration,
        },
    };
    // Bound the schedule so a fast machine doesn't explode the event
    // count (nor a slow one starve the statistics). Scaling the rate
    // keeps the *shape* (the overload multiple is relative to capacity;
    // the cap only bounds wall-clock work).
    let mean = raw.mean_rps().max(1e-9);
    let expected = mean * horizon;
    let factor = if expected > opts.max_events() as f64 {
        opts.max_events() as f64 / expected
    } else if expected < opts.min_events() as f64 {
        opts.min_events() as f64 / expected
    } else {
        1.0
    };
    match raw {
        ArrivalProcess::Poisson { rps } => ArrivalProcess::Poisson { rps: rps * factor },
        ArrivalProcess::DiurnalBurst {
            base_rps,
            peak_rps,
            period,
        } => ArrivalProcess::DiurnalBurst {
            base_rps: base_rps * factor,
            peak_rps: peak_rps * factor,
            period,
        },
    }
}

/// Poll the sync service until its accept thread has bound.
fn await_listen_addr(svc: &crate::sync::SyncService) -> Result<std::net::SocketAddr> {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if let Some(addr) = svc.listen_addr() {
            return Ok(addr);
        }
        if Instant::now() > deadline {
            bail!("sync listener did not bind");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Turn one roundtrip result into a sample, parsing the wire-visible
/// metadata (cost, cache outcome, models used) on success and the typed
/// shed reason on 429/503.
fn classify(at: Duration, lat_us: u64, result: Result<HttpResponse, HttpError>) -> Sample {
    let mut sample = Sample {
        at,
        lat_us,
        status: 0,
        reason: None,
        cost_usd: 0.0,
        cache_hit: false,
        gen: GenClass::CacheOnly,
    };
    let resp = match result {
        Ok(r) => r,
        Err(e) => {
            sample.reason = Some(format!("transport:{e}"));
            return sample;
        }
    };
    sample.status = resp.status;
    let Ok(j) = Json::parse(&resp.body) else {
        return sample;
    };
    if resp.status == 200 {
        if let Some(meta) = j.get("metadata") {
            sample.cost_usd = meta.get("cost_usd").and_then(|v| v.as_f64()).unwrap_or(0.0);
            sample.cache_hit = match meta.get("cache") {
                Some(Json::Str(s)) => s == "exact_hit",
                // Semantic hits serialize as {"kind":"semantic_hit",...}.
                Some(obj) => obj
                    .get("kind")
                    .and_then(|k| k.as_str())
                    .map(|k| k == "semantic_hit")
                    .unwrap_or(false),
                None => false,
            };
            sample.gen = classify_generations(meta.get("models_used"));
        }
    } else {
        sample.reason = j.get("reason").and_then(|r| r.as_str()).map(String::from);
    }
    sample
}

fn classify_generations(models_used: Option<&Json>) -> GenClass {
    let Some(Json::Arr(items)) = models_used else {
        return GenClass::CacheOnly;
    };
    let (mut old, mut new) = (false, false);
    for item in items {
        let Some(name) = item.get("model").and_then(|m| m.as_str()) else {
            return GenClass::Mixed; // unparseable entry: fail loud
        };
        match ModelId::parse(name) {
            Ok(m) => match m.spec().generation {
                Generation::Old => old = true,
                Generation::New => new = true,
            },
            Err(_) => return GenClass::Mixed,
        }
    }
    match (old, new) {
        (false, false) => GenClass::CacheOnly,
        (true, false) => GenClass::Old,
        (false, true) => GenClass::New,
        (true, true) => GenClass::Mixed,
    }
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn summarize(
    sc: &Scenario,
    samples: &[Sample],
    duration: Duration,
    reconfig: Option<&ReconfigSpec>,
    reconfig_applied: bool,
    sync_applied: Option<u64>,
) -> ScenarioOutcome {
    let scheduled = samples.len() as u64;
    let mut served = 0u64;
    let mut shed = 0u64;
    let mut transport_errors = 0u64;
    let mut shed_by_reason: BTreeMap<String, u64> = BTreeMap::new();
    let mut served_lat: Vec<u64> = Vec::new();
    let mut total_cost = 0.0f64;
    let mut hits = 0u64;
    let mut slo_violations = 0u64;
    let mut cutover_violations = 0u64;
    let mut inv = InvariantReport::default();
    let slo_us = sc.slo_ms * 1000;

    let cutover_window = reconfig.map(|rc| {
        let center = duration.mul_f64(rc.at_frac);
        let half = duration.mul_f64(rc.window_frac);
        (center.saturating_sub(half), center + half)
    });

    for s in samples {
        match s.status {
            200 => {
                served += 1;
                served_lat.push(s.lat_us);
                total_cost += s.cost_usd;
                if s.cache_hit {
                    hits += 1;
                }
                if s.lat_us > slo_us {
                    slo_violations += 1;
                    if let Some((lo, hi)) = cutover_window {
                        if s.at >= lo && s.at <= hi {
                            cutover_violations += 1;
                        }
                    }
                }
                if reconfig.is_some() {
                    inv.checked += 1;
                    match s.gen {
                        GenClass::Old => inv.old_only += 1,
                        GenClass::New => inv.new_only += 1,
                        GenClass::CacheOnly => inv.cache_only += 1,
                        GenClass::Mixed => inv.mixed += 1,
                    }
                }
            }
            429 | 503 => {
                shed += 1;
                let reason = s.reason.clone().unwrap_or_else(|| "unknown".into());
                *shed_by_reason.entry(reason).or_insert(0) += 1;
            }
            0 => transport_errors += 1,
            _ => {
                let reason = format!("http_{}", s.status);
                shed += 1;
                *shed_by_reason.entry(reason).or_insert(0) += 1;
            }
        }
    }
    served_lat.sort_unstable();

    ScenarioOutcome {
        name: sc.name.to_string(),
        offered_rps: scheduled as f64 / duration.as_secs_f64().max(1e-9),
        scheduled,
        served,
        shed,
        transport_errors,
        p50_us: percentile(&served_lat, 0.50),
        p99_us: percentile(&served_lat, 0.99),
        slo_ms: sc.slo_ms,
        slo_violations,
        cost_per_1k_usd: if served == 0 {
            0.0
        } else {
            total_cost / served as f64 * 1000.0
        },
        cache_hit_rate: if served == 0 {
            0.0
        } else {
            hits as f64 / served as f64
        },
        shed_by_reason,
        invariant: reconfig.map(|_| inv),
        cutover_slo_violations: reconfig.map(|_| cutover_violations),
        reconfig_applied: reconfig.map(|_| reconfig_applied),
        sync_applied,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_names_are_unique_and_cover_the_regimes() {
        let m = default_matrix();
        let names: Vec<&str> = m.iter().map(|s| s.name).collect();
        let set: std::collections::BTreeSet<&str> = names.iter().copied().collect();
        assert_eq!(set.len(), names.len(), "duplicate scenario names");
        for want in [
            "underload",
            "overload_shed",
            "breaker_trip",
            "cache_cold",
            "cache_warm",
            "two_node_sync",
            "reconfig",
        ] {
            assert!(set.contains(want), "matrix missing {want}");
        }
        let rc = m.iter().find(|s| s.name == "reconfig").unwrap();
        assert_eq!(rc.start_generation, Generation::Old);
        assert!(rc.reconfig.is_some());
    }

    #[test]
    fn generation_classification() {
        let arr = |names: &[&str]| {
            Json::Arr(
                names
                    .iter()
                    .map(|n| Json::obj(vec![("model", Json::str(*n)), ("role", Json::str("x"))]))
                    .collect(),
            )
        };
        assert_eq!(
            classify_generations(Some(&arr(&["gpt-4", "gpt-3.5-turbo"]))),
            GenClass::Old
        );
        assert_eq!(
            classify_generations(Some(&arr(&["gpt-4o-mini"]))),
            GenClass::New
        );
        assert_eq!(
            classify_generations(Some(&arr(&["gpt-4", "gpt-4o-mini"]))),
            GenClass::Mixed
        );
        assert_eq!(classify_generations(Some(&arr(&[]))), GenClass::CacheOnly);
        assert_eq!(classify_generations(None), GenClass::CacheOnly);
    }

    #[test]
    fn percentile_bounds() {
        assert_eq!(percentile(&[], 0.5), 0);
        let v = [1, 2, 3, 4, 100];
        assert_eq!(percentile(&v, 0.5), 3);
        assert_eq!(percentile(&v, 0.99), 100);
    }
}
