//! The traffic matrix: per-tenant service-type mixes and deterministic
//! trace generation.
//!
//! A [`TenantSpec`] is one application sharing the proxy: a user pool, an
//! arrival weight, and a mix over [`ServiceType`]s. [`Trace::generate`]
//! combines a tenant set with an arrival schedule into a sorted list of
//! fully serialized HTTP request bodies, each pinned to its scheduled
//! arrival offset. Everything derives from the seed — two builds of the
//! same trace are byte-identical, witnessed by [`Trace::fingerprint`]
//! (and cross-process by `llmbridge trace` + `tests/workload_determinism.rs`).
//!
//! Prompt lengths are heavy-tailed ([`bounded_pareto`] over word counts,
//! alpha ~1.15): most prompts are short, a few are hundreds of words —
//! the regime PAPERS.md's traffic-source paper warns about. Response
//! lengths are owned by the serving backend (the generator's per-model
//! decode lengths are themselves heavy-tailed across the pool); the trace
//! shapes the input side only.

use std::time::Duration;

use crate::api::{CachePolicy, Request, ServiceType};
use crate::models::pricing::ModelId;
use crate::util::rng::Rng;
use crate::util::{fnv1a, seed_of};

use super::arrivals::ArrivalProcess;

/// One application (tenant) sharing the proxy.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: &'static str,
    /// Distinct users in this tenant's pool (per-user serialization and
    /// quotas apply per user, so pool size shapes contention).
    pub users: usize,
    /// Relative share of total arrivals.
    pub weight: f64,
    /// Service-type mix, weighted; drawn independently per request.
    pub mix: Vec<(ServiceType, f64)>,
}

/// Sample from a bounded Pareto distribution via inverse transform:
/// heavy-tailed in `[xmin, xmax]` with tail index `alpha`.
pub fn bounded_pareto(rng: &mut Rng, alpha: f64, xmin: f64, xmax: f64) -> f64 {
    let u = rng.f64();
    let ratio = (xmin / xmax).powf(alpha);
    xmin / (1.0 - u * (1.0 - ratio)).powf(1.0 / alpha)
}

/// The standard tenant set: three applications whose mixes collectively
/// lower to all seven routing policies (Fixed, QualityMax, CostMin,
/// BudgetCap, LatencyClass, Allowlist, CascadeVerify).
pub fn standard_tenants() -> Vec<TenantSpec> {
    vec![
        TenantSpec {
            name: "chat",
            users: 12,
            weight: 3.0,
            mix: vec![
                (ServiceType::Quality, 0.2),
                (ServiceType::default(), 0.4), // model_selector cascade
                (ServiceType::LatencyFirst, 0.4),
            ],
        },
        TenantSpec {
            name: "classroom",
            users: 8,
            weight: 2.0,
            mix: vec![
                (
                    ServiceType::UsageBased {
                        allowed: vec![
                            ModelId::Gpt4oMini,
                            ModelId::Claude3Haiku,
                            ModelId::Llama38b,
                            ModelId::Phi3Mini,
                        ],
                        fallback: ModelId::Gpt4oMini,
                    },
                    0.6,
                ),
                (
                    ServiceType::Budget {
                        max_usd_per_mtok_in: 1.0,
                    },
                    0.4,
                ),
            ],
        },
        TenantSpec {
            name: "kb",
            users: 6,
            weight: 2.0,
            mix: vec![
                (
                    ServiceType::SmartCache {
                        model: ModelId::Phi3Mini,
                    },
                    0.3,
                ),
                (
                    ServiceType::SmartContext {
                        k: 3,
                        model: ModelId::Claude3Haiku,
                    },
                    0.2,
                ),
                (ServiceType::Cost, 0.3),
                (
                    ServiceType::Fixed {
                        model: ModelId::Gpt4oMini,
                        cache: CachePolicy::Auto,
                        context_k: 0,
                    },
                    0.2,
                ),
            ],
        },
    ]
}

/// Tenants restricted to generation-*delegated* service types (quality /
/// cost / budget / model_selector): every model in every response derives
/// from one `router::lower` call over one generation, so the
/// reconfiguration invariant — all of a response's models belong to a
/// single generation — is exact, with no pinned-model noise.
pub fn delegated_tenants() -> Vec<TenantSpec> {
    vec![
        TenantSpec {
            name: "delegated-scored",
            users: 10,
            weight: 1.0,
            mix: vec![
                (ServiceType::Quality, 0.3),
                (ServiceType::Cost, 0.4),
                (
                    ServiceType::Budget {
                        max_usd_per_mtok_in: 1.0,
                    },
                    0.3,
                ),
            ],
        },
        TenantSpec {
            name: "delegated-cascade",
            users: 10,
            weight: 1.0,
            mix: vec![(ServiceType::default(), 1.0)],
        },
    ]
}

/// Tenants whose lowered policies all consult the exact prefetch store,
/// for the cache-warm vs cache-cold pair.
pub fn cacheable_tenants() -> Vec<TenantSpec> {
    vec![TenantSpec {
        name: "buttons",
        users: 8,
        weight: 1.0,
        mix: vec![
            (
                ServiceType::Fixed {
                    model: ModelId::Gpt4oMini,
                    cache: CachePolicy::Auto,
                    context_k: 0,
                },
                0.5,
            ),
            (ServiceType::LatencyFirst, 0.25),
            (ServiceType::Cost, 0.25),
        ],
    }]
}

/// One scheduled request.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Scheduled arrival offset from the trace start.
    pub at: Duration,
    pub tenant: &'static str,
    pub user: String,
    pub prompt: String,
    /// The serialized `POST /v1/request` body.
    pub body: String,
}

/// A deterministic open-loop trace: events sorted by arrival offset.
#[derive(Debug)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    /// FNV-1a over every `(offset, body)` pair — byte-identical traces
    /// have equal fingerprints, and any drift in arrivals, tenant
    /// selection, or request serialization changes it.
    pub fingerprint: u64,
}

impl Trace {
    /// Build the trace for one scenario run. All randomness forks off
    /// `seed`; the arrival schedule and the per-event draws use
    /// independent streams so adding tenants never perturbs arrivals.
    pub fn generate(
        seed: u64,
        tenants: &[TenantSpec],
        arrivals: &ArrivalProcess,
        duration: Duration,
    ) -> Trace {
        let mut root = Rng::new(seed);
        let mut sched_rng = root.fork(1);
        let mut pick_rng = root.fork(2);
        let mut len_rng = root.fork(3);

        let offsets = arrivals.schedule(duration, &mut sched_rng);
        let total_weight: f64 = tenants.iter().map(|t| t.weight).sum();
        let mut events = Vec::with_capacity(offsets.len());
        for (i, at) in offsets.into_iter().enumerate() {
            let tenant = pick_weighted(&mut pick_rng, tenants, total_weight);
            let user_idx = pick_rng.below(tenant.users.max(1));
            let user = format!("{}-u{user_idx}", tenant.name);
            let mix_total: f64 = tenant.mix.iter().map(|(_, w)| w).sum();
            let st = pick_mix(&mut pick_rng, &tenant.mix, mix_total);
            let words = bounded_pareto(&mut len_rng, 1.15, 6.0, 120.0) as usize;
            let prompt = synth_prompt(tenant.name, i, words, &mut len_rng);
            let req = Request::new(&user, "scn", &prompt)
                .service_type(st.clone())
                .no_context_update();
            events.push(TraceEvent {
                at,
                tenant: tenant.name,
                user,
                prompt,
                body: req.to_json().to_string(),
            });
        }

        let mut acc = String::new();
        for ev in &events {
            acc.push_str(&ev.at.as_micros().to_string());
            acc.push('|');
            acc.push_str(&ev.body);
            acc.push('\n');
        }
        Trace {
            fingerprint: fnv1a(acc.as_bytes()),
            events,
        }
    }

    /// Distinct prompts, for pre-warming the exact cache.
    pub fn unique_prompts(&self) -> Vec<&str> {
        let mut seen = std::collections::BTreeSet::new();
        self.events
            .iter()
            .filter(|e| seen.insert(e.prompt.as_str()))
            .map(|e| e.prompt.as_str())
            .collect()
    }
}

fn pick_weighted<'a>(
    rng: &mut Rng,
    tenants: &'a [TenantSpec],
    total: f64,
) -> &'a TenantSpec {
    let mut x = rng.f64() * total;
    for t in tenants {
        x -= t.weight;
        if x <= 0.0 {
            return t;
        }
    }
    tenants.last().expect("non-empty tenant set")
}

fn pick_mix<'a>(
    rng: &mut Rng,
    mix: &'a [(ServiceType, f64)],
    total: f64,
) -> &'a ServiceType {
    let mut x = rng.f64() * total;
    for (st, w) in mix {
        x -= w;
        if x <= 0.0 {
            return st;
        }
    }
    &mix.last().expect("non-empty mix").0
}

/// Deterministic word-salad prompt of roughly `words` words. The leading
/// `tenant qN` token keeps every event's prompt unique (cold runs see no
/// accidental repeats; warm runs seed the exact store from the trace).
fn synth_prompt(tenant: &str, idx: usize, words: usize, rng: &mut Rng) -> String {
    const VOCAB: [&str; 24] = [
        "explain", "the", "difference", "between", "protocol", "cache",
        "latency", "model", "cost", "summarize", "compare", "quantum",
        "gateway", "token", "budget", "capital", "history", "of",
        "transformer", "network", "overview", "tradeoffs", "in", "practice",
    ];
    let mut p = format!("{tenant} q{idx}:");
    for _ in 0..words.max(1) {
        p.push(' ');
        p.push_str(VOCAB[rng.below(VOCAB.len())]);
    }
    p
}

/// Fingerprint a tenant set (the `llmbridge trace` CLI surfaces this so
/// the cross-process determinism test can diff it).
pub fn tenants_fingerprint(tenants: &[TenantSpec]) -> u64 {
    let mut acc = String::new();
    for t in tenants {
        acc.push_str(t.name);
        acc.push('|');
        acc.push_str(&t.users.to_string());
        acc.push('|');
        acc.push_str(&t.weight.to_bits().to_string());
        for (st, w) in &t.mix {
            acc.push('|');
            acc.push_str(&st.to_json().to_string());
            acc.push('|');
            acc.push_str(&w.to_bits().to_string());
        }
        acc.push('\n');
    }
    seed_of(&[&acc])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    fn trace(seed: u64) -> Trace {
        Trace::generate(
            seed,
            &standard_tenants(),
            &ArrivalProcess::Poisson { rps: 400.0 },
            Duration::from_secs(1),
        )
    }

    #[test]
    fn same_seed_same_fingerprint() {
        let (a, b) = (trace(42), trace(42));
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.events.len(), b.events.len());
        assert_ne!(trace(43).fingerprint, a.fingerprint);
    }

    #[test]
    fn standard_mix_covers_every_service_type_family() {
        let t = trace(7);
        let names: BTreeSet<&str> = standard_tenants()
            .iter()
            .flat_map(|t| t.mix.iter().map(|(st, _)| st.name()))
            .collect();
        // All seven routing policies: fixed→Fixed, quality→QualityMax,
        // cost→CostMin, budget→BudgetCap, latency_first→LatencyClass,
        // usage_based→Allowlist, model_selector→CascadeVerify (plus the
        // smart_* types, which lower to Fixed routing).
        for want in [
            "fixed",
            "quality",
            "cost",
            "budget",
            "latency_first",
            "usage_based",
            "model_selector",
            "smart_cache",
            "smart_context",
        ] {
            assert!(names.contains(want), "mix missing {want}");
        }
        assert!(t.events.len() > 100);
    }

    #[test]
    fn pareto_lengths_bounded_and_skewed() {
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..20_000)
            .map(|_| bounded_pareto(&mut rng, 1.15, 6.0, 120.0))
            .collect();
        assert!(xs.iter().all(|&x| (6.0..=120.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[xs.len() / 2];
        // Heavy tail: mean well above median, and the max stretches out.
        assert!(mean > 1.25 * median, "mean={mean} median={median}");
        assert!(*sorted.last().unwrap() > 80.0);
    }

    #[test]
    fn prompts_unique_and_bodies_parse_back() {
        let t = trace(9);
        assert_eq!(t.unique_prompts().len(), t.events.len());
        let j = crate::util::json::Json::parse(&t.events[0].body).unwrap();
        let req = Request::from_json(&j).unwrap();
        assert!(!req.update_context);
        assert_eq!(req.prompt, t.events[0].prompt);
    }
}
