//! Arrival processes for the open-loop engine.
//!
//! An arrival process turns a duration into a deterministic list of
//! *scheduled* arrival offsets — the driver sends each request at its
//! offset regardless of how the server is doing, and latency is measured
//! from the schedule, so a melting server cannot slow the clock down and
//! hide its own queueing delay (no coordinated omission).
//!
//! Two processes, per "Introducing LLMs as the Next Challenging Internet
//! Traffic Source" (PAPERS.md): homogeneous [`ArrivalProcess::Poisson`]
//! and the non-homogeneous [`ArrivalProcess::DiurnalBurst`], a compressed
//! "day" whose rate swings sinusoidally between a base and a peak
//! (sampled by Lewis thinning at the peak rate, so the realized process
//! is exactly Poisson with the time-varying intensity).

use std::time::Duration;

use crate::util::rng::Rng;

/// How request arrivals are distributed over a scenario's duration.
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson: exponential inter-arrival times at `rps`.
    Poisson { rps: f64 },
    /// Non-homogeneous Poisson: intensity swings from `base_rps` up to
    /// `peak_rps` and back over `period` (one compressed diurnal cycle),
    /// peaking mid-period.
    DiurnalBurst {
        base_rps: f64,
        peak_rps: f64,
        period: Duration,
    },
}

impl ArrivalProcess {
    /// Mean offered rate over one period, in requests per second.
    pub fn mean_rps(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rps } => *rps,
            // The sin^2 profile averages to the midpoint.
            ArrivalProcess::DiurnalBurst {
                base_rps, peak_rps, ..
            } => 0.5 * (base_rps + peak_rps),
        }
    }

    /// Deterministic arrival offsets in `[0, duration)`, sorted ascending.
    pub fn schedule(&self, duration: Duration, rng: &mut Rng) -> Vec<Duration> {
        let horizon = duration.as_secs_f64();
        let mut out = Vec::new();
        match self {
            ArrivalProcess::Poisson { rps } => {
                if *rps <= 0.0 {
                    return out;
                }
                let mut t = 0.0;
                loop {
                    t += exp_sample(rng, *rps);
                    if t >= horizon {
                        break;
                    }
                    out.push(Duration::from_secs_f64(t));
                }
            }
            ArrivalProcess::DiurnalBurst {
                base_rps,
                peak_rps,
                period,
            } => {
                let peak = peak_rps.max(*base_rps);
                if peak <= 0.0 {
                    return out;
                }
                let period = period.as_secs_f64().max(1e-6);
                // Lewis thinning: sample at the peak rate, accept with
                // probability rate(t)/peak.
                let mut t = 0.0;
                loop {
                    t += exp_sample(rng, peak);
                    if t >= horizon {
                        break;
                    }
                    let phase = (t / period) * std::f64::consts::TAU;
                    let rate =
                        base_rps + (peak - base_rps) * 0.5 * (1.0 - phase.cos());
                    if rng.f64() < rate / peak {
                        out.push(Duration::from_secs_f64(t));
                    }
                }
            }
        }
        out
    }
}

/// One exponential inter-arrival time at `rate` per second.
fn exp_sample(rng: &mut Rng, rate: f64) -> f64 {
    let u = rng.f64();
    -(1.0 - u).ln() / rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_count_near_expectation() {
        let p = ArrivalProcess::Poisson { rps: 500.0 };
        let n = p.schedule(Duration::from_secs(4), &mut Rng::new(7)).len();
        // 2000 expected, sd ~45; 5 sigma either way.
        assert!((1775..=2225).contains(&n), "n={n}");
    }

    #[test]
    fn schedules_are_deterministic_and_sorted() {
        let p = ArrivalProcess::DiurnalBurst {
            base_rps: 50.0,
            peak_rps: 400.0,
            period: Duration::from_secs(2),
        };
        let a = p.schedule(Duration::from_secs(2), &mut Rng::new(3));
        let b = p.schedule(Duration::from_secs(2), &mut Rng::new(3));
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert_ne!(a, p.schedule(Duration::from_secs(2), &mut Rng::new(4)));
    }

    #[test]
    fn diurnal_peak_denser_than_trough() {
        let p = ArrivalProcess::DiurnalBurst {
            base_rps: 20.0,
            peak_rps: 800.0,
            period: Duration::from_secs(4),
        };
        let sched = p.schedule(Duration::from_secs(4), &mut Rng::new(11));
        // Peak quarter is centered mid-period; trough quarter at the start.
        let trough = sched.iter().filter(|d| d.as_secs_f64() < 1.0).count();
        let peak = sched
            .iter()
            .filter(|d| (1.5..2.5).contains(&d.as_secs_f64()))
            .count();
        assert!(peak > 3 * trough.max(1), "peak={peak} trough={trough}");
    }
}
