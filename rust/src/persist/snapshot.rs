//! Snapshot directory format + the `CURRENT` generation pointer.
//!
//! A data directory holds one committed generation `N`:
//!
//! ```text
//! CURRENT          "N\n" — the committed generation (atomic rename swap)
//! snap-N/          absent for N == 0 (nothing compacted yet)
//!   MANIFEST.json  geometry + checksums the loader validates against
//!   kv.jsonl       KvStore::snapshot (history, profiles)
//!   vecdb.bin      AdaptiveIndex::save — bulk rows (pre-normalized):
//!                  LBV2 on the flat tier; LBV3 (rows + cell assignments
//!                  + trained centroids) on the IVF tier, so a restore of
//!                  a migrated cache never re-runs k-means; LBV4 on the
//!                  quantized tier (i8 codes, mmap'd lazily at boot).
//!                  LBV2 dirs written before the adaptive tier keep
//!                  loading.
//!   cache.jsonl    SemanticCache::snapshot_into — objects/keys/exact/meta
//!   state.jsonl    quota rows + exchange rows
//! wal-N.log        mutations since snap-N
//! ```
//!
//! Compaction writes the next generation into `snap-tmp`, renames it to
//! `snap-(N+1)`, creates `wal-(N+1).log`, and only then swaps `CURRENT`
//! (write-temp + rename, with directory fsyncs around the commit). A
//! crash anywhere before the swap leaves generation `N` fully intact;
//! stale `snap-tmp` / next-generation leftovers are clobbered by the next
//! attempt and GC'd at boot.
//!
//! ## vecdb.bin: LBV2 vs LBV3 vs LBV4
//!
//! The vector file is written by the adaptive index's `save`:
//!
//! * **LBV2** (flat tier): `"LBV2" [dim u32][metric u8][count u64]
//!   [ids: count×u64][rows: count×dim×f32]` — bulk pre-normalized rows;
//!   load rebuilds the index without re-inserting row by row.
//! * **LBV3** (IVF tier): LBV2's geometry plus the trained section (cell
//!   assignments + centroids) and an FNV-1a payload checksum, so a
//!   migrated cache restores **without re-running k-means**. See
//!   [`crate::vecdb::adaptive`] for the exact layout.
//! * **LBV4** (quantized IVF tier, at/above the cache's quantize
//!   threshold): the trained section with rows stored as i8 codes + one
//!   f32 scale per row. Byte layout:
//!
//!   ```text
//!   "LBV4"                          4-byte magic
//!   [dim       u32][metric u8]     geometry (as LBV2/LBV3)
//!   [count     u64]
//!   [nlist     u32][nprobe u32]    trained policy (as LBV3)
//!   [codes_off u64]                4096-aligned start of the code region
//!   [meta_crc  u64]                FNV-1a over ids…centroids below
//!   [codes_crc u64]                FNV-1a over the code region
//!   [ids         count×u64]        cell-grouped …
//!   [assignments count×u32]        … non-decreasing cell per row
//!   [scales      count×f32]        per-row dequantization scale
//!   [centroids   nlist×dim×f32]    trained coarse quantizer
//!   [zero-pad    to codes_off]
//!   [codes       count×dim×i8]     row-major, cell-contiguous
//!   ```
//!
//!   The code region — the bulk of the file — is **mmap'd, not read**, on
//!   unix: `restore_from_dir` returns after parsing + checksumming only
//!   the metadata, and queries fault code pages in on demand. `meta_crc`
//!   is verified on every load; `codes_crc` only where the bytes are read
//!   anyway (the non-unix eager fallback), since hashing the region at
//!   boot would defeat the laziness it exists for.
//!
//! Every version loads: an LBV2 file from an older generation boots as
//! the flat tier and re-migrates through normal maintenance; LBV4 is only
//! written once a corpus crosses the quantize threshold, so pre-LBV4
//! deployments keep producing snapshots older binaries can read.
//!
//! ## Capture consistency and restore validation
//!
//! The capture runs with the persist layer's gate held exclusively (all
//! journaled mutators hold it shared — lock order is documented in
//! `cache/mod.rs`), so `MANIFEST.json`'s counts and checksums describe
//! exactly the rows the files captured. Restore validates field by field
//! and goes through the cache's validated bulk load, which rebuilds the
//! id→slot map and shard placement and rejects dangling keys, orphan
//! vectors, duplicate ids, and a stale id allocator — any mismatch is
//! [`BridgeError::Persist`] (HTTP 500), never a silent partial boot. A
//! `LOCK` file (owner pid + /proc starttime, so pids recycled after a
//! reboot are reclaimed) refuses to share one data dir across processes.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::cache::SemanticCache;
use crate::error::BridgeError;
use crate::kvstore::KvStore;
use crate::util::json::Json;

const MANIFEST_VERSION: f64 = 1.0;

pub(crate) fn persist_err(what: &str, e: impl std::fmt::Display) -> BridgeError {
    BridgeError::Persist(format!("{what}: {e}"))
}

/// Snapshot geometry + checksums, written last into the snapshot dir and
/// validated field-by-field on restore.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub generation: u64,
    pub embed_dim: usize,
    pub objects: usize,
    pub keys: usize,
    pub exact: usize,
    pub next_id: u64,
    pub kv_len: usize,
    pub kv_checksum: u64,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(MANIFEST_VERSION)),
            ("generation", Json::num(self.generation as f64)),
            ("embed_dim", Json::num(self.embed_dim as f64)),
            ("objects", Json::num(self.objects as f64)),
            ("keys", Json::num(self.keys as f64)),
            ("exact", Json::num(self.exact as f64)),
            ("next_id", Json::num(self.next_id as f64)),
            ("kv_len", Json::num(self.kv_len as f64)),
            // Full-width u64: hex string, not a (lossy) JSON number.
            ("kv_checksum", Json::str(format!("{:016x}", self.kv_checksum))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Manifest, BridgeError> {
        let field = |k: &str| {
            j.f64_of(k)
                .map_err(|e| persist_err("snapshot MANIFEST", e))
        };
        if field("version")? != MANIFEST_VERSION {
            return Err(BridgeError::Persist(format!(
                "snapshot MANIFEST version {} unsupported (want {MANIFEST_VERSION})",
                field("version")?
            )));
        }
        let kv_checksum = u64::from_str_radix(
            &j.str_of("kv_checksum")
                .map_err(|e| persist_err("snapshot MANIFEST", e))?,
            16,
        )
        .map_err(|e| persist_err("snapshot MANIFEST kv_checksum", e))?;
        Ok(Manifest {
            generation: field("generation")? as u64,
            embed_dim: field("embed_dim")? as usize,
            objects: field("objects")? as usize,
            keys: field("keys")? as usize,
            exact: field("exact")? as usize,
            next_id: field("next_id")? as u64,
            kv_len: field("kv_len")? as usize,
            kv_checksum,
        })
    }
}

/// Per-user quota state row (absolute values, like the WAL op).
#[derive(Clone, Debug)]
pub struct QuotaRow {
    pub user: String,
    pub requests: u64,
    pub input_tokens: u64,
    pub output_tokens: u64,
}

/// A served exchange row; the request is kept in its REST JSON form.
#[derive(Clone, Debug)]
pub struct ExchangeRow {
    pub request_id: u64,
    pub regen_count: u32,
    pub request: Json,
}

/// Everything a snapshot restores (the WAL tail replays on top).
pub struct SnapshotState {
    pub kv: KvStore,
    pub cache: SemanticCache,
    pub quotas: Vec<QuotaRow>,
    pub exchanges: Vec<ExchangeRow>,
}

/// Counts the compaction capture hands back for the manifest.
pub struct CaptureCounts {
    pub objects: usize,
    pub keys: usize,
    pub exact: usize,
    pub next_id: u64,
    pub kv_len: usize,
    pub kv_checksum: u64,
}

// ------------------------------------------------------------- CURRENT

pub fn snap_dir(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("snap-{generation}"))
}

pub fn wal_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("wal-{generation}.log"))
}

/// The committed generation (0 when nothing was ever compacted).
pub fn read_current(dir: &Path) -> Result<u64, BridgeError> {
    let path = dir.join("CURRENT");
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
        Err(e) => return Err(persist_err("CURRENT read", e)),
    };
    text.trim()
        .parse::<u64>()
        .map_err(|e| persist_err(&format!("CURRENT parse '{}'", text.trim()), e))
}

/// fsync a directory so renames/creations/unlinks of its entries are
/// durable, not just the file contents (Linux semantics; best-effort
/// no-op where directories can't be opened).
pub fn sync_dir(dir: &Path) -> Result<(), BridgeError> {
    match std::fs::File::open(dir) {
        Ok(f) => f.sync_all().map_err(|e| persist_err("dir sync", e)),
        Err(_) => Ok(()),
    }
}

/// Atomically commit a new generation: write-temp, fsync, rename, then
/// fsync the directory so the rename itself is durable before callers
/// GC the superseded generation.
pub fn write_current(dir: &Path, generation: u64) -> Result<(), BridgeError> {
    let tmp = dir.join("CURRENT.tmp");
    let mut f = std::fs::File::create(&tmp).map_err(|e| persist_err("CURRENT.tmp", e))?;
    writeln!(f, "{generation}").map_err(|e| persist_err("CURRENT.tmp write", e))?;
    f.sync_all().map_err(|e| persist_err("CURRENT.tmp sync", e))?;
    std::fs::rename(&tmp, dir.join("CURRENT"))
        .map_err(|e| persist_err("CURRENT rename", e))?;
    sync_dir(dir)
}

// ------------------------------------------------------------ snapshot

/// Write MANIFEST.json into a snapshot dir (done last: a dir without a
/// manifest is an aborted capture, and the loader will reject it).
pub fn write_manifest(snap: &Path, manifest: &Manifest) -> Result<(), BridgeError> {
    let path = snap.join("MANIFEST.json");
    let mut f = std::fs::File::create(&path).map_err(|e| persist_err("MANIFEST create", e))?;
    f.write_all(manifest.to_json().to_string().as_bytes())
        .map_err(|e| persist_err("MANIFEST write", e))?;
    f.sync_all().map_err(|e| persist_err("MANIFEST sync", e))?;
    Ok(())
}

/// Write state.jsonl: quota + exchange rows.
pub fn write_state(
    path: &Path,
    quotas: &[QuotaRow],
    exchanges: &[ExchangeRow],
) -> Result<(), BridgeError> {
    let f = std::fs::File::create(path).map_err(|e| persist_err("state.jsonl create", e))?;
    let mut w = std::io::BufWriter::new(f);
    for q in quotas {
        let row = Json::obj(vec![
            ("t", Json::str("quota")),
            ("user", Json::str(q.user.clone())),
            ("requests", Json::num(q.requests as f64)),
            ("in", Json::num(q.input_tokens as f64)),
            ("out", Json::num(q.output_tokens as f64)),
        ]);
        writeln!(w, "{}", row.to_string()).map_err(|e| persist_err("state.jsonl write", e))?;
    }
    for e in exchanges {
        let row = Json::obj(vec![
            ("t", Json::str("exch")),
            // Request ids are full-width hashes: hex, not f64.
            ("id", Json::str(format!("{:016x}", e.request_id))),
            ("regen", Json::num(e.regen_count as f64)),
            ("req", e.request.clone()),
        ]);
        writeln!(w, "{}", row.to_string()).map_err(|e| persist_err("state.jsonl write", e))?;
    }
    let f = w
        .into_inner()
        .map_err(|e| persist_err("state.jsonl flush", e))?;
    f.sync_all().map_err(|e| persist_err("state.jsonl sync", e))?;
    Ok(())
}

fn read_state(path: &Path) -> Result<(Vec<QuotaRow>, Vec<ExchangeRow>), BridgeError> {
    let text =
        std::fs::read_to_string(path).map_err(|e| persist_err("state.jsonl read", e))?;
    let mut quotas = Vec::new();
    let mut exchanges = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let row = Json::parse(line).map_err(|e| persist_err("state.jsonl parse", e))?;
        let tag = row
            .str_of("t")
            .map_err(|e| persist_err("state.jsonl row", e))?;
        match tag.as_str() {
            "quota" => quotas.push(QuotaRow {
                user: row.str_of("user").map_err(|e| persist_err("quota row", e))?,
                requests: row.f64_of("requests").map_err(|e| persist_err("quota row", e))?
                    as u64,
                input_tokens: row.f64_of("in").map_err(|e| persist_err("quota row", e))?
                    as u64,
                output_tokens: row.f64_of("out").map_err(|e| persist_err("quota row", e))?
                    as u64,
            }),
            "exch" => exchanges.push(ExchangeRow {
                request_id: u64::from_str_radix(
                    &row.str_of("id").map_err(|e| persist_err("exch row", e))?,
                    16,
                )
                .map_err(|e| persist_err("exch row id", e))?,
                regen_count: row.f64_of("regen").map_err(|e| persist_err("exch row", e))?
                    as u32,
                request: row
                    .req("req")
                    .map_err(|e| persist_err("exch row", e))?
                    .clone(),
            }),
            other => {
                return Err(BridgeError::Persist(format!(
                    "unknown state.jsonl row type '{other}'"
                )))
            }
        }
    }
    Ok((quotas, exchanges))
}

/// Load generation `generation`'s snapshot. Generation 0 has none by
/// construction; for N > 0 a missing or inconsistent snapshot dir is
/// corruption (CURRENT committed it).
pub fn load(
    dir: &Path,
    generation: u64,
    embed_dim: usize,
) -> Result<Option<SnapshotState>, BridgeError> {
    if generation == 0 {
        return Ok(None);
    }
    let snap = snap_dir(dir, generation);
    if !snap.is_dir() {
        return Err(BridgeError::Persist(format!(
            "CURRENT names generation {generation} but {snap:?} is missing"
        )));
    }
    let manifest_text = std::fs::read_to_string(snap.join("MANIFEST.json"))
        .map_err(|e| persist_err("MANIFEST read", e))?;
    let manifest = Manifest::from_json(
        &Json::parse(&manifest_text).map_err(|e| persist_err("MANIFEST parse", e))?,
    )?;
    if manifest.generation != generation {
        return Err(BridgeError::Persist(format!(
            "MANIFEST generation {} does not match CURRENT {generation}",
            manifest.generation
        )));
    }
    if manifest.embed_dim != embed_dim {
        return Err(BridgeError::Persist(format!(
            "snapshot embed_dim {} does not match the engine's {embed_dim}",
            manifest.embed_dim
        )));
    }
    let kv = KvStore::restore(&snap.join("kv.jsonl"))
        .map_err(|e| persist_err("kv.jsonl restore", e))?;
    if kv.len() != manifest.kv_len || kv.checksum() != manifest.kv_checksum {
        return Err(BridgeError::Persist(format!(
            "kv.jsonl does not match MANIFEST (len {} vs {}, checksum mismatch)",
            kv.len(),
            manifest.kv_len
        )));
    }
    let cache = SemanticCache::restore_from_dir(&snap, embed_dim)
        .map_err(|e| persist_err("cache snapshot restore", format!("{e:#}")))?;
    if cache.len_objects() != manifest.objects
        || cache.len_keys() != manifest.keys
        || cache.len_exact() != manifest.exact
        || cache.next_id_hint() != manifest.next_id
    {
        return Err(BridgeError::Persist(format!(
            "cache snapshot does not match MANIFEST (objects {}/{}, keys {}/{}, exact {}/{})",
            cache.len_objects(),
            manifest.objects,
            cache.len_keys(),
            manifest.keys,
            cache.len_exact(),
            manifest.exact,
        )));
    }
    let (quotas, exchanges) = read_state(&snap.join("state.jsonl"))?;
    Ok(Some(SnapshotState {
        kv,
        cache,
        quotas,
        exchanges,
    }))
}
