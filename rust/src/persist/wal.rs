//! Binary write-ahead log: length-prefixed, checksummed records.
//!
//! ## File format
//!
//! ```text
//! offset 0            "LBWAL001"                      8-byte magic + version
//! then, per record:   [payload_len: u32 LE]
//!                     [crc:         u64 LE]           FNV-1a over the payload
//!                     [payload:     payload_len bytes]
//! ```
//!
//! A declared `payload_len` above [`MAX_RECORD`] is treated as corruption,
//! not a big record. Each [`WalOp`] payload is a tagged binary encoding
//! (no JSON on the append path — a PUT carries its raw embedding vectors,
//! so records are written raw and bulk). The logged operations:
//!
//! * **exact-cache put** ([`WalOp::PutExact`]) — prompt + response.
//! * **semantic put** ([`WalOp::PutObject`]) — the cache object **plus
//!   each typed key's id and raw embedding**, so restore never touches
//!   the engine (no re-embedding — restarts never re-pay the inference
//!   the cache exists to avoid).
//! * **clear** ([`WalOp::Clear`]).
//! * **quota** ([`WalOp::Quota`]) — *absolute* per-user state, appended
//!   under the quota lock so WAL order = state order; replay is
//!   last-record-wins.
//! * **exchange** ([`WalOp::Exchange`]) — a served request in its REST
//!   JSON form, so `regenerate` works across restarts.
//!
//! ## Versioned (replicated) records
//!
//! When replication is enabled (`--node-id`), cache mutations carry a
//! [`Stamp`] — the `(origin_node, version)` identity the anti-entropy
//! protocol keys on — and are journaled as the stamped twins of the ops
//! above: [`WalOp::PutExactV`], [`WalOp::PutObjectV`],
//! [`WalOp::RemoveExactV`], plus [`WalOp::Adopt`], which retro-stamps a
//! pre-replication entry without re-journaling its payload. A stamp is
//! encoded as `origin: str, version: u64` appended after the legacy
//! fields, so the versioned encodings are strict supersets of the legacy
//! ones. An unreplicated node keeps writing the legacy tags byte-for-byte
//! unchanged, and legacy records always replay as **version-0** entries
//! (origin `""`), which any stamped write beats — that is the entire
//! upgrade path for pre-replication WALs.
//!
//! ## Recovery semantics
//!
//! * A **torn tail** — the expected artifact of a crash or power loss —
//!   is truncated away with a warning, keeping the durable prefix.
//!   Appends reach only the page cache, so a power loss can legitimately
//!   leave garbage *inside* the last record (or a zero-filled tail), not
//!   just a short one. An anomalous record (checksum mismatch,
//!   undecodable payload, past-EOF or insane declared length) is torn
//!   when a **resync probe** finds no complete valid record after it.
//! * An anomalous record with a decodable, checksum-valid record
//!   somewhere after it is **interior corruption**: recovery surfaces
//!   [`BridgeError::Persist`] rather than silently dropping the valid
//!   tail (a flipped length field cannot masquerade as a torn tail).
//!
//! Appends are a single `write_all` of the whole record under one mutex,
//! so a crash can tear at most the final record. Bytes reach the OS page
//! cache on every append (durable across process crashes); `fsync` is
//! paid only at WAL creation and snapshot compaction, not per append —
//! the cache/quota/exchange state is therefore durable *to the last
//! append* across process crashes, and to the last compaction across
//! power loss.
//!
//! WAL records are **tier-agnostic**: a replayed PUT re-inserts its logged
//! embeddings into whichever vector-index tier the restored snapshot is on
//! (flat, or the LBV3-restored IVF, where the row lands in its nearest
//! trained cell) — the log format needs no knowledge of the index tier.

use std::io::{Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::cache::{AdoptTarget, CacheObject, CachedType, Stamp};
use crate::error::BridgeError;
use crate::util::fnv1a;

/// WAL file magic + format version.
pub const WAL_MAGIC: &[u8; 8] = b"LBWAL001";
/// `payload_len: u32` + `crc: u64`.
const RECORD_HEADER: usize = 4 + 8;
/// Sanity cap on one record's payload. Far above any real op (a delegated
/// PUT logs tens of keys x embed_dim f32s, i.e. tens of KiB); a declared
/// length beyond it is corruption, not a big record.
pub const MAX_RECORD: usize = 64 * 1024 * 1024;

const TAG_PUT_EXACT: u8 = 1;
const TAG_PUT_OBJECT: u8 = 2;
const TAG_CLEAR: u8 = 3;
const TAG_QUOTA: u8 = 4;
const TAG_EXCHANGE: u8 = 5;
const TAG_REMOVE_EXACT: u8 = 6;
// Stamped twins of the cache mutations above (see "Versioned records" in
// the module docs). Only written when replication is enabled.
const TAG_PUT_EXACT_V: u8 = 7;
const TAG_PUT_OBJECT_V: u8 = 8;
const TAG_REMOVE_EXACT_V: u8 = 9;
const TAG_ADOPT: u8 = 10;

/// [`AdoptTarget`] discriminants inside a [`WalOp::Adopt`] payload.
const ADOPT_EXACT: u8 = 1;
const ADOPT_OBJECT: u8 = 2;

/// One durable mutation. Cache PUTs carry the embedding vectors computed
/// at insert time, so replay never touches the engine (no re-embedding).
#[derive(Clone, Debug, PartialEq)]
pub enum WalOp {
    /// `SemanticCache::put_exact` (original prompt; normalization is
    /// deterministic and re-applied on replay).
    PutExact { prompt: String, response: String },
    /// One `SemanticCache::put`: the object plus its typed keys, each with
    /// the original key id and the raw embedding handed to the index.
    PutObject {
        object: CacheObject,
        keys: Vec<(u64, CachedType, Vec<f32>)>,
    },
    /// `SemanticCache::clear`.
    Clear,
    /// Absolute per-user quota state after a mutation (last record wins on
    /// replay; appended under the quota lock so WAL order = state order).
    Quota {
        user: String,
        requests: u64,
        input_tokens: u64,
        output_tokens: u64,
    },
    /// A served exchange (for `regenerate` across restarts); the request
    /// is stored as its REST JSON form.
    Exchange {
        request_id: u64,
        regen_count: u32,
        request_json: String,
    },
    /// `SemanticCache::remove_exact` — admin invalidation of one exact
    /// entry (`DELETE /admin/cache?key=`). Journaled so an invalidation
    /// survives restart instead of resurrecting the stale entry.
    RemoveExact { prompt: String },
    /// Stamped [`WalOp::PutExact`]: a replicated exact-cache put (local
    /// write on a `--node-id` bridge, or a remote entry applied by sync).
    PutExactV {
        prompt: String,
        response: String,
        stamp: Stamp,
    },
    /// Stamped [`WalOp::PutObject`]. On this path the logged vectors are
    /// the index's *stored* rows (already normalized for cosine), replayed
    /// verbatim — replicas must be bit-identical, so replay never
    /// re-normalizes.
    PutObjectV {
        object: CacheObject,
        keys: Vec<(u64, CachedType, Vec<f32>)>,
        stamp: Stamp,
    },
    /// Stamped [`WalOp::RemoveExact`]: a replicated tombstone. Replay
    /// records the tombstone even when the key is absent, so a removal
    /// beats a concurrent remote put regardless of arrival order.
    RemoveExactV { prompt: String, stamp: Stamp },
    /// Retro-stamp one pre-replication (version-0) entry when a node is
    /// first booted with `--node-id` — payload-free, so adopting a large
    /// legacy corpus costs bytes proportional to keys, not vectors.
    Adopt { target: AdoptTarget, stamp: Stamp },
}

// ------------------------------------------------------------- encoding
//
// The primitive writers and `Cursor` are pub(crate): the sync wire
// protocol (`crate::sync`) frames its messages in this same encoding, so
// both ends of a peer session share one set of codec primitives.

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

pub(crate) fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len() as u32);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn put_stamp(out: &mut Vec<u8>, s: &Stamp) {
    put_str(out, &s.origin);
    put_u64(out, s.version);
}

pub(crate) struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(bytes: &'a [u8]) -> Cursor<'a> {
        Cursor { bytes, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| format!("payload underrun at byte {}", self.pos))?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn str(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| "non-utf8 string".to_string())
    }

    pub(crate) fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or("vector length overflow")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub(crate) fn stamp(&mut self) -> Result<Stamp, String> {
        Ok(Stamp {
            origin: self.str()?,
            version: self.u64()?,
        })
    }

    pub(crate) fn done(&self) -> Result<(), String> {
        if self.pos != self.bytes.len() {
            return Err(format!(
                "trailing bytes in payload ({} of {})",
                self.pos,
                self.bytes.len()
            ));
        }
        Ok(())
    }
}

impl WalOp {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            WalOp::PutExact { prompt, response } => {
                out.push(TAG_PUT_EXACT);
                put_str(&mut out, prompt);
                put_str(&mut out, response);
            }
            WalOp::PutObject { object, keys } => {
                out.push(TAG_PUT_OBJECT);
                put_u64(&mut out, object.id);
                out.push(object.is_document as u8);
                put_str(&mut out, &object.text);
                put_str(&mut out, &object.origin);
                put_u32(&mut out, keys.len() as u32);
                for (key_id, ctype, vector) in keys {
                    put_u64(&mut out, *key_id);
                    out.push(ctype.tag());
                    put_f32s(&mut out, vector);
                }
            }
            WalOp::Clear => out.push(TAG_CLEAR),
            WalOp::Quota {
                user,
                requests,
                input_tokens,
                output_tokens,
            } => {
                out.push(TAG_QUOTA);
                put_str(&mut out, user);
                put_u64(&mut out, *requests);
                put_u64(&mut out, *input_tokens);
                put_u64(&mut out, *output_tokens);
            }
            WalOp::Exchange {
                request_id,
                regen_count,
                request_json,
            } => {
                out.push(TAG_EXCHANGE);
                put_u64(&mut out, *request_id);
                put_u32(&mut out, *regen_count);
                put_str(&mut out, request_json);
            }
            WalOp::RemoveExact { prompt } => {
                out.push(TAG_REMOVE_EXACT);
                put_str(&mut out, prompt);
            }
            WalOp::PutExactV {
                prompt,
                response,
                stamp,
            } => {
                out.push(TAG_PUT_EXACT_V);
                put_str(&mut out, prompt);
                put_str(&mut out, response);
                put_stamp(&mut out, stamp);
            }
            WalOp::PutObjectV {
                object,
                keys,
                stamp,
            } => {
                out.push(TAG_PUT_OBJECT_V);
                put_u64(&mut out, object.id);
                out.push(object.is_document as u8);
                put_str(&mut out, &object.text);
                put_str(&mut out, &object.origin);
                put_u32(&mut out, keys.len() as u32);
                for (key_id, ctype, vector) in keys {
                    put_u64(&mut out, *key_id);
                    out.push(ctype.tag());
                    put_f32s(&mut out, vector);
                }
                put_stamp(&mut out, stamp);
            }
            WalOp::RemoveExactV { prompt, stamp } => {
                out.push(TAG_REMOVE_EXACT_V);
                put_str(&mut out, prompt);
                put_stamp(&mut out, stamp);
            }
            WalOp::Adopt { target, stamp } => {
                out.push(TAG_ADOPT);
                match target {
                    AdoptTarget::Exact(key) => {
                        out.push(ADOPT_EXACT);
                        put_str(&mut out, key);
                    }
                    AdoptTarget::Object(id) => {
                        out.push(ADOPT_OBJECT);
                        put_u64(&mut out, *id);
                    }
                }
                put_stamp(&mut out, stamp);
            }
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<WalOp, String> {
        let mut c = Cursor {
            bytes: payload,
            pos: 0,
        };
        let op = match c.u8()? {
            TAG_PUT_EXACT => WalOp::PutExact {
                prompt: c.str()?,
                response: c.str()?,
            },
            TAG_PUT_OBJECT => {
                let id = c.u64()?;
                let is_document = c.u8()? != 0;
                let text = c.str()?;
                let origin = c.str()?;
                let nkeys = c.u32()? as usize;
                let mut keys = Vec::with_capacity(nkeys.min(1024));
                for _ in 0..nkeys {
                    let key_id = c.u64()?;
                    let ctype = CachedType::from_tag(c.u8()?)
                        .ok_or_else(|| "bad cached-type tag".to_string())?;
                    keys.push((key_id, ctype, c.f32s()?));
                }
                WalOp::PutObject {
                    object: CacheObject {
                        id,
                        text,
                        origin,
                        is_document,
                    },
                    keys,
                }
            }
            TAG_CLEAR => WalOp::Clear,
            TAG_QUOTA => WalOp::Quota {
                user: c.str()?,
                requests: c.u64()?,
                input_tokens: c.u64()?,
                output_tokens: c.u64()?,
            },
            TAG_EXCHANGE => WalOp::Exchange {
                request_id: c.u64()?,
                regen_count: c.u32()?,
                request_json: c.str()?,
            },
            TAG_REMOVE_EXACT => WalOp::RemoveExact { prompt: c.str()? },
            TAG_PUT_EXACT_V => WalOp::PutExactV {
                prompt: c.str()?,
                response: c.str()?,
                stamp: c.stamp()?,
            },
            TAG_PUT_OBJECT_V => {
                let id = c.u64()?;
                let is_document = c.u8()? != 0;
                let text = c.str()?;
                let origin = c.str()?;
                let nkeys = c.u32()? as usize;
                let mut keys = Vec::with_capacity(nkeys.min(1024));
                for _ in 0..nkeys {
                    let key_id = c.u64()?;
                    let ctype = CachedType::from_tag(c.u8()?)
                        .ok_or_else(|| "bad cached-type tag".to_string())?;
                    keys.push((key_id, ctype, c.f32s()?));
                }
                WalOp::PutObjectV {
                    object: CacheObject {
                        id,
                        text,
                        origin,
                        is_document,
                    },
                    keys,
                    stamp: c.stamp()?,
                }
            }
            TAG_REMOVE_EXACT_V => WalOp::RemoveExactV {
                prompt: c.str()?,
                stamp: c.stamp()?,
            },
            TAG_ADOPT => {
                let target = match c.u8()? {
                    ADOPT_EXACT => AdoptTarget::Exact(c.str()?),
                    ADOPT_OBJECT => AdoptTarget::Object(c.u64()?),
                    t => return Err(format!("bad adopt-target tag {t}")),
                };
                WalOp::Adopt {
                    target,
                    stamp: c.stamp()?,
                }
            }
            t => return Err(format!("unknown op tag {t}")),
        };
        c.done()?;
        Ok(op)
    }
}

// -------------------------------------------------------------- writing

/// Append-side of a WAL file. Thread-safe: one internal mutex serializes
/// appends, and each record is a single `write_all`, so a crash can tear
/// only the final record.
pub struct WalWriter {
    file: Mutex<std::fs::File>,
    len: AtomicU64,
    append_errors: AtomicU64,
    /// Latched when a failed append could not be rolled back: the file may
    /// end in a partial record that later appends would bury as *interior*
    /// corruption, so the writer refuses all further work.
    poisoned: AtomicBool,
}

impl WalWriter {
    /// Create (truncate) a fresh WAL and write + fsync the magic.
    pub fn create(path: &Path) -> std::io::Result<WalWriter> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(WAL_MAGIC)?;
        f.sync_all()?;
        Ok(WalWriter {
            file: Mutex::new(f),
            len: AtomicU64::new(WAL_MAGIC.len() as u64),
            append_errors: AtomicU64::new(0),
            poisoned: AtomicBool::new(false),
        })
    }

    /// Open an existing, already-recovered WAL for append.
    pub fn open_append(path: &Path) -> std::io::Result<WalWriter> {
        let f = std::fs::OpenOptions::new().append(true).open(path)?;
        let len = f.metadata()?.len();
        Ok(WalWriter {
            file: Mutex::new(f),
            len: AtomicU64::new(len),
            append_errors: AtomicU64::new(0),
            poisoned: AtomicBool::new(false),
        })
    }

    /// Current file length in bytes (compaction trigger input).
    pub fn len(&self) -> u64 {
        self.len.load(Ordering::Relaxed)
    }

    pub fn append_errors(&self) -> u64 {
        self.append_errors.load(Ordering::Relaxed)
    }

    pub fn append(&self, op: &WalOp) -> std::io::Result<()> {
        let payload = op.encode();
        if payload.len() > MAX_RECORD {
            // Enforce the reader's sanity cap at write time: an op this
            // size must be rejected here (the caller sees the error and
            // the record is dropped), never written and then classified
            // as corruption at every subsequent boot.
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!(
                    "wal record of {} bytes exceeds the {MAX_RECORD}-byte cap",
                    payload.len()
                ),
            ));
        }
        let mut rec = Vec::with_capacity(RECORD_HEADER + payload.len());
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        rec.extend_from_slice(&payload);
        let mut f = self.file.lock().unwrap();
        if self.poisoned.load(Ordering::Relaxed) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "wal writer poisoned by an unrecoverable earlier append failure",
            ));
        }
        if let Err(e) = f.write_all(&rec) {
            // write_all may have persisted a prefix of the record. Roll
            // the file back to the last committed offset so a later
            // successful append cannot bury the partial record as
            // *interior* corruption (which would brick every future
            // boot). If the rollback itself fails, latch the writer shut.
            let committed = self.len.load(Ordering::Relaxed);
            let rolled_back =
                f.set_len(committed).is_ok() && f.seek(SeekFrom::Start(committed)).is_ok();
            if !rolled_back {
                self.poisoned.store(true, Ordering::Relaxed);
                eprintln!(
                    "persist: WAL append failed AND rollback failed; \
                     writer latched shut (recovery will truncate the torn tail)"
                );
            }
            return Err(e);
        }
        self.len.fetch_add(rec.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Flush appended records to stable storage. Appends are page-cache
    /// only by design (a torn tail is recoverable); graceful shutdown
    /// calls this so a clean exit loses nothing.
    pub fn sync(&self) -> std::io::Result<()> {
        self.file.lock().unwrap().sync_all()
    }

    /// Append, counting (and warning once about) failures instead of
    /// surfacing them — for mutation paths with `()` signatures
    /// (`put_exact`, `clear`, quota charges) where durability is
    /// best-effort by design.
    pub fn append_best_effort(&self, op: &WalOp) {
        if let Err(e) = self.append(op) {
            if self.append_errors.fetch_add(1, Ordering::Relaxed) == 0 {
                eprintln!("persist: WAL append failed ({e}); durability degraded");
            }
        }
    }
}

// ------------------------------------------------------------- reading

/// What recovery found and did.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryReport {
    /// Complete, checksum-valid records replayed.
    pub ops: usize,
    /// Torn-tail bytes dropped (0 on a clean shutdown).
    pub truncated_bytes: u64,
}

/// Pure scan of WAL bytes: the decoded ops plus the durable byte length
/// (everything after it is a torn tail). An anomalous record (bad
/// checksum, undecodable payload, insane declared length) is a torn tail
/// when it is the *final* record or the rest of the file is zeros — the
/// expected power-loss artifacts under page-cache-only appends — and
/// typed interior corruption ([`BridgeError::Persist`]) when valid-looking
/// data continues beyond it.
pub fn scan(bytes: &[u8]) -> Result<(Vec<WalOp>, u64), BridgeError> {
    if bytes.len() < WAL_MAGIC.len() {
        // Torn before the magic finished writing: nothing durable.
        return Ok((Vec::new(), 0));
    }
    if &bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
        return Err(BridgeError::Persist(
            "bad WAL magic (not a LBWAL001 file)".to_string(),
        ));
    }
    let mut pos = WAL_MAGIC.len();
    let mut ops = Vec::new();
    loop {
        let rem = bytes.len() - pos;
        if rem < RECORD_HEADER {
            break; // clean EOF, or torn header
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u64::from_le_bytes(bytes[pos + 4..pos + 12].try_into().unwrap());
        let anomaly = if len > MAX_RECORD {
            format!(
                "record {} at byte {pos} declares {len} bytes (cap {MAX_RECORD})",
                ops.len()
            )
        } else if rem < RECORD_HEADER + len {
            // Usually a genuine torn final record — but a flipped length
            // field on a mid-file record claims the same shape, so this
            // too must pass the resync probe below before truncating.
            format!("record {} at byte {pos} extends past end of file", ops.len())
        } else {
            let payload = &bytes[pos + RECORD_HEADER..pos + RECORD_HEADER + len];
            if fnv1a(payload) == crc {
                match WalOp::decode(payload) {
                    Ok(op) => {
                        ops.push(op);
                        pos += RECORD_HEADER + len;
                        continue;
                    }
                    Err(e) => format!("record {} at byte {pos} decode: {e}", ops.len()),
                }
            } else {
                format!("checksum mismatch in record {} at byte {pos}", ops.len())
            }
        };
        // Anomalous record: a crash artifact only if nothing meaningful
        // follows. A flipped length field can make a mid-file record
        // *claim* to reach EOF, so "extent reaches EOF" alone would
        // silently truncate valid later records — probe ahead for any
        // decodable record first; finding one proves this is interior
        // corruption, not a torn tail.
        let zero_tail = bytes[pos..].iter().all(|&b| b == 0);
        if !zero_tail && any_valid_record_in(bytes, pos + 1) {
            return Err(BridgeError::Persist(format!("wal {anomaly}")));
        }
        break;
    }
    Ok((ops, pos as u64))
}

/// How far past an anomaly the resync probe looks for a next record. A
/// true record after a corrupt one starts within `RECORD_HEADER +
/// payload_len` bytes; typical payloads are KBs, so 1 MiB covers real
/// logs while bounding the (rare, recovery-only) probe cost.
const RESYNC_WINDOW: usize = 1024 * 1024;

/// Is there a complete, checksum-valid, decodable record starting
/// anywhere in `bytes[start..start+RESYNC_WINDOW]`? A 64-bit content
/// checksum plus a successful decode makes a false positive on garbage
/// astronomically unlikely.
fn any_valid_record_in(bytes: &[u8], start: usize) -> bool {
    let end = bytes.len();
    let probe_end = end.min(start.saturating_add(RESYNC_WINDOW));
    let mut q = start;
    while q + RECORD_HEADER <= probe_end {
        let len = u32::from_le_bytes(bytes[q..q + 4].try_into().unwrap()) as usize;
        if len <= MAX_RECORD && q + RECORD_HEADER + len <= end {
            let crc = u64::from_le_bytes(bytes[q + 4..q + 12].try_into().unwrap());
            let payload = &bytes[q + RECORD_HEADER..q + RECORD_HEADER + len];
            if fnv1a(payload) == crc && WalOp::decode(payload).is_ok() {
                return true;
            }
        }
        q += 1;
    }
    false
}

/// Read and recover a WAL file: decode the durable prefix and truncate a
/// torn tail in place (with a warning). A missing file is an empty log.
pub fn recover(path: &Path) -> Result<(Vec<WalOp>, RecoveryReport), BridgeError> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok((Vec::new(), RecoveryReport::default()))
        }
        Err(e) => {
            return Err(BridgeError::Persist(format!("wal read {path:?}: {e}")))
        }
    };
    let (ops, valid_len) = scan(&bytes)?;
    let truncated_bytes = bytes.len() as u64 - valid_len;
    if truncated_bytes > 0 {
        eprintln!(
            "persist: torn WAL tail at {path:?}: keeping {} records, dropping {truncated_bytes} trailing bytes",
            ops.len()
        );
        let f = std::fs::OpenOptions::new()
            .write(true)
            .open(path)
            .map_err(|e| BridgeError::Persist(format!("wal truncate open {path:?}: {e}")))?;
        f.set_len(valid_len)
            .map_err(|e| BridgeError::Persist(format!("wal truncate {path:?}: {e}")))?;
    }
    let report = RecoveryReport {
        ops: ops.len(),
        truncated_bytes,
    };
    Ok((ops, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen_text};

    fn gen_stamp(r: &mut crate::util::rng::Rng) -> Stamp {
        Stamp {
            origin: gen_text(r, 2),
            version: r.next_u64() >> 32,
        }
    }

    fn sample_ops(r: &mut crate::util::rng::Rng) -> Vec<WalOp> {
        let n = 1 + r.below(6);
        (0..n)
            .map(|i| match r.below(10) {
                0 => WalOp::PutExact {
                    prompt: gen_text(r, 6),
                    response: gen_text(r, 6),
                },
                1 => WalOp::PutObject {
                    object: CacheObject {
                        id: r.next_u64() >> 12,
                        text: gen_text(r, 8),
                        origin: gen_text(r, 3),
                        is_document: r.chance(0.5),
                    },
                    keys: (0..1 + r.below(3))
                        .map(|k| {
                            (
                                r.next_u64() >> 12,
                                CachedType::from_tag((k % 7) as u8).unwrap(),
                                (0..8).map(|_| r.normal() as f32).collect(),
                            )
                        })
                        .collect(),
                },
                2 => WalOp::Clear,
                3 => WalOp::Quota {
                    user: gen_text(r, 2),
                    requests: i as u64,
                    input_tokens: r.next_u64() >> 20,
                    output_tokens: r.next_u64() >> 20,
                },
                4 => WalOp::Exchange {
                    request_id: r.next_u64(),
                    regen_count: r.below(4) as u32,
                    request_json: format!("{{\"user\":\"{}\"}}", gen_text(r, 1)),
                },
                5 => WalOp::RemoveExact {
                    prompt: gen_text(r, 6),
                },
                6 => WalOp::PutExactV {
                    prompt: gen_text(r, 6),
                    response: gen_text(r, 6),
                    stamp: gen_stamp(r),
                },
                7 => WalOp::PutObjectV {
                    object: CacheObject {
                        id: r.next_u64() >> 12,
                        text: gen_text(r, 8),
                        origin: gen_text(r, 3),
                        is_document: r.chance(0.5),
                    },
                    keys: (0..1 + r.below(3))
                        .map(|k| {
                            (
                                r.next_u64() >> 12,
                                CachedType::from_tag((k % 7) as u8).unwrap(),
                                (0..8).map(|_| r.normal() as f32).collect(),
                            )
                        })
                        .collect(),
                    stamp: gen_stamp(r),
                },
                8 => WalOp::RemoveExactV {
                    prompt: gen_text(r, 6),
                    stamp: gen_stamp(r),
                },
                _ => WalOp::Adopt {
                    target: if r.chance(0.5) {
                        AdoptTarget::Exact(gen_text(r, 4))
                    } else {
                        AdoptTarget::Object(r.next_u64() >> 12)
                    },
                    stamp: gen_stamp(r),
                },
            })
            .collect()
    }

    #[test]
    fn prop_op_encode_decode_roundtrip() {
        forall(
            41,
            100,
            |r| sample_ops(r),
            |ops| {
                ops.iter()
                    .all(|op| WalOp::decode(&op.encode()).as_ref() == Ok(op))
            },
        );
    }

    #[test]
    fn writer_scan_roundtrip_and_torn_tail() {
        let dir = std::env::temp_dir().join("llmbridge_wal_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.wal");
        let w = WalWriter::create(&path).unwrap();
        let ops = vec![
            WalOp::PutExact {
                prompt: "what is a wal".into(),
                response: "a log".into(),
            },
            WalOp::Clear,
            WalOp::Quota {
                user: "u1".into(),
                requests: 3,
                input_tokens: 10,
                output_tokens: 20,
            },
        ];
        for op in &ops {
            w.append(op).unwrap();
        }
        assert_eq!(w.len(), std::fs::metadata(&path).unwrap().len());
        let bytes = std::fs::read(&path).unwrap();
        let (back, valid) = scan(&bytes).unwrap();
        assert_eq!(back, ops);
        assert_eq!(valid, bytes.len() as u64);

        // Torn tail: drop 3 bytes — last record is gone, prefix survives.
        let (back, valid) = scan(&bytes[..bytes.len() - 3]).unwrap();
        assert_eq!(back.len(), 2);
        assert!(valid < bytes.len() as u64);

        // recover() truncates the file in place.
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let (back, report) = recover(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert!(report.truncated_bytes > 0);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), valid);
        // A second recovery is clean.
        let (_, report) = recover(&path).unwrap();
        assert_eq!(report.truncated_bytes, 0);
    }

    #[test]
    fn interior_corruption_is_typed_not_truncated() {
        let dir = std::env::temp_dir().join("llmbridge_wal_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.wal");
        let w = WalWriter::create(&path).unwrap();
        for i in 0..4 {
            w.append(&WalOp::PutExact {
                prompt: format!("interior prompt {i}"),
                response: "r".into(),
            })
            .unwrap();
        }
        let good = std::fs::read(&path).unwrap();

        // Flip a payload byte of the first record: valid records follow,
        // so this is interior corruption, not a crash artifact.
        let mut bad = good.clone();
        bad[WAL_MAGIC.len() + RECORD_HEADER + 10] ^= 0x40;
        let err = scan(&bad).unwrap_err();
        assert!(matches!(err, BridgeError::Persist(_)), "{err}");
        assert_eq!(err.http_status(), 500);

        // An insane declared length mid-file: the resync probe finds the
        // intact records after it, so this is typed interior corruption —
        // never a silent truncation of the valid tail.
        let mut bad = good.clone();
        bad[WAL_MAGIC.len()..WAL_MAGIC.len() + 4]
            .copy_from_slice(&(MAX_RECORD as u32 + 1).to_le_bytes());
        assert!(matches!(scan(&bad).unwrap_err(), BridgeError::Persist(_)));

        // Wrong magic.
        let mut bad = good;
        bad[0] = b'X';
        assert!(matches!(scan(&bad).unwrap_err(), BridgeError::Persist(_)));
    }

    /// Power-loss artifacts under page-cache-only appends: garbage inside
    /// the final record and a zero-filled tail page both recover as torn
    /// tails (the durable prefix survives), never as boot-fatal errors.
    #[test]
    fn power_loss_tail_artifacts_recover_as_torn() {
        let dir = std::env::temp_dir().join("llmbridge_wal_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("powerloss.wal");
        let w = WalWriter::create(&path).unwrap();
        let mut boundaries = vec![w.len()];
        for i in 0..4 {
            w.append(&WalOp::PutExact {
                prompt: format!("powerloss prompt {i}"),
                response: "r".into(),
            })
            .unwrap();
            boundaries.push(w.len());
        }
        drop(w);
        let good = std::fs::read(&path).unwrap();

        // Garbage inside the FINAL record (checksum mismatch at EOF).
        let mut torn = good.clone();
        let last_payload = boundaries[3] as usize + RECORD_HEADER + 2;
        torn[last_payload] ^= 0xFF;
        let (ops, valid) = scan(&torn).unwrap();
        assert_eq!(ops.len(), 3);
        assert_eq!(valid, boundaries[3]);

        // Zero-filled tail after the last good record (delayed alloc).
        let mut torn = good.clone();
        torn.extend(std::iter::repeat(0u8).take(512));
        let (ops, valid) = scan(&torn).unwrap();
        assert_eq!(ops.len(), 4);
        assert_eq!(valid, boundaries[4]);
    }

    #[test]
    fn oversized_record_rejected_at_append_not_at_boot() {
        let dir = std::env::temp_dir().join("llmbridge_wal_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("oversize.wal");
        let w = WalWriter::create(&path).unwrap();
        w.append(&WalOp::Clear).unwrap();
        let huge = WalOp::PutExact {
            prompt: "p".into(),
            response: "r".repeat(MAX_RECORD + 1),
        };
        let err = w.append(&huge).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
        drop(w);
        // The file stays fully readable: nothing oversized was written.
        let (ops, report) = recover(&path).unwrap();
        assert_eq!(ops, vec![WalOp::Clear]);
        assert_eq!(report.truncated_bytes, 0);
    }

    #[test]
    fn missing_file_is_empty_log() {
        let (ops, report) =
            recover(Path::new("/definitely/not/a/real/llmbridge.wal")).unwrap();
        assert!(ops.is_empty());
        assert_eq!(report.truncated_bytes, 0);
    }
}
