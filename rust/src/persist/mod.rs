//! Durable-state subsystem: snapshot + write-ahead-log persistence for
//! the proxy's stateful core (semantic cache, vector index, quotas,
//! exchanges, KV history).
//!
//! The paper's cache pays off over *months* of deployment (the WhatsApp
//! service ran 12+; §5.1), so the state it accumulates must survive
//! restarts instead of re-paying the API cost it exists to avoid. The
//! design is a classic snapshot + log pair:
//!
//! * every cache mutation (`put_exact` / `put` / `put_interaction` /
//!   `put_delegated` / `clear`) and every quota/exchange update appends a
//!   checksummed binary record to the current WAL ([`wal`]). PUT records
//!   carry the embedding vectors computed at insert time, so restore
//!   never re-embeds;
//! * compaction folds the log into a snapshot generation ([`snapshot`]):
//!   a validated bulk image of the sharded cache (LBV2 vector rows +
//!   object/key/exact rows), the KV store, and quota/exchange state,
//!   committed by an atomic `CURRENT` swap;
//! * boot restores the committed snapshot, then replays the WAL tail,
//!   tolerating a torn final record (truncate-and-warn) while rejecting
//!   interior corruption with a typed [`BridgeError::Persist`].
//!
//! ## Concurrency: the compaction gate
//!
//! All journaled mutators hold the [`Persistence`] gate in *shared* mode
//! across their apply+append; compaction holds it *exclusively* while it
//! captures state and swaps generations. That makes each snapshot a
//! consistent cut with an empty log — no mutation can straddle the swap.
//! Lock order is always gate → state locks (cache shards / quota map) →
//! WAL file mutex; compaction takes gate(write) → state read locks, so
//! there is no cycle. The gate is free (one uncontended `RwLock` read)
//! when persistence is enabled and entirely absent when it is not.

pub mod snapshot;
pub mod wal;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::cache::{AdoptTarget, CacheObject, CachedType, Journal, JournalGuard, Stamp};
use crate::error::BridgeError;
use self::snapshot::{persist_err, CaptureCounts, Manifest, SnapshotState};
use self::wal::{RecoveryReport, WalOp, WalWriter};

/// Everything boot needs to rebuild the in-memory state: the committed
/// snapshot (if any) plus the decoded WAL tail to replay on top.
pub struct Boot {
    pub snapshot: Option<SnapshotState>,
    pub wal_ops: Vec<WalOp>,
    pub report: RecoveryReport,
}

struct WriterSlot {
    generation: u64,
    wal: WalWriter,
}

/// Counters surfaced for tests/metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct PersistStats {
    pub generation: u64,
    pub wal_bytes: u64,
    pub replayed_ops: usize,
    pub truncated_bytes: u64,
    pub compactions: u64,
    pub append_errors: u64,
}

/// A live data directory: the current WAL writer plus the compaction
/// machinery. Owned by the `Bridge` (behind `Arc`, because the cache
/// holds it as its [`Journal`]).
pub struct Persistence {
    dir: PathBuf,
    gate: RwLock<()>,
    writer: Mutex<WriterSlot>,
    compacting: AtomicBool,
    compactions: AtomicU64,
    boot_report: RecoveryReport,
    /// Canonicalized registry key for the data-dir lock this instance
    /// holds a reference on (released on drop).
    lock_key: PathBuf,
}

impl Drop for Persistence {
    fn drop(&mut self) {
        release_dir_lock(&self.lock_key);
    }
}

/// Process-local refcount of held data-dir locks, keyed by canonical
/// path. The LOCK *file* guards against other processes; this registry
/// makes in-process sharing sound: the file is created when the first
/// instance acquires a dir and removed only when the last one drops —
/// dropping one of two same-process bridges no longer unlocks the dir
/// under the survivor.
static LOCKED_DIRS: std::sync::OnceLock<Mutex<std::collections::HashMap<PathBuf, usize>>> =
    std::sync::OnceLock::new();

fn lock_registry() -> &'static Mutex<std::collections::HashMap<PathBuf, usize>> {
    LOCKED_DIRS.get_or_init(|| Mutex::new(std::collections::HashMap::new()))
}

fn release_dir_lock(key: &Path) {
    let mut reg = lock_registry().lock().unwrap();
    if let Some(n) = reg.get_mut(key) {
        *n -= 1;
        if *n == 0 {
            reg.remove(key);
            let _ = std::fs::remove_file(key.join("LOCK"));
        }
    }
}

/// The process's start time from `/proc/<pid>/stat` (field 22) — the
/// cheap std-only way to tell a recycled pid from the original owner
/// after a host reboot. `None` when the process does not exist (or on
/// platforms without procfs).
fn proc_starttime(pid: u32) -> Option<String> {
    #[cfg(target_os = "linux")]
    {
        let stat = std::fs::read_to_string(format!("/proc/{pid}/stat")).ok()?;
        // The comm field is parenthesized and may contain spaces; fields
        // resume after the last ')'. starttime is field 22, i.e. index 19
        // of the post-comm whitespace split (state is field 3).
        let rest = stat.rsplit_once(')')?.1;
        rest.split_whitespace().nth(19).map(|s| s.to_string())
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = pid;
        None
    }
}

/// Is the LOCK's recorded owner still the process that wrote it?
/// Without procfs we cannot probe, so a foreign owner is conservatively
/// treated as alive (the operator removes a truly stale LOCK by hand).
fn lock_owner_alive(pid: u32, recorded_start: Option<&str>) -> bool {
    if !cfg!(target_os = "linux") {
        return true;
    }
    match proc_starttime(pid) {
        // No such process.
        None => false,
        // A different start time means the pid was recycled after a
        // reboot/crash: the recorded owner is dead.
        Some(current) => match recorded_start {
            Some(rec) if !rec.is_empty() => current == rec,
            _ => true,
        },
    }
}

/// Advisory cross-process lock: a `LOCK` file holding `pid starttime`,
/// created with `create_new`. Two *processes* on one data dir would
/// destroy each other's state (dueling compactions, appends to an
/// unlinked WAL), so a live foreign owner is a typed refusal. A lock
/// whose owner is gone — or whose pid was recycled after a reboot
/// (start-time mismatch) — is reclaimed. In-process sharing goes through
/// [`lock_registry`]: additional opens of an already-held dir just bump
/// the refcount (tests that reopen a dir they still hold a bridge for;
/// the WAL-sharing hazards of doing so with two *writing* bridges remain
/// the caller's responsibility). Returns the registry key.
fn acquire_dir_lock(dir: &Path) -> Result<PathBuf, BridgeError> {
    let key = dir
        .canonicalize()
        .map_err(|e| persist_err("data dir canonicalize", e))?;
    // Hold the registry mutex across the whole file dance so two threads
    // of this process can't race the create_new/reclaim sequence.
    let mut reg = lock_registry().lock().unwrap();
    if let Some(n) = reg.get_mut(&key) {
        *n += 1;
        return Ok(key);
    }
    let path = key.join("LOCK");
    for _ in 0..2 {
        match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(mut f) => {
                use std::io::Write as _;
                let me = std::process::id();
                let _ = writeln!(f, "{me} {}", proc_starttime(me).unwrap_or_default());
                let _ = f.sync_all();
                reg.insert(key.clone(), 1);
                return Ok(key);
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                let content = std::fs::read_to_string(&path).unwrap_or_default();
                let mut parts = content.split_whitespace();
                let owner: Option<u32> = parts.next().and_then(|s| s.parse().ok());
                let recorded_start = parts.next();
                match owner {
                    // Our own pid with no registry entry: a leaked file
                    // from an aborted boot of this process — reclaim.
                    Some(pid) if pid == std::process::id() => {
                        let _ = std::fs::remove_file(&path);
                    }
                    Some(pid) if !lock_owner_alive(pid, recorded_start) => {
                        // Dead owner: reclaim and retry the create_new.
                        let _ = std::fs::remove_file(&path);
                    }
                    // Unparseable/empty LOCK: a torn acquire — reclaim.
                    None => {
                        let _ = std::fs::remove_file(&path);
                    }
                    Some(pid) => {
                        return Err(BridgeError::Persist(format!(
                            "data dir {dir:?} is locked by another process \
                             (LOCK pid {pid}); refusing to share a WAL",
                        )))
                    }
                }
            }
            Err(e) => return Err(persist_err("LOCK create", e)),
        }
    }
    Err(BridgeError::Persist(format!(
        "data dir {dir:?} LOCK contention; retry"
    )))
}

/// Remove every `snap-*` dir / `wal-*.log` file whose generation is not
/// the committed one, plus aborted temp files. Best-effort (boot-time
/// hygiene, never a boot failure).
fn gc_stale_generations(dir: &Path, current: u64) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else {
            continue;
        };
        let snap_gen = name.strip_prefix("snap-").and_then(|s| s.parse::<u64>().ok());
        let wal_gen = name
            .strip_prefix("wal-")
            .and_then(|s| s.strip_suffix(".log"))
            .and_then(|s| s.parse::<u64>().ok());
        let stale = match (snap_gen, wal_gen) {
            (Some(g), _) | (_, Some(g)) => g != current,
            _ => name == "snap-tmp" || name == "CURRENT.tmp",
        };
        if stale {
            let path = entry.path();
            if path.is_dir() {
                let _ = std::fs::remove_dir_all(&path);
            } else {
                let _ = std::fs::remove_file(&path);
            }
        }
    }
}

impl Persistence {
    /// Open (or create) a data directory: take the advisory lock, restore
    /// the committed snapshot, recover the WAL (truncating a torn tail),
    /// and arm the writer.
    pub fn open(dir: &Path, embed_dim: usize) -> Result<(Persistence, Boot), BridgeError> {
        std::fs::create_dir_all(dir).map_err(|e| persist_err("data dir create", e))?;
        let lock_key = acquire_dir_lock(dir)?;
        // If boot fails past this point (corrupt CURRENT/snapshot/WAL),
        // release this call's lock reference — otherwise a failed open
        // leaks the refcount (and possibly the LOCK file) forever.
        struct LockCleanup {
            key: Option<PathBuf>,
        }
        impl Drop for LockCleanup {
            fn drop(&mut self) {
                if let Some(key) = &self.key {
                    release_dir_lock(key);
                }
            }
        }
        let mut cleanup = LockCleanup {
            key: Some(lock_key.clone()),
        };
        let generation = snapshot::read_current(dir)?;
        // Sweep generations other than the committed one: aborted
        // captures (snap-tmp, uncommitted snap-N+1) and — after a crash
        // in the post-commit GC window — the superseded generation, which
        // later compactions would otherwise never reclaim.
        gc_stale_generations(dir, generation);
        let snap = snapshot::load(dir, generation, embed_dim)?;
        let wal_file = snapshot::wal_path(dir, generation);
        let (wal_ops, report) = wal::recover(&wal_file)?;
        // A missing or sub-magic file (torn before the header landed)
        // starts fresh; otherwise append after the recovered prefix.
        let durable_len = std::fs::metadata(&wal_file).map(|m| m.len()).unwrap_or(0);
        let wal = if durable_len < wal::WAL_MAGIC.len() as u64 {
            WalWriter::create(&wal_file)
        } else {
            WalWriter::open_append(&wal_file)
        }
        .map_err(|e| persist_err("wal open", e))?;
        let p = Persistence {
            dir: dir.to_path_buf(),
            gate: RwLock::new(()),
            writer: Mutex::new(WriterSlot { generation, wal }),
            compacting: AtomicBool::new(false),
            compactions: AtomicU64::new(0),
            boot_report: report,
            lock_key,
        };
        let boot = Boot {
            snapshot: snap,
            wal_ops,
            report,
        };
        // Boot succeeded: the Persistence's own Drop now holds the lock
        // reference.
        cleanup.key = None;
        Ok((p, boot))
    }

    pub fn data_dir(&self) -> &Path {
        &self.dir
    }

    /// Shared-mode gate for one journaled mutation (see module docs).
    pub fn gate_shared(&self) -> RwLockReadGuard<'_, ()> {
        self.gate.read().unwrap()
    }

    fn gate_exclusive(&self) -> RwLockWriteGuard<'_, ()> {
        self.gate.write().unwrap()
    }

    pub fn append(&self, op: &WalOp) -> std::io::Result<()> {
        self.writer.lock().unwrap().wal.append(op)
    }

    pub fn append_best_effort(&self, op: &WalOp) {
        self.writer.lock().unwrap().wal.append_best_effort(op)
    }

    /// Fsync the WAL — the graceful-shutdown flush (appends are
    /// page-cache only; see [`WalWriter::sync`]).
    pub fn sync_wal(&self) -> std::io::Result<()> {
        self.writer.lock().unwrap().wal.sync()
    }

    /// Current WAL size — the compaction trigger input.
    pub fn wal_len(&self) -> u64 {
        self.writer.lock().unwrap().wal.len()
    }

    pub fn stats(&self) -> PersistStats {
        let slot = self.writer.lock().unwrap();
        PersistStats {
            generation: slot.generation,
            wal_bytes: slot.wal.len(),
            replayed_ops: self.boot_report.ops,
            truncated_bytes: self.boot_report.truncated_bytes,
            compactions: self.compactions.load(Ordering::Relaxed),
            append_errors: slot.wal.append_errors(),
        }
    }

    /// Run one compaction. `capture` writes the bridge-owned state files
    /// (`kv.jsonl`, `vecdb.bin`, `cache.jsonl`, `state.jsonl`) into the
    /// fresh snapshot dir and returns the manifest counts; it runs with
    /// the gate held exclusively, so the cut is consistent and the WAL it
    /// supersedes is complete. Returns false if a compaction was already
    /// in flight.
    pub fn compact_with(
        &self,
        embed_dim: usize,
        capture: impl FnOnce(&Path) -> Result<CaptureCounts, BridgeError>,
    ) -> Result<bool, BridgeError> {
        if self.compacting.swap(true, Ordering::Acquire) {
            return Ok(false);
        }
        let out = self.compact_inner(embed_dim, capture);
        self.compacting.store(false, Ordering::Release);
        out.map(|_| true)
    }

    fn compact_inner(
        &self,
        embed_dim: usize,
        capture: impl FnOnce(&Path) -> Result<CaptureCounts, BridgeError>,
    ) -> Result<(), BridgeError> {
        let _gate = self.gate_exclusive();
        let mut slot = self.writer.lock().unwrap();
        let old_gen = slot.generation;
        let new_gen = old_gen + 1;

        // 1. Capture into snap-tmp (clobbering any stale aborted attempt).
        let tmp = self.dir.join("snap-tmp");
        let _ = std::fs::remove_dir_all(&tmp);
        std::fs::create_dir_all(&tmp).map_err(|e| persist_err("snap-tmp create", e))?;
        let counts = capture(&tmp)?;
        write_manifest_for(&tmp, new_gen, embed_dim, &counts)?;

        // 2. Publish the files under their generation names. The capture
        //    files are individually fsynced; sync the tmp dir so its
        //    entries are durable before the rename, then the data dir so
        //    the rename itself is.
        snapshot::sync_dir(&tmp)?;
        let final_dir = snapshot::snap_dir(&self.dir, new_gen);
        let _ = std::fs::remove_dir_all(&final_dir);
        std::fs::rename(&tmp, &final_dir).map_err(|e| persist_err("snapshot rename", e))?;
        let new_wal_path = snapshot::wal_path(&self.dir, new_gen);
        let _ = std::fs::remove_file(&new_wal_path);
        let new_wal =
            WalWriter::create(&new_wal_path).map_err(|e| persist_err("new wal create", e))?;
        snapshot::sync_dir(&self.dir)?;

        // 3. Commit: CURRENT now names the new generation (write_current
        //    fsyncs the data dir after its rename, so the commit is
        //    durable before any GC below). A crash before this line
        //    leaves the old generation authoritative.
        snapshot::write_current(&self.dir, new_gen)?;
        *slot = WriterSlot {
            generation: new_gen,
            wal: new_wal,
        };

        // 4. GC the superseded generation (best-effort).
        let _ = std::fs::remove_file(snapshot::wal_path(&self.dir, old_gen));
        let _ = std::fs::remove_dir_all(snapshot::snap_dir(&self.dir, old_gen));
        self.compactions.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

fn write_manifest_for(
    tmp: &Path,
    generation: u64,
    embed_dim: usize,
    counts: &CaptureCounts,
) -> Result<(), BridgeError> {
    snapshot::write_manifest(
        tmp,
        &Manifest {
            generation,
            embed_dim,
            objects: counts.objects,
            keys: counts.keys,
            exact: counts.exact,
            next_id: counts.next_id,
            kv_len: counts.kv_len,
            kv_checksum: counts.kv_checksum,
        },
    )
}

/// The cache journals through the persistence layer: mutations enter the
/// gate in shared mode and append their WAL record after the in-memory
/// apply. `log_put` surfaces append failures (the PUT's `Result` can carry
/// them); the `()`-signature paths are best-effort and counted.
impl Journal for Persistence {
    fn enter(&self) -> JournalGuard<'_> {
        JournalGuard::Shared(self.gate_shared())
    }

    fn enter_exclusive(&self) -> JournalGuard<'_> {
        JournalGuard::Exclusive(self.gate_exclusive())
    }

    fn log_put_exact(&self, prompt: &str, response: &str) {
        self.append_best_effort(&WalOp::PutExact {
            prompt: prompt.to_string(),
            response: response.to_string(),
        });
    }

    fn log_put(
        &self,
        object: CacheObject,
        keys: Vec<(u64, CachedType, Vec<f32>)>,
    ) -> anyhow::Result<()> {
        self.append(&WalOp::PutObject { object, keys })
            .map_err(|e| anyhow::anyhow!("wal append: {e}"))
    }

    fn log_clear(&self) {
        self.append_best_effort(&WalOp::Clear);
    }

    fn log_remove_exact(&self, prompt: &str) {
        self.append_best_effort(&WalOp::RemoveExact {
            prompt: prompt.to_string(),
        });
    }

    fn log_put_exact_v(&self, prompt: &str, response: &str, stamp: &Stamp) {
        self.append_best_effort(&WalOp::PutExactV {
            prompt: prompt.to_string(),
            response: response.to_string(),
            stamp: stamp.clone(),
        });
    }

    fn log_put_v(
        &self,
        object: CacheObject,
        keys: Vec<(u64, CachedType, Vec<f32>)>,
        stamp: &Stamp,
    ) -> anyhow::Result<()> {
        self.append(&WalOp::PutObjectV {
            object,
            keys,
            stamp: stamp.clone(),
        })
        .map_err(|e| anyhow::anyhow!("wal append: {e}"))
    }

    fn log_remove_exact_v(&self, prompt: &str, stamp: &Stamp) {
        self.append_best_effort(&WalOp::RemoveExactV {
            prompt: prompt.to_string(),
            stamp: stamp.clone(),
        });
    }

    fn log_adopt(&self, target: AdoptTarget, stamp: &Stamp) {
        self.append_best_effort(&WalOp::Adopt {
            target,
            stamp: stamp.clone(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "llmbridge_persist_mod_{name}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn open_fresh_dir_is_empty_generation_zero() {
        let dir = fresh_dir("fresh");
        let (p, boot) = Persistence::open(&dir, 8).unwrap();
        assert!(boot.snapshot.is_none());
        assert!(boot.wal_ops.is_empty());
        let s = p.stats();
        assert_eq!(s.generation, 0);
        assert_eq!(s.wal_bytes, wal::WAL_MAGIC.len() as u64);
        // The WAL file exists and is re-openable.
        drop(p);
        let (p, boot) = Persistence::open(&dir, 8).unwrap();
        assert!(boot.wal_ops.is_empty());
        assert_eq!(p.stats().generation, 0);
    }

    #[test]
    fn appends_survive_reopen() {
        let dir = fresh_dir("reopen");
        let (p, _) = Persistence::open(&dir, 8).unwrap();
        p.append(&WalOp::PutExact {
            prompt: "p".into(),
            response: "r".into(),
        })
        .unwrap();
        p.append(&WalOp::Clear).unwrap();
        drop(p);
        let (_, boot) = Persistence::open(&dir, 8).unwrap();
        assert_eq!(boot.wal_ops.len(), 2);
        assert!(matches!(boot.wal_ops[1], WalOp::Clear));
    }

    #[test]
    fn current_pointing_at_missing_snapshot_is_typed_corruption() {
        let dir = fresh_dir("missing_snap");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("CURRENT"), "3\n").unwrap();
        let err = Persistence::open(&dir, 8).unwrap_err();
        assert!(matches!(err, BridgeError::Persist(_)), "{err}");
    }
}
