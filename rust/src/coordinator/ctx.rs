//! The per-request context threaded through the pipeline stages.

use std::time::Instant;

use crate::api::{CacheOutcome, Metadata, Request};
use crate::models::generator::Completion;
use crate::models::pricing::ModelId;
use crate::models::quality::QueryTraits;
use crate::router::ServicePolicy;

/// Everything one request accumulates on its way through
/// `CacheStage → ContextStage → RouteStage → AccountStage`.
///
/// Stages only read requests and write results here; the Bridge owns all
/// shared state (cache, history, quotas, telemetry).
pub struct RequestCtx<'a> {
    pub req: &'a Request,
    pub regen_count: u32,
    pub start: Instant,
    /// The lowered service policy driving every stage.
    pub policy: ServicePolicy,
    pub traits: QueryTraits,

    // -- accumulated along the way -------------------------------------
    /// (model, role) pairs for the transparency metadata.
    pub models_used: Vec<(String, String)>,
    /// Every real pool call made on behalf of this request (billing).
    pub calls: Vec<Completion>,
    pub cache_outcome: CacheOutcome,
    /// A semantic-cache hit grounded the response (§3.5).
    pub grounded: bool,
    pub verifier_score: Option<f64>,
    /// Response text produced by the smart-cache GET, consumed by the
    /// route stage instead of a fresh generation.
    pub smart_cache_response: Option<String>,
    /// Milliseconds spent in delegated context-LLM calls (Fig 6c).
    pub context_llm_ms: f64,
    /// History messages that rode along as context.
    pub context_messages: usize,
    /// Context sufficiency for the quality model.
    pub sufficiency: f64,
    /// Fully-rendered model input (context + prompt).
    pub input_text: String,

    // -- outputs --------------------------------------------------------
    pub text: Option<String>,
    /// Latent quality of the served response (simulation-only).
    pub latent: f64,
    /// The model credited with the answer; `None` means the exact cache
    /// served it.
    pub answer_model: Option<ModelId>,
    /// The route stage ran (quota is only charged for routed requests).
    pub routed: bool,
    pub meta: Option<Metadata>,
}

impl<'a> RequestCtx<'a> {
    pub fn new(req: &'a Request, regen_count: u32, policy: ServicePolicy) -> RequestCtx<'a> {
        RequestCtx {
            req,
            regen_count,
            start: Instant::now(),
            policy,
            traits: req.effective_traits(),
            models_used: Vec::new(),
            calls: Vec::new(),
            cache_outcome: CacheOutcome::Skipped,
            grounded: false,
            verifier_score: None,
            smart_cache_response: None,
            context_llm_ms: 0.0,
            context_messages: 0,
            sufficiency: 1.0,
            input_text: String::new(),
            text: None,
            latent: 0.0,
            answer_model: None,
            routed: false,
            meta: None,
        }
    }
}
