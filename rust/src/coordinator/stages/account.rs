//! Final stage: accounting and transparency metadata.
//!
//! Folds every pool call the request made into tokens/cost/latency
//! telemetry, charges quota-metered requests, and builds the [`Metadata`]
//! the application sees (§3.2 transparency). Always runs — including for
//! short-circuited exact hits, whose empty call list yields the zero-cost
//! metadata the paper's buttons path promises.

use crate::api::Metadata;
use crate::coordinator::ctx::RequestCtx;
use crate::coordinator::pipeline::{exchange_id, Bridge};
use crate::error::BridgeError;
use crate::models::pricing::LatencyClass;

use super::{Flow, Stage};

pub struct AccountStage;

impl Stage for AccountStage {
    fn run(&self, bridge: &Bridge, cx: &mut RequestCtx) -> Result<Flow, BridgeError> {
        let mut input_tokens = 0;
        let mut output_tokens = 0;
        let mut cost = 0.0;
        let mut llm_ms = 0.0;
        for c in &cx.calls {
            llm_ms += c.latency.as_secs_f64() * 1e3;
            input_tokens += c.input_tokens;
            output_tokens += c.output_tokens;
            cost += c.cost_usd;
            bridge
                .telemetry
                .costs
                .record(c.model.as_str(), c.input_tokens, c.output_tokens, c.cost_usd);
            match c.model.spec().latency_class {
                LatencyClass::Small => bridge.telemetry.llm_latency_small.record(c.latency),
                LatencyClass::Large => bridge.telemetry.llm_latency_large.record(c.latency),
            }
        }
        if cx.policy.quota && cx.routed {
            bridge.charge_quota_tokens(&cx.req.user, input_tokens, output_tokens);
        }
        let latency_ms = cx.start.elapsed().as_secs_f64() * 1e3;
        bridge.telemetry.request_latency.record(cx.start.elapsed());

        cx.meta = Some(Metadata {
            request_id: exchange_id(cx.req, cx.regen_count),
            service_type: cx.req.service_type.name().to_string(),
            models_used: std::mem::take(&mut cx.models_used),
            cache: cx.cache_outcome.clone(),
            context_messages: cx.context_messages,
            input_tokens,
            output_tokens,
            cost_usd: cost,
            latency_ms,
            verifier_score: cx.verifier_score,
            context_llm_ms: cx.context_llm_ms,
            llm_ms,
            latent_quality: cx.latent,
            grounded: cx.grounded,
            regen_count: cx.regen_count,
        });
        Ok(Flow::Continue)
    }
}
