//! The staged request pipeline (paper Fig 2, order ②-④).
//!
//! Each stage is a small, order-independent unit that reads the request's
//! [`ServicePolicy`](crate::router::ServicePolicy) and mutates the
//! [`RequestCtx`](super::ctx::RequestCtx); `Bridge::resolve` threads the
//! context through `CacheStage → ContextStage → RouteStage` and always
//! finishes with `AccountStage`. A stage returning [`Flow::Done`]
//! short-circuits the remaining pre-accounting stages (the exact-hit fast
//! path).

pub mod account;
pub mod cache;
pub mod context;
pub mod route;

pub use account::AccountStage;
pub use cache::CacheStage;
pub use context::ContextStage;
pub use route::RouteStage;

use super::ctx::RequestCtx;
use super::pipeline::Bridge;
use crate::error::BridgeError;

/// Whether the pipeline keeps running after a stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flow {
    Continue,
    /// The response is already in the context; skip to accounting.
    Done,
}

pub trait Stage {
    fn run(&self, bridge: &Bridge, cx: &mut RequestCtx) -> Result<Flow, BridgeError>;
}
