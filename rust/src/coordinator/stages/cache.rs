//! Stage ②: the semantic cache (§3.5).
//!
//! Exact-match lookup runs before history/traits are materialized: the
//! prefetched-button path (§5.1) is the latency-critical one
//! (EXPERIMENTS.md §Perf). The delegated semantic GET ("SmartCache") runs
//! second and, on a used hit, carries its grounded response forward for
//! the route stage to serve. Regeneration bypasses both lookups.

use crate::api::CacheOutcome;
use crate::coordinator::ctx::RequestCtx;
use crate::coordinator::pipeline::Bridge;
use crate::error::BridgeError;
use crate::models::quality::{latent_score, GenCondition};

use super::{Flow, Stage};

pub struct CacheStage;

impl Stage for CacheStage {
    fn run(&self, bridge: &Bridge, cx: &mut RequestCtx) -> Result<Flow, BridgeError> {
        if cx.regen_count > 0 {
            return Ok(Flow::Continue);
        }
        if cx.policy.cache.exact {
            if let Some(text) = bridge.cache.get_exact(&cx.req.prompt) {
                // Prefetched exact hit (WhatsApp buttons): zero LLM cost.
                bridge.telemetry.counters.incr("cache_exact_hits");
                cx.cache_outcome = CacheOutcome::ExactHit;
                cx.latent = latent_score(&cx.traits, 0.9, GenCondition::default());
                cx.text = Some(text);
                return Ok(Flow::Done);
            }
        }
        if let Some(model) = cx.policy.cache.smart {
            let out =
                bridge
                    .cache
                    .smart_get(&bridge.generator, model, &cx.req.prompt, &cx.traits)?;
            cx.calls.extend(out.llm_calls.iter().cloned());
            for c in &out.llm_calls {
                cx.models_used
                    .push((c.model.as_str().to_string(), "cache-llm".into()));
            }
            match (&out.hit, out.used) {
                (Some(h), true) => {
                    cx.cache_outcome = CacheOutcome::SemanticHit { score: h.score };
                    cx.grounded = true;
                    cx.smart_cache_response = out.response.clone();
                    bridge.telemetry.counters.incr("cache_semantic_hits");
                }
                (Some(_), false) | (None, _) => {
                    cx.cache_outcome = CacheOutcome::Miss;
                    bridge.telemetry.counters.incr("cache_misses");
                }
            }
        }
        Ok(Flow::Continue)
    }
}
