//! Stage ③: the context manager (§3.4).
//!
//! Loads the conversation history and runs the policy's filter tree over
//! it; delegated context-LLM calls (SmartContext, Summarize) are billed
//! to the request. Produces the fully-rendered model input.

use crate::context::{FilterCtx, HistoryStore};
use crate::coordinator::ctx::RequestCtx;
use crate::coordinator::pipeline::Bridge;
use crate::error::BridgeError;

use super::{Flow, Stage};

pub struct ContextStage;

impl Stage for ContextStage {
    fn run(&self, bridge: &Bridge, cx: &mut RequestCtx) -> Result<Flow, BridgeError> {
        let msgs = HistoryStore::new(&bridge.kv).get(&cx.req.user, &cx.req.conversation);
        let selection = cx.policy.context.apply(
            &msgs,
            &cx.req.prompt,
            &FilterCtx {
                generator: &bridge.generator,
                traits: &cx.traits,
            },
        )?;
        cx.context_llm_ms = selection
            .llm_calls
            .iter()
            .map(|c| c.latency.as_secs_f64() * 1e3)
            .sum();
        for c in &selection.llm_calls {
            cx.models_used
                .push((c.model.as_str().to_string(), "context-llm".into()));
        }
        cx.calls.extend(selection.llm_calls.iter().cloned());
        let ctx_messages = selection.messages(&msgs);
        cx.sufficiency = selection.sufficiency(msgs.len());
        cx.context_messages = ctx_messages.len();
        let rendered: String = ctx_messages
            .iter()
            .map(|m| m.render())
            .collect::<Vec<_>>()
            .join("\n");
        cx.input_text = if rendered.is_empty() {
            cx.req.prompt.clone()
        } else {
            format!("{rendered}\nuser: {}", cx.req.prompt)
        };
        Ok(Flow::Continue)
    }
}
