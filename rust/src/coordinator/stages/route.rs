//! Stage ④: the model adapter (§3.3), driven by the routing policy.
//!
//! Asks the request's [`RoutingPolicy`](crate::router::RoutingPolicy) for
//! a [`RoutePlan`](crate::router::RoutePlan) and executes it: one
//! generation, or the verification cascade. A smart-cache hit from stage
//! ② short-circuits generation — the grounded response is served under
//! the cache-LLM's name. The per-user quota gates allowlist requests
//! before any model runs.
//!
//! Execution is wrapped by the per-model circuit breaker
//! ([`crate::ops::CircuitBreaker`]): the plan's *answer model* is gated
//! before any model runs, and the outcome is reported back. While a
//! model's breaker is open the request fast-fails with
//! [`BridgeError::BreakerOpen`] (503, `"reason":"breaker"`,
//! `Retry-After`) instead of pinning a worker on a sick backend. Only
//! infrastructure failures (`Internal`, `UpstreamTimeout`) count against
//! the breaker; a caller's `BadRequest` never trips it.

use crate::adapter::Cascade;
use crate::coordinator::ctx::RequestCtx;
use crate::coordinator::pipeline::Bridge;
use crate::error::BridgeError;
use crate::models::quality::{latent_score, GenCondition, QueryTraits};
use crate::ops::Admission;
use crate::router::{RouteError, RoutePlan};

use super::{Flow, Stage};

pub struct RouteStage;

impl Stage for RouteStage {
    fn run(&self, bridge: &Bridge, cx: &mut RequestCtx) -> Result<Flow, BridgeError> {
        let cond = GenCondition {
            context_sufficiency: cx.sufficiency,
            grounded: cx.grounded,
        };
        let traits = cx.traits.clone();

        if let Some(text) = cx.smart_cache_response.take() {
            // Cache content already produced the response (cache-LLM calls
            // were billed by the cache stage).
            let model = cx
                .policy
                .cache
                .smart
                .expect("smart-cache hit implies a smart cache plan");
            cx.latent = latent_score(&traits, model.spec().capability, cond);
            cx.text = Some(text);
            cx.answer_model = Some(model);
            cx.routed = true;
            return Ok(Flow::Continue);
        }

        let gated = cx.policy.quota;
        if gated && !bridge.reserve_quota_slot(&cx.req.user) {
            bridge.telemetry.counters.incr("quota_rejections");
            return Err(BridgeError::QuotaExceeded {
                user: cx.req.user.clone(),
            });
        }
        if let Err(e) = execute_plan(bridge, cx, cond, &traits) {
            // A request that served nothing must not burn quota — client
            // typos or engine failures would otherwise drain the cap.
            if gated {
                bridge.release_quota_slot(&cx.req.user);
            }
            return Err(e);
        }
        cx.routed = true;
        Ok(Flow::Continue)
    }
}

/// Resolve the routing policy to a plan and execute it under the answer
/// model's circuit breaker.
fn execute_plan(
    bridge: &Bridge,
    cx: &mut RequestCtx,
    cond: GenCondition,
    traits: &QueryTraits,
) -> Result<(), BridgeError> {
    let requested = cx.req.params.get("model").map(|s| s.as_str());
    let plan = cx.policy.routing.route(requested).map_err(|e| match e {
        // The caller's own parameters made routing impossible.
        RouteError::UnknownModel(_) | RouteError::NoModelUnderBudget { .. } => {
            BridgeError::bad_request(e.to_string())
        }
        // A policy the pool can't satisfy is a configuration bug.
        RouteError::EmptyPool(_) => BridgeError::Internal(anyhow::anyhow!("{e}")),
    })?;

    // The breaker keys on the model that answers first: the single plan's
    // model, or the cascade's m1 (a cascade with a dead m1 never reaches
    // m2, so m1's health is the plan's health).
    let answer_model = match &plan {
        RoutePlan::Single { model, .. } => *model,
        RoutePlan::Cascade { m1, .. } => *m1,
    };
    let breaker = bridge.breaker();
    match breaker.admit(answer_model.as_str()) {
        Admission::Allow => {}
        Admission::Probe => {
            bridge.telemetry.counters.incr("breaker_probes");
        }
        Admission::Deny { retry_after } => {
            bridge.telemetry.counters.incr("breaker_shed");
            return Err(BridgeError::BreakerOpen {
                model: answer_model.as_str().to_string(),
                retry_after_secs: retry_after.as_secs().max(1),
            });
        }
    }

    match run_plan(bridge, cx, cond, traits, plan) {
        Ok(()) => {
            if breaker.record_success(answer_model.as_str()) {
                bridge.telemetry.counters.incr("breaker_recoveries");
            }
            Ok(())
        }
        Err(e) => {
            // Only infrastructure failures advance the breaker; a client's
            // bad parameters say nothing about the backend's health.
            if matches!(
                e,
                BridgeError::Internal(_) | BridgeError::UpstreamTimeout { .. }
            ) {
                bridge.telemetry.counters.incr("breaker_failures");
                if breaker.record_failure(answer_model.as_str()) {
                    bridge.telemetry.counters.incr("breaker_trips");
                }
            }
            Err(e)
        }
    }
}

/// Execute a resolved plan (generation or cascade).
fn run_plan(
    bridge: &Bridge,
    cx: &mut RequestCtx,
    cond: GenCondition,
    traits: &QueryTraits,
    plan: RoutePlan,
) -> Result<(), BridgeError> {
    // Failpoint for the resilience tests: a request carrying
    // `params.failpoint = "generate"` fails as if the backend died,
    // exercising the breaker path end-to-end over real HTTP.
    if cx.req.params.get("failpoint").map(String::as_str) == Some("generate")
        && crate::util::failpoints_enabled()
    {
        return Err(BridgeError::Internal(anyhow::anyhow!(
            "failpoint: injected generate failure"
        )));
    }

    match plan {
        RoutePlan::Single {
            model,
            denied_requested,
        } => {
            if denied_requested {
                // Curated-list deny (the §5.2 "domain denylist" analogy):
                // fall back instead of failing.
                bridge.telemetry.counters.incr("model_denied");
            }
            let completion = bridge.generator.generate(model, &cx.input_text, None)?;
            cx.models_used.push((model.as_str().into(), "answer".into()));
            cx.latent = latent_score(traits, model.spec().capability, cond);
            cx.text = Some(completion.text.clone());
            cx.calls.push(completion);
            cx.answer_model = Some(model);
        }
        RoutePlan::Cascade {
            m1,
            m2,
            verifier,
            threshold,
        } => {
            let cascade = Cascade {
                m1,
                m2,
                verifier,
                threshold,
            };
            let result =
                cascade.run(&bridge.generator, &cx.input_text, &cx.req.prompt, traits, cond)?;
            cx.models_used.push((m1.as_str().into(), "m1".into()));
            cx.models_used.push((verifier.as_str().into(), "verifier".into()));
            if result.escalated {
                cx.models_used.push((m2.as_str().into(), "m2".into()));
                bridge.telemetry.counters.incr("cascade_escalations");
            }
            cx.verifier_score = Some(result.verifier_score);
            cx.calls.extend(result.calls.iter().cloned());
            cx.latent = result.latent;
            cx.text = Some(result.completion.text.clone());
            cx.answer_model = Some(result.completion.model);
        }
    }
    Ok(())
}
