//! Stage ④: the model adapter (§3.3), driven by the routing policy.
//!
//! Asks the request's [`RoutingPolicy`](crate::router::RoutingPolicy) for
//! a [`RoutePlan`](crate::router::RoutePlan) and executes it: one
//! generation, or the verification cascade. A smart-cache hit from stage
//! ② short-circuits generation — the grounded response is served under
//! the cache-LLM's name. The per-user quota gates allowlist requests
//! before any model runs.

use crate::adapter::Cascade;
use crate::coordinator::ctx::RequestCtx;
use crate::coordinator::pipeline::Bridge;
use crate::error::BridgeError;
use crate::models::quality::{latent_score, GenCondition, QueryTraits};
use crate::router::{RouteError, RoutePlan};

use super::{Flow, Stage};

pub struct RouteStage;

impl Stage for RouteStage {
    fn run(&self, bridge: &Bridge, cx: &mut RequestCtx) -> Result<Flow, BridgeError> {
        let cond = GenCondition {
            context_sufficiency: cx.sufficiency,
            grounded: cx.grounded,
        };
        let traits = cx.traits.clone();

        if let Some(text) = cx.smart_cache_response.take() {
            // Cache content already produced the response (cache-LLM calls
            // were billed by the cache stage).
            let model = cx
                .policy
                .cache
                .smart
                .expect("smart-cache hit implies a smart cache plan");
            cx.latent = latent_score(&traits, model.spec().capability, cond);
            cx.text = Some(text);
            cx.answer_model = Some(model);
            cx.routed = true;
            return Ok(Flow::Continue);
        }

        let gated = cx.policy.quota;
        if gated && !bridge.reserve_quota_slot(&cx.req.user) {
            bridge.telemetry.counters.incr("quota_rejections");
            return Err(BridgeError::QuotaExceeded {
                user: cx.req.user.clone(),
            });
        }
        if let Err(e) = execute_plan(bridge, cx, cond, &traits) {
            // A request that served nothing must not burn quota — client
            // typos or engine failures would otherwise drain the cap.
            if gated {
                bridge.release_quota_slot(&cx.req.user);
            }
            return Err(e);
        }
        cx.routed = true;
        Ok(Flow::Continue)
    }
}

/// Resolve the routing policy to a plan and execute it.
fn execute_plan(
    bridge: &Bridge,
    cx: &mut RequestCtx,
    cond: GenCondition,
    traits: &QueryTraits,
) -> Result<(), BridgeError> {
    let requested = cx.req.params.get("model").map(|s| s.as_str());
    let plan = cx.policy.routing.route(requested).map_err(|e| match e {
        // The caller's own parameters made routing impossible.
        RouteError::UnknownModel(_) | RouteError::NoModelUnderBudget { .. } => {
            BridgeError::bad_request(e.to_string())
        }
        // A policy the pool can't satisfy is a configuration bug.
        RouteError::EmptyPool(_) => BridgeError::Internal(anyhow::anyhow!("{e}")),
    })?;

    match plan {
        RoutePlan::Single {
            model,
            denied_requested,
        } => {
            if denied_requested {
                // Curated-list deny (the §5.2 "domain denylist" analogy):
                // fall back instead of failing.
                bridge.telemetry.counters.incr("model_denied");
            }
            let completion = bridge.generator.generate(model, &cx.input_text, None)?;
            cx.models_used.push((model.as_str().into(), "answer".into()));
            cx.latent = latent_score(traits, model.spec().capability, cond);
            cx.text = Some(completion.text.clone());
            cx.calls.push(completion);
            cx.answer_model = Some(model);
        }
        RoutePlan::Cascade {
            m1,
            m2,
            verifier,
            threshold,
        } => {
            let cascade = Cascade {
                m1,
                m2,
                verifier,
                threshold,
            };
            let result =
                cascade.run(&bridge.generator, &cx.input_text, &cx.req.prompt, traits, cond)?;
            cx.models_used.push((m1.as_str().into(), "m1".into()));
            cx.models_used.push((verifier.as_str().into(), "verifier".into()));
            if result.escalated {
                cx.models_used.push((m2.as_str().into(), "m2".into()));
                bridge.telemetry.counters.incr("cascade_escalations");
            }
            cx.verifier_score = Some(result.verifier_score);
            cx.calls.extend(result.calls.iter().cloned());
            cx.latent = result.latent;
            cx.text = Some(result.completion.text.clone());
            cx.answer_model = Some(result.completion.model);
        }
    }
    Ok(())
}
