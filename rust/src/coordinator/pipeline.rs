//! The Bridge: owns the shared proxy state (engine, cache, history,
//! quotas, telemetry) and orchestrates the staged request pipeline in the
//! paper's order — cache (§3.5) → context manager (§3.4) → model adapter
//! (§3.3) → accounting — plus regeneration, follow-up prefetch (§5.1),
//! and the §5.2 batch mode.
//!
//! Stage logic lives in [`super::stages`]; model choice lives in
//! [`crate::router`]. `resolve` only threads a [`RequestCtx`] through the
//! stages.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::api::{CachePolicy, Request, Response, ServiceType};
use crate::cache::SemanticCache;
use crate::context::{HistoryStore, Message};
use crate::coordinator::ctx::RequestCtx;
use crate::coordinator::stages::{AccountStage, CacheStage, ContextStage, Flow, RouteStage, Stage};
use crate::error::BridgeError;
use crate::kvstore::KvStore;
use crate::models::generator::Generator;
use crate::models::pricing::{Generation, ModelId};
use crate::persist::snapshot::{CaptureCounts, ExchangeRow, QuotaRow};
use crate::persist::wal::WalOp;
use crate::persist::Persistence;
use crate::router;
use crate::runtime::EngineHandle;
use crate::telemetry::Telemetry;
use crate::util::json::Json;
use crate::workload::classroom::Quota;

/// Proxy configuration.
#[derive(Clone, Debug)]
pub struct BridgeConfig {
    /// Synchronously prefetch follow-up answers into the exact cache after
    /// each response (the WhatsApp buttons; async in production, sync here
    /// for determinism).
    pub prefetch_followups: bool,
    /// Which model generation the delegated service types draw from at
    /// boot. Hot-swappable at runtime via [`Bridge::set_generation`]
    /// (`POST /admin/config {"generation": "old"|"new"}`); this field
    /// only seeds the live cell.
    pub generation: Generation,
    /// Memoize completions (replay accelerator; see Generator docs).
    pub memoize: bool,
    /// Per-user quota for the usage-based service type.
    pub quota: Quota,
    /// Durable-state directory (snapshot + WAL; see [`crate::persist`]).
    /// `None` (the default) keeps the proxy fully in-memory — the hot
    /// path, tier-1 tests, and benches are untouched.
    pub data_dir: Option<PathBuf>,
    /// Compact the WAL into a snapshot once it exceeds this many bytes
    /// (checked by [`Bridge::maybe_compact`], which the server polls from
    /// a background janitor thread).
    pub compact_wal_bytes: u64,
    /// Per-model circuit-breaker tunables (`--breaker-threshold`,
    /// `--breaker-cooldown-secs`); hot-reloadable via `POST /admin/config`.
    pub breaker: crate::ops::BreakerConfig,
    /// Engine RPC deadline override (`--engine-timeout-secs`); `None`
    /// keeps the engine's 120s default.
    pub engine_timeout: Option<std::time::Duration>,
    /// Replication identity (`--node-id`). `None` (the default) keeps
    /// replication off: no stamps, no sync threads, the hot path exactly
    /// as before. Set it (distinct per node) to stamp every cache write
    /// and allow a [`crate::sync::SyncService`] to exchange deltas with
    /// peers.
    pub node_id: Option<String>,
}

impl Default for BridgeConfig {
    fn default() -> Self {
        BridgeConfig {
            prefetch_followups: false,
            generation: Generation::New,
            memoize: true,
            quota: Quota::default(),
            data_dir: None,
            compact_wal_bytes: 8 * 1024 * 1024,
            breaker: crate::ops::BreakerConfig::default(),
            engine_timeout: None,
            node_id: None,
        }
    }
}

#[derive(Default, Clone, Debug)]
pub(crate) struct QuotaState {
    requests: u64,
    input_tokens: u64,
    output_tokens: u64,
}

struct StoredExchange {
    request: Request,
    regen_count: u32,
}

/// How many served exchanges stay regenerable. The map used to be
/// unbounded but reset on every restart; durable restarts would otherwise
/// grow it (and every snapshot capture) with the deployment's lifetime
/// request count, so it is now explicitly a window of the most recent
/// exchanges — regenerate targets recent responses by design (§3.2).
const MAX_EXCHANGES: usize = 4096;

/// Insertion-ordered, bounded exchange map: oldest entries are evicted
/// once the window fills, in memory and (via snapshot capture order) on
/// disk.
#[derive(Default)]
struct ExchangeStore {
    map: HashMap<u64, StoredExchange>,
    order: std::collections::VecDeque<u64>,
}

impl ExchangeStore {
    fn insert(&mut self, request_id: u64, exchange: StoredExchange) {
        if self.map.insert(request_id, exchange).is_none() {
            self.order.push_back(request_id);
            while self.order.len() > MAX_EXCHANGES {
                if let Some(evicted) = self.order.pop_front() {
                    self.map.remove(&evicted);
                }
            }
        }
    }
}

/// The LLMBridge proxy.
///
/// Request-scoped state is read-mostly: `exchanges` (regeneration lookups)
/// and `quotas` (gate checks) sit behind `RwLock`s so concurrent requests
/// only serialize on the brief writes that record an exchange or charge a
/// quota.
pub struct Bridge {
    pub(crate) engine: EngineHandle,
    pub(crate) generator: Arc<Generator>,
    pub(crate) kv: KvStore,
    pub(crate) cache: SemanticCache,
    pub(crate) telemetry: Arc<Telemetry>,
    exchanges: RwLock<ExchangeStore>,
    quotas: RwLock<HashMap<String, QuotaState>>,
    /// Snapshot+WAL durability; `None` when no data dir is configured.
    persist: Option<Arc<Persistence>>,
    /// Per-model circuit breaker guarding generator execution (RouteStage).
    pub(crate) breaker: crate::ops::CircuitBreaker,
    /// Live model-pool generation (0 = Old, 1 = New), hot-swappable via
    /// `POST /admin/config {"generation": ...}`. Each request loads this
    /// exactly once and threads the loaded value through both `escalate`
    /// and `lower`, so a concurrent swap can never produce a response
    /// mixing the two pools — every response is consistent with either
    /// the pre- or post-swap snapshot. `config.generation` remains the
    /// boot value only.
    generation: std::sync::atomic::AtomicU8,
    pub config: BridgeConfig,
}

fn generation_to_u8(g: Generation) -> u8 {
    match g {
        Generation::Old => 0,
        Generation::New => 1,
    }
}

fn generation_from_u8(v: u8) -> Generation {
    if v == 0 {
        Generation::Old
    } else {
        Generation::New
    }
}

impl Bridge {
    /// Bring up the proxy over the build's serving backend: the PJRT
    /// engine loading artifacts from `dir` under `--features pjrt`, the
    /// deterministic pure-Rust backend otherwise (`dir` is then not
    /// consulted — see [`EngineHandle::spawn_from_dir`]).
    pub fn open(dir: impl AsRef<Path>) -> Result<Bridge> {
        Bridge::open_with(dir, BridgeConfig::default())
    }

    pub fn open_with(dir: impl AsRef<Path>, config: BridgeConfig) -> Result<Bridge> {
        let engine = EngineHandle::spawn_from_dir(dir)?;
        Bridge::from_engine(engine, config)
    }

    /// Build on an already-running engine (shared across bridges in tests).
    ///
    /// With `config.data_dir` set, boot restores the committed snapshot
    /// generation, replays the WAL tail on top (tolerating a torn final
    /// record), and wires the cache's journal — a populated cache serves
    /// the same hits after a restart as before it. A corrupt snapshot or
    /// an interior-corrupt WAL fails boot with [`BridgeError::Persist`]
    /// rather than silently loading partial state.
    pub fn from_engine(engine: EngineHandle, config: BridgeConfig) -> Result<Bridge> {
        let mut generator = Generator::new(engine.clone());
        generator.memoize = config.memoize;
        let embed_dim = engine.embed_dim();
        let telemetry = Arc::new(Telemetry::default());

        let mut kv = KvStore::new();
        let mut cache = SemanticCache::new(embed_dim);
        let mut quotas: HashMap<String, QuotaState> = HashMap::new();
        let mut exchanges = ExchangeStore::default();
        let mut persist = None;

        if let Some(dir) = &config.data_dir {
            let (p, boot) = Persistence::open(dir, embed_dim)?;
            if let Some(snap) = boot.snapshot {
                kv = snap.kv;
                cache = snap.cache;
                for q in snap.quotas {
                    quotas.insert(
                        q.user,
                        QuotaState {
                            requests: q.requests,
                            input_tokens: q.input_tokens,
                            output_tokens: q.output_tokens,
                        },
                    );
                }
                for e in snap.exchanges {
                    let request = Request::from_json(&e.request).map_err(|err| {
                        BridgeError::Persist(format!(
                            "snapshot exchange {:016x}: {err:#}",
                            e.request_id
                        ))
                    })?;
                    exchanges.insert(
                        e.request_id,
                        StoredExchange {
                            request,
                            regen_count: e.regen_count,
                        },
                    );
                }
            }
            let replayed = boot.wal_ops.len();
            for op in boot.wal_ops {
                match op {
                    WalOp::PutExact { prompt, response } => {
                        cache.put_exact(&prompt, &response)
                    }
                    WalOp::PutObject { object, keys } => {
                        cache.apply_logged_put(object, &keys).map_err(|e| {
                            BridgeError::Persist(format!("wal replay: {e:#}"))
                        })?
                    }
                    WalOp::Clear => cache.clear(),
                    WalOp::Quota {
                        user,
                        requests,
                        input_tokens,
                        output_tokens,
                    } => {
                        quotas.insert(
                            user,
                            QuotaState {
                                requests,
                                input_tokens,
                                output_tokens,
                            },
                        );
                    }
                    WalOp::Exchange {
                        request_id,
                        regen_count,
                        request_json,
                    } => {
                        let request = Json::parse(&request_json)
                            .and_then(|j| Request::from_json(&j))
                            .map_err(|e| {
                                BridgeError::Persist(format!(
                                    "wal exchange {request_id:016x}: {e:#}"
                                ))
                            })?;
                        exchanges.insert(
                            request_id,
                            StoredExchange {
                                request,
                                regen_count,
                            },
                        );
                    }
                    WalOp::RemoveExact { prompt } => {
                        cache.remove_exact(&prompt);
                    }
                    WalOp::PutExactV {
                        prompt,
                        response,
                        stamp,
                    } => cache.replay_put_exact_v(&prompt, &response, &stamp),
                    WalOp::RemoveExactV { prompt, stamp } => {
                        cache.replay_remove_exact_v(&prompt, &stamp)
                    }
                    WalOp::PutObjectV {
                        object,
                        keys,
                        stamp,
                    } => cache.replay_put_object_v(object, &keys, &stamp).map_err(|e| {
                        BridgeError::Persist(format!("wal replay: {e:#}"))
                    })?,
                    WalOp::Adopt { target, stamp } => cache.replay_adopt(&target, &stamp),
                }
            }
            telemetry.counters.add("persist_replayed_ops", replayed as u64);
            telemetry
                .counters
                .add("persist_truncated_bytes", boot.report.truncated_bytes);
            let p = Arc::new(p);
            // Journal wired only now: recovery itself is not re-journaled.
            cache.set_journal(p.clone());
            persist = Some(p);
        }

        if let Some(node) = &config.node_id {
            // After restore + replay (which seed the version floor) and
            // after the journal is wired (adoption records must hit the
            // WAL): turn on stamping, then retro-stamp any legacy
            // version-0 entries so a pre-replication corpus replicates.
            cache.enable_replication(node);
            let adopted = cache.adopt_unstamped();
            if adopted > 0 {
                telemetry.counters.add("sync_adopted_entries", adopted as u64);
            }
        }

        if let Some(timeout) = config.engine_timeout {
            engine.set_rpc_timeout(timeout);
        }
        let breaker = crate::ops::CircuitBreaker::new(config.breaker);

        Ok(Bridge {
            engine,
            generator: Arc::new(generator),
            kv,
            cache,
            telemetry,
            exchanges: RwLock::new(exchanges),
            quotas: RwLock::new(quotas),
            persist,
            breaker,
            generation: std::sync::atomic::AtomicU8::new(generation_to_u8(config.generation)),
            config,
        })
    }

    /// The persistence layer, when a data dir is configured.
    pub fn persistence(&self) -> Option<&Arc<Persistence>> {
        self.persist.as_ref()
    }

    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    pub fn generator(&self) -> &Generator {
        &self.generator
    }

    pub fn cache(&self) -> &SemanticCache {
        &self.cache
    }

    pub fn kv(&self) -> &KvStore {
        &self.kv
    }

    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    /// The per-model circuit breaker (admin surface + route stage).
    pub fn breaker(&self) -> &crate::ops::CircuitBreaker {
        &self.breaker
    }

    /// The live model-pool generation the delegated service types draw
    /// from. Loaded once per request (see `resolve_with`), so readers see
    /// either the pre- or post-swap pool, never a mix.
    pub fn generation(&self) -> Generation {
        generation_from_u8(self.generation.load(std::sync::atomic::Ordering::Acquire))
    }

    /// Atomically swap the live model-pool generation. In-flight requests
    /// finish on the generation they loaded at admission; requests
    /// admitted after the swap observe the new one. There is no
    /// intermediate state to observe.
    pub fn set_generation(&self, g: Generation) {
        self.generation
            .store(generation_to_u8(g), std::sync::atomic::Ordering::Release);
    }

    pub fn history(&self, user: &str, conversation: &str) -> Vec<Message> {
        HistoryStore::new(&self.kv).get(user, conversation)
    }

    pub fn clear_history(&self, user: &str, conversation: &str) {
        HistoryStore::new(&self.kv).clear(user, conversation)
    }

    // ------------------------------------------------------------ handle

    /// `proxy.request` (Table 2).
    pub fn handle(&self, req: Request) -> Result<Response, BridgeError> {
        let resp = self.resolve(&req, 0)?;
        self.record_exchange(resp.metadata.request_id, req, 0);
        Ok(resp)
    }

    /// Store (and, when durable, journal) a served exchange so
    /// `regenerate` works across restarts. Append under the exchange
    /// write lock so WAL order matches state order.
    fn record_exchange(&self, request_id: u64, request: Request, regen_count: u32) {
        let _gate = self.persist.as_ref().map(|p| p.gate_shared());
        let mut ex = self.exchanges.write().unwrap();
        if let Some(p) = &self.persist {
            p.append_best_effort(&WalOp::Exchange {
                request_id,
                regen_count,
                request_json: request.to_json().to_string(),
            });
        }
        ex.insert(
            request_id,
            StoredExchange {
                request,
                regen_count,
            },
        );
    }

    /// `proxy.regenerate` (Table 2): re-resolve a previous request.
    /// `new_service_type = None` keeps the same type but nudges the proxy
    /// toward quality (§3.2).
    pub fn regenerate(
        &self,
        request_id: u64,
        new_service_type: Option<ServiceType>,
    ) -> Result<Response, BridgeError> {
        let (mut req, count) = {
            let ex = self.exchanges.read().unwrap();
            let e = ex
                .map
                .get(&request_id)
                .ok_or(BridgeError::UnknownRequest(request_id))?;
            (e.request.clone(), e.regen_count + 1)
        };
        // One generation load for the whole regeneration: escalate and
        // resolve must agree even if an admin swap lands between them.
        let generation = self.generation();
        req.service_type = match new_service_type {
            Some(st) => st,
            None => router::escalate(&req.service_type, generation),
        };
        self.telemetry.counters.incr("regenerations");
        let resp = self.resolve_with(&req, count, generation)?;
        self.record_exchange(resp.metadata.request_id, req, count);
        Ok(resp)
    }

    // ---------------------------------------------------------- pipeline

    /// Thread one request through the staged pipeline. All service-type
    /// semantics live in the lowered [`router::ServicePolicy`]; all model
    /// choice in the routing policy it carries.
    fn resolve(&self, req: &Request, regen_count: u32) -> Result<Response, BridgeError> {
        self.resolve_with(req, regen_count, self.generation())
    }

    /// `resolve` with an explicitly threaded generation: the caller loads
    /// the live generation exactly once, so every model choice this
    /// request makes (the lowered policy is the complete routing table)
    /// comes from one consistent snapshot even while an admin swap races.
    fn resolve_with(
        &self,
        req: &Request,
        regen_count: u32,
        generation: Generation,
    ) -> Result<Response, BridgeError> {
        self.telemetry.counters.incr("requests");
        let policy = router::lower(&req.service_type, generation, regen_count);
        let mut cx = RequestCtx::new(req, regen_count, policy);

        let stages: [&dyn Stage; 3] = [&CacheStage, &ContextStage, &RouteStage];
        for stage in stages {
            if let Flow::Done = stage.run(self, &mut cx)? {
                break;
            }
        }
        AccountStage.run(self, &mut cx)?;

        let meta = cx.meta.take().expect("account stage builds metadata");
        let text = cx.text.take().expect("pipeline produced a response");
        let (model, grounded_citations) = match cx.answer_model {
            Some(m) => (m.as_str().to_string(), m.spec().grounded_citations),
            None => ("cache".to_string(), false),
        };
        Ok(self.finish(req, regen_count, text, meta, model, grounded_citations))
    }

    fn finish(
        &self,
        req: &Request,
        regen_count: u32,
        text: String,
        meta: crate::api::Metadata,
        model: String,
        grounded_citations: bool,
    ) -> Response {
        if req.update_context {
            let history = HistoryStore::new(&self.kv);
            let msg = Message {
                prompt: req.prompt.clone(),
                response: text.clone(),
                model,
                grounded_citations,
                seq: 0,
            };
            if regen_count > 0 {
                // §5.1: regeneration replaces the initial response in the
                // context rather than appending a duplicate turn.
                history.replace_last(&req.user, &req.conversation, msg);
            } else {
                history.append(&req.user, &req.conversation, msg);
            }
        }
        if self.config.prefetch_followups && regen_count == 0 {
            if let Err(e) = self.prefetch_followups(req) {
                self.telemetry.counters.incr("prefetch_errors");
                let _ = e;
            }
        }
        Response {
            text,
            metadata: meta,
        }
    }

    /// Anticipate follow-up queries and cache their answers (§5.1: shown
    /// as WhatsApp buttons; exact-match retrieval on press).
    fn prefetch_followups(&self, req: &Request) -> Result<()> {
        let kws = crate::cache::chunker::keywords(&req.prompt, 2);
        let Some(kw) = kws.first() else {
            return Ok(());
        };
        // Anticipate both single-keyword and bigram-topic phrasings
        // ("more about sleep" and "more about sleep hygiene").
        let mut followups = vec![
            format!("more about {kw}"),
            format!("why is {kw} important"),
            format!("history of {kw}"),
        ];
        if kws.len() >= 2 {
            followups.push(format!("more about {} {}", kws[0], kws[1]));
            followups.push(format!("more about {} {}", kws[1], kws[0]));
        }
        for followup in followups {
            if self.cache.get_exact(&followup).is_none() {
                let c = self
                    .generator
                    .generate(ModelId::Claude3Haiku, &followup, Some(16))?;
                self.telemetry.counters.incr("prefetched_followups");
                self.telemetry.costs.record(
                    c.model.as_str(),
                    c.input_tokens,
                    c.output_tokens,
                    c.cost_usd,
                );
                self.cache.put_exact(&followup, &c.text);
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------- quota

    /// Atomically gate one request against the user's quota: under a
    /// single write lock, reject if any cap is already met, else reserve
    /// the request slot. Check-and-reserve in one critical section means
    /// concurrent requests from one user cannot all slip past the cap
    /// between a read-side check and a later charge. Returns whether the
    /// slot was reserved.
    pub(crate) fn reserve_quota_slot(&self, user: &str) -> bool {
        let _gate = self.persist.as_ref().map(|p| p.gate_shared());
        let mut q = self.quotas.write().unwrap();
        let quota = &self.config.quota;
        let st = q.entry(user.to_string()).or_default();
        if st.requests >= quota.max_requests
            || st.input_tokens >= quota.max_input_tokens
            || st.output_tokens >= quota.max_output_tokens
        {
            return false;
        }
        st.requests += 1;
        self.journal_quota(user, st);
        true
    }

    /// Roll back a reservation whose request failed after the gate — a
    /// request that served nothing must not consume quota.
    pub(crate) fn release_quota_slot(&self, user: &str) {
        let _gate = self.persist.as_ref().map(|p| p.gate_shared());
        let mut q = self.quotas.write().unwrap();
        if let Some(st) = q.get_mut(user) {
            st.requests = st.requests.saturating_sub(1);
            let st = st.clone();
            self.journal_quota(user, &st);
        }
    }

    /// Charge a resolved request's token usage (its request slot was
    /// reserved at the route gate).
    pub(crate) fn charge_quota_tokens(&self, user: &str, input_tokens: u64, output_tokens: u64) {
        let _gate = self.persist.as_ref().map(|p| p.gate_shared());
        let mut q = self.quotas.write().unwrap();
        let st = q.entry(user.to_string()).or_default();
        st.input_tokens += input_tokens;
        st.output_tokens += output_tokens;
        self.journal_quota(user, st);
    }

    /// Journal a user's absolute quota state. Called while the caller
    /// still holds the quota write lock (so WAL record order matches
    /// state-mutation order; the replay rule is last-record-wins).
    fn journal_quota(&self, user: &str, st: &QuotaState) {
        if let Some(p) = &self.persist {
            p.append_best_effort(&WalOp::Quota {
                user: user.to_string(),
                requests: st.requests,
                input_tokens: st.input_tokens,
                output_tokens: st.output_tokens,
            });
        }
    }

    /// Quota usage for a user (classroom dashboards).
    pub fn quota_usage(&self, user: &str) -> (u64, u64, u64) {
        let q = self.quotas.read().unwrap();
        q.get(user)
            .map(|s| (s.requests, s.input_tokens, s.output_tokens))
            .unwrap_or((0, 0, 0))
    }

    // ------------------------------------------------------- compaction

    /// Fold the WAL into a fresh snapshot generation (no-op without a
    /// data dir; returns whether a compaction ran). The persist layer
    /// holds its gate exclusively across the capture, so the snapshot is
    /// a consistent cut and the superseded WAL is complete.
    pub fn compact_persistence(&self) -> Result<bool, BridgeError> {
        let Some(p) = &self.persist else {
            return Ok(false);
        };
        let ran = p.compact_with(self.engine.embed_dim(), |tmp| {
            // History writes are not gated, so the manifest must describe
            // exactly the rows the file captured — snapshot() returns the
            // (len, checksum) it computed under the shard locks as it
            // wrote, never a second (possibly newer) read of the store.
            let (kv_len, kv_checksum) = self
                .kv
                .snapshot(&tmp.join("kv.jsonl"))
                .map_err(|e| BridgeError::Persist(format!("kv snapshot: {e:#}")))?;
            self.cache
                .snapshot_into(tmp)
                .map_err(|e| BridgeError::Persist(format!("cache snapshot: {e:#}")))?;
            let quotas: Vec<QuotaRow> = {
                let q = self.quotas.read().unwrap();
                q.iter()
                    .map(|(user, st)| QuotaRow {
                        user: user.clone(),
                        requests: st.requests,
                        input_tokens: st.input_tokens,
                        output_tokens: st.output_tokens,
                    })
                    .collect()
            };
            let exchanges: Vec<ExchangeRow> = {
                // Capture in insertion order so the restored store evicts
                // the same (oldest) entries when the window refills.
                let ex = self.exchanges.read().unwrap();
                ex.order
                    .iter()
                    .filter_map(|id| {
                        ex.map.get(id).map(|e| ExchangeRow {
                            request_id: *id,
                            regen_count: e.regen_count,
                            request: e.request.to_json(),
                        })
                    })
                    .collect()
            };
            crate::persist::snapshot::write_state(
                &tmp.join("state.jsonl"),
                &quotas,
                &exchanges,
            )?;
            // Cache/quota/exchange mutators all hold the gate, so these
            // reads are consistent with the files just written.
            Ok(CaptureCounts {
                objects: self.cache.len_objects(),
                keys: self.cache.len_keys(),
                exact: self.cache.len_exact(),
                next_id: self.cache.next_id_hint(),
                kv_len,
                kv_checksum,
            })
        })?;
        if ran {
            self.telemetry.counters.incr("persist_compactions");
        }
        Ok(ran)
    }

    /// Compact iff the WAL has outgrown `config.compact_wal_bytes` — the
    /// size-keyed trigger the server's background janitor polls.
    pub fn maybe_compact(&self) -> Result<bool, BridgeError> {
        let Some(p) = &self.persist else {
            return Ok(false);
        };
        if p.wal_len() < self.config.compact_wal_bytes {
            return Ok(false);
        }
        self.compact_persistence()
    }

    /// Run one semantic-cache index maintenance step if due (flat→IVF
    /// migration past the threshold, or a drift-triggered retrain). The
    /// k-means runs off every request path — the server's janitor polls
    /// this; returns whether a rebuild ran. Unlike compaction this is
    /// independent of persistence: a purely in-memory cache migrates too.
    pub fn maybe_rebuild_index(&self) -> bool {
        let ran = self.cache.maybe_rebuild_index();
        if ran {
            self.telemetry.counters.incr("index_rebuilds");
        }
        ran
    }
}

pub(crate) fn exchange_id(req: &Request, regen_count: u32) -> u64 {
    req.stable_id() ^ ((regen_count as u64) << 56)
}

// ---------------------------------------------------------------------
// Batch mode (§5.2 future work): "users can submit a batch of prompts to
// be processed by multiple models simultaneously ... lowering the
// development overhead of benchmarking and compositional workflows."
// ---------------------------------------------------------------------

/// One batch entry result: the same prompt resolved under several models,
/// side by side — the §5.2 benchmarking workflow as a first-class call.
#[derive(Debug)]
pub struct BatchComparison {
    pub prompt: String,
    /// (model, response) per requested model, in request order.
    pub responses: Vec<(ModelId, Response)>,
}

impl Bridge {
    /// Resolve every prompt under every model. Context and cache are
    /// bypassed (benchmarking semantics: identical isolated inputs), so
    /// every (prompt, model) cell is independent — a bounded pool of
    /// scoped threads pulls cells off a shared counter and fans out
    /// across the concurrent hot path.
    pub fn handle_batch(
        &self,
        user: &str,
        prompts: &[String],
        models: &[ModelId],
    ) -> Result<Vec<BatchComparison>, BridgeError> {
        let n_cells = prompts.len() * models.len();
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(n_cells)
            .max(1);
        let next = std::sync::atomic::AtomicUsize::new(0);
        let failed = std::sync::atomic::AtomicBool::new(false);
        let cells: std::sync::Mutex<Vec<Option<Result<Response, BridgeError>>>> =
            std::sync::Mutex::new((0..n_cells).map(|_| None).collect());
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    // Stop pulling fresh cells once any cell errored:
                    // don't bill the rest of a batch that will be thrown
                    // away (in-flight cells still finish).
                    if failed.load(std::sync::atomic::Ordering::Relaxed) {
                        break;
                    }
                    let cell = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if cell >= n_cells {
                        break;
                    }
                    let (i, j) = (cell / models.len(), cell % models.len());
                    let model = models[j];
                    let req = Request::new(user, &format!("batch-{i}-{model}"), &prompts[i])
                        .service_type(ServiceType::Fixed {
                            model,
                            cache: CachePolicy::Skip,
                            context_k: 0,
                        })
                        .no_context_update();
                    let result = self.handle(req);
                    if result.is_err() {
                        failed.store(true, std::sync::atomic::Ordering::Relaxed);
                    }
                    cells.lock().unwrap()[cell] = Some(result);
                });
            }
        });
        let mut flat = cells.into_inner().unwrap();
        // An error leaves later cells unfilled; surface the first one
        // recorded (row-major) rather than an incomplete comparison.
        if let Some(pos) = flat.iter().position(|c| matches!(c, Some(Err(_)))) {
            if let Some(Err(e)) = flat.remove(pos) {
                return Err(e);
            }
        }
        let mut flat = flat.into_iter();
        let mut out = Vec::with_capacity(prompts.len());
        for prompt in prompts {
            let mut responses = Vec::with_capacity(models.len());
            for model in models {
                match flat.next() {
                    Some(Some(Ok(resp))) => responses.push((*model, resp)),
                    _ => unreachable!("error scan above returned early"),
                }
            }
            self.telemetry.counters.incr("batch_prompts");
            out.push(BatchComparison {
                prompt: prompt.clone(),
                responses,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exchange_id_varies_with_regen_count() {
        let req = Request::new("u", "c", "prompt");
        let a = exchange_id(&req, 0);
        let b = exchange_id(&req, 1);
        assert_ne!(a, b);
        assert_eq!(a, exchange_id(&req, 0));
    }
}
