//! The Bridge: everything a request touches, in the paper's order —
//! cache (§3.5) → context manager (§3.4) → model adapter (§3.3) — plus
//! transparency metadata, history updates, regeneration, quotas, and
//! prefetch of anticipated follow-ups (§5.1).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::adapter::{cascade_models, Cascade};
use crate::api::{CacheOutcome, CachePolicy, Metadata, Request, Response, ServiceType};
use crate::cache::SemanticCache;
use crate::context::{Filter, FilterCtx, HistoryStore, Message};
use crate::kvstore::KvStore;
use crate::models::generator::{Completion, Generator};
use crate::models::pricing::{Generation, LatencyClass, ModelId, POOL};
use crate::models::quality::{latent_score, GenCondition};
use crate::runtime::{EngineHandle, Registry};
use crate::telemetry::Telemetry;
use crate::workload::classroom::Quota;

/// Proxy configuration.
#[derive(Clone, Debug)]
pub struct BridgeConfig {
    /// Synchronously prefetch follow-up answers into the exact cache after
    /// each response (the WhatsApp buttons; async in production, sync here
    /// for determinism).
    pub prefetch_followups: bool,
    /// Which model generation the delegated service types draw from.
    pub generation: Generation,
    /// Memoize completions (replay accelerator; see Generator docs).
    pub memoize: bool,
    /// Per-user quota for the usage-based service type.
    pub quota: Quota,
}

impl Default for BridgeConfig {
    fn default() -> Self {
        BridgeConfig {
            prefetch_followups: false,
            generation: Generation::New,
            memoize: true,
            quota: Quota::default(),
        }
    }
}

#[derive(Default, Clone, Debug)]
struct QuotaState {
    requests: u64,
    input_tokens: u64,
    output_tokens: u64,
}

struct StoredExchange {
    request: Request,
    regen_count: u32,
}

/// The LLMBridge proxy.
///
/// Request-scoped state is read-mostly: `exchanges` (regeneration lookups)
/// and `quotas` (gate checks) sit behind `RwLock`s so concurrent requests
/// only serialize on the brief writes that record an exchange or charge a
/// quota.
pub struct Bridge {
    engine: EngineHandle,
    generator: Arc<Generator>,
    kv: KvStore,
    cache: SemanticCache,
    telemetry: Arc<Telemetry>,
    exchanges: RwLock<HashMap<u64, StoredExchange>>,
    quotas: RwLock<HashMap<String, QuotaState>>,
    pub config: BridgeConfig,
}

impl Bridge {
    /// Load artifacts from `dir` and bring up the proxy.
    pub fn open(dir: impl AsRef<Path>) -> Result<Bridge> {
        Bridge::open_with(dir, BridgeConfig::default())
    }

    pub fn open_with(dir: impl AsRef<Path>, config: BridgeConfig) -> Result<Bridge> {
        let registry = Registry::load(dir)?;
        let engine = EngineHandle::spawn(registry)?;
        Bridge::from_engine(engine, config)
    }

    /// Build on an already-running engine (shared across bridges in tests).
    pub fn from_engine(engine: EngineHandle, config: BridgeConfig) -> Result<Bridge> {
        let mut generator = Generator::new(engine.clone());
        generator.memoize = config.memoize;
        let embed_dim = engine.embed_dim();
        Ok(Bridge {
            engine,
            generator: Arc::new(generator),
            kv: KvStore::new(),
            cache: SemanticCache::new(embed_dim),
            telemetry: Arc::new(Telemetry::default()),
            exchanges: RwLock::new(HashMap::new()),
            quotas: RwLock::new(HashMap::new()),
            config,
        })
    }

    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    pub fn generator(&self) -> &Generator {
        &self.generator
    }

    pub fn cache(&self) -> &SemanticCache {
        &self.cache
    }

    pub fn kv(&self) -> &KvStore {
        &self.kv
    }

    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    pub fn history(&self, user: &str, conversation: &str) -> Vec<Message> {
        HistoryStore::new(&self.kv).get(user, conversation)
    }

    pub fn clear_history(&self, user: &str, conversation: &str) {
        HistoryStore::new(&self.kv).clear(user, conversation)
    }

    // ------------------------------------------------------------ handle

    /// `proxy.request` (Table 2).
    pub fn handle(&self, req: Request) -> Result<Response> {
        let resp = self.resolve(&req, 0)?;
        self.exchanges.write().unwrap().insert(
            resp.metadata.request_id,
            StoredExchange {
                request: req,
                regen_count: 0,
            },
        );
        Ok(resp)
    }

    /// `proxy.regenerate` (Table 2): re-resolve a previous request.
    /// `new_service_type = None` keeps the same type but nudges the proxy
    /// toward quality (§3.2).
    pub fn regenerate(
        &self,
        request_id: u64,
        new_service_type: Option<ServiceType>,
    ) -> Result<Response> {
        let (mut req, count) = {
            let ex = self.exchanges.read().unwrap();
            let e = ex
                .get(&request_id)
                .ok_or_else(|| anyhow::anyhow!("unknown request id {request_id:x}"))?;
            (e.request.clone(), e.regen_count + 1)
        };
        req.service_type = match new_service_type {
            Some(st) => st,
            None => escalate(&req.service_type, self.config.generation),
        };
        self.telemetry.counters.incr("regenerations");
        let resp = self.resolve(&req, count)?;
        self.exchanges.write().unwrap().insert(
            resp.metadata.request_id,
            StoredExchange {
                request: req,
                regen_count: count,
            },
        );
        Ok(resp)
    }

    // ---------------------------------------------------------- pipeline

    fn resolve(&self, req: &Request, regen_count: u32) -> Result<Response> {
        let start = Instant::now();
        self.telemetry.counters.incr("requests");

        let mut models_used: Vec<(String, String)> = Vec::new();
        let mut calls: Vec<Completion> = Vec::new();
        let mut cache_outcome = CacheOutcome::Skipped;
        let mut grounded = false;
        let mut verifier_score = None;

        // ---- Stage ②: cache -------------------------------------------
        // Exact-match lookup runs before history/traits are materialized:
        // the prefetched-button path (§5.1) is the latency-critical one
        // (EXPERIMENTS.md §Perf).
        let skip_cache = matches!(
            req.service_type,
            ServiceType::Fixed {
                cache: CachePolicy::Skip,
                ..
            }
        );
        if !skip_cache && regen_count == 0 {
            if let Some(text) = self.cache.get_exact(&req.prompt) {
                // Prefetched exact hit (WhatsApp buttons): zero LLM cost.
                self.telemetry.counters.incr("cache_exact_hits");
                let traits = req.effective_traits();
                let latent = latent_score(&traits, 0.9, GenCondition::default());
                let latency_ms = start.elapsed().as_secs_f64() * 1e3;
                self.telemetry.request_latency.record(start.elapsed());
                return Ok(self.finish(
                    req,
                    regen_count,
                    text,
                    Metadata {
                        request_id: exchange_id(req, regen_count),
                        service_type: req.service_type.name().to_string(),
                        models_used: vec![],
                        cache: CacheOutcome::ExactHit,
                        context_messages: 0,
                        input_tokens: 0,
                        output_tokens: 0,
                        cost_usd: 0.0,
                        latency_ms,
                        verifier_score: None,
                        context_llm_ms: 0.0,
                        llm_ms: 0.0,
                        latent_quality: latent,
                        grounded: false,
                        regen_count,
                    },
                    "cache".to_string(),
                    false,
                ));
            }
        }
        let traits = req.effective_traits();
        let history = HistoryStore::new(&self.kv);
        let msgs = history.get(&req.user, &req.conversation);
        let mut smart_cache_response: Option<String> = None;
        if let ServiceType::SmartCache { model } = &req.service_type {
            if regen_count == 0 {
                let out =
                    self.cache
                        .smart_get(&self.generator, *model, &req.prompt, &traits)?;
                calls.extend(out.llm_calls.iter().cloned());
                for c in &out.llm_calls {
                    models_used.push((c.model.as_str().to_string(), "cache-llm".into()));
                }
                match (&out.hit, out.used) {
                    (Some(h), true) => {
                        cache_outcome = CacheOutcome::SemanticHit { score: h.score };
                        grounded = true;
                        smart_cache_response = out.response.clone();
                        self.telemetry.counters.incr("cache_semantic_hits");
                    }
                    (Some(_), false) | (None, _) => {
                        cache_outcome = CacheOutcome::Miss;
                        self.telemetry.counters.incr("cache_misses");
                    }
                }
            } else {
                cache_outcome = CacheOutcome::Skipped;
            }
        }

        // ---- Stage ③: context manager ---------------------------------
        let filter = self.context_filter(&req.service_type, regen_count);
        let cx = FilterCtx {
            generator: &self.generator,
            traits: &traits,
        };
        let selection = filter.apply(&msgs, &req.prompt, &cx)?;
        let context_llm_ms: f64 = selection
            .llm_calls
            .iter()
            .map(|c| c.latency.as_secs_f64() * 1e3)
            .sum();
        for c in &selection.llm_calls {
            models_used.push((c.model.as_str().to_string(), "context-llm".into()));
        }
        calls.extend(selection.llm_calls.iter().cloned());
        let ctx_messages = selection.messages(&msgs);
        let sufficiency = selection.sufficiency(msgs.len());
        let rendered_ctx: String = ctx_messages
            .iter()
            .map(|m| m.render())
            .collect::<Vec<_>>()
            .join("\n");
        let input_text = if rendered_ctx.is_empty() {
            req.prompt.clone()
        } else {
            format!("{rendered_ctx}\nuser: {}", req.prompt)
        };

        // ---- Stage ④: model adapter -----------------------------------
        let cond = GenCondition {
            context_sufficiency: sufficiency,
            grounded,
        };
        let (text, latent, answer_model) = if let Some(resp_text) = smart_cache_response {
            // Cache content already produced the response (cache-LLM calls
            // are billed above).
            let model = match &req.service_type {
                ServiceType::SmartCache { model } => *model,
                _ => unreachable!(),
            };
            let latent = latent_score(&traits, model.spec().capability, cond);
            (resp_text, latent, model)
        } else {
            match &req.service_type {
                ServiceType::ModelSelector {
                    threshold,
                    m1,
                    m2,
                    verifier,
                } => {
                    let (m1, m2, v) =
                        cascade_models(self.config.generation, *m1, *m2, *verifier)?;
                    let cascade = Cascade {
                        m1,
                        m2,
                        verifier: v,
                        threshold: *threshold,
                    };
                    let result =
                        cascade.run(&self.generator, &input_text, &req.prompt, &traits, cond)?;
                    models_used.push((m1.as_str().into(), "m1".into()));
                    models_used.push((v.as_str().into(), "verifier".into()));
                    if result.escalated {
                        models_used.push((m2.as_str().into(), "m2".into()));
                        self.telemetry.counters.incr("cascade_escalations");
                    }
                    verifier_score = Some(result.verifier_score);
                    calls.extend(result.calls.iter().cloned());
                    (
                        result.completion.text.clone(),
                        result.latent,
                        result.completion.model,
                    )
                }
                other => {
                    let model = self.pick_model(other, req)?;
                    let completion = self.generator.generate(model, &input_text, None)?;
                    models_used.push((model.as_str().into(), "answer".into()));
                    let latent = latent_score(&traits, model.spec().capability, cond);
                    calls.push(completion.clone());
                    (completion.text, latent, model)
                }
            }
        };

        // ---- Accounting -------------------------------------------------
        let mut input_tokens = 0;
        let mut output_tokens = 0;
        let mut cost = 0.0;
        let mut llm_ms = 0.0;
        for c in &calls {
            llm_ms += c.latency.as_secs_f64() * 1e3;
            input_tokens += c.input_tokens;
            output_tokens += c.output_tokens;
            cost += c.cost_usd;
            self.telemetry
                .costs
                .record(c.model.as_str(), c.input_tokens, c.output_tokens, c.cost_usd);
            match c.model.spec().latency_class {
                LatencyClass::Small => self.telemetry.llm_latency_small.record(c.latency),
                LatencyClass::Large => self.telemetry.llm_latency_large.record(c.latency),
            }
        }
        if let ServiceType::UsageBased { .. } = &req.service_type {
            let mut q = self.quotas.write().unwrap();
            let st = q.entry(req.user.clone()).or_default();
            st.requests += 1;
            st.input_tokens += input_tokens;
            st.output_tokens += output_tokens;
        }
        let latency_ms = start.elapsed().as_secs_f64() * 1e3;
        self.telemetry.request_latency.record(start.elapsed());

        let meta = Metadata {
            request_id: exchange_id(req, regen_count),
            service_type: req.service_type.name().to_string(),
            models_used,
            cache: cache_outcome,
            context_messages: ctx_messages.len(),
            input_tokens,
            output_tokens,
            cost_usd: cost,
            latency_ms,
            verifier_score,
            context_llm_ms,
            llm_ms,
            latent_quality: latent,
            grounded,
            regen_count,
        };
        Ok(self.finish(
            req,
            regen_count,
            text,
            meta,
            answer_model.as_str().to_string(),
            answer_model.spec().grounded_citations,
        ))
    }

    fn finish(
        &self,
        req: &Request,
        regen_count: u32,
        text: String,
        meta: Metadata,
        model: String,
        grounded_citations: bool,
    ) -> Response {
        if req.update_context {
            let history = HistoryStore::new(&self.kv);
            let msg = Message {
                prompt: req.prompt.clone(),
                response: text.clone(),
                model,
                grounded_citations,
                seq: 0,
            };
            if regen_count > 0 {
                // §5.1: regeneration replaces the initial response in the
                // context rather than appending a duplicate turn.
                history.replace_last(&req.user, &req.conversation, msg);
            } else {
                history.append(&req.user, &req.conversation, msg);
            }
        }
        if self.config.prefetch_followups && regen_count == 0 {
            if let Err(e) = self.prefetch_followups(req) {
                self.telemetry.counters.incr("prefetch_errors");
                let _ = e;
            }
        }
        Response {
            text,
            metadata: meta,
        }
    }

    /// Anticipate follow-up queries and cache their answers (§5.1: shown
    /// as WhatsApp buttons; exact-match retrieval on press).
    fn prefetch_followups(&self, req: &Request) -> Result<()> {
        let kws = crate::cache::chunker::keywords(&req.prompt, 2);
        let Some(kw) = kws.first() else {
            return Ok(());
        };
        // Anticipate both single-keyword and bigram-topic phrasings
        // ("more about sleep" and "more about sleep hygiene").
        let mut followups = vec![
            format!("more about {kw}"),
            format!("why is {kw} important"),
            format!("history of {kw}"),
        ];
        if kws.len() >= 2 {
            followups.push(format!("more about {} {}", kws[0], kws[1]));
            followups.push(format!("more about {} {}", kws[1], kws[0]));
        }
        for followup in followups {
            if self.cache.get_exact(&followup).is_none() {
                let c = self
                    .generator
                    .generate(ModelId::Claude3Haiku, &followup, Some(16))?;
                self.telemetry.counters.incr("prefetched_followups");
                self.telemetry.costs.record(
                    c.model.as_str(),
                    c.input_tokens,
                    c.output_tokens,
                    c.cost_usd,
                );
                self.cache.put_exact(&followup, &c.text);
            }
        }
        Ok(())
    }

    /// The context filter each service type implies (§3.2's list).
    fn context_filter(&self, st: &ServiceType, regen_count: u32) -> Filter {
        match st {
            ServiceType::Fixed { context_k, .. } => Filter::LastK(*context_k),
            ServiceType::Quality => Filter::All,
            ServiceType::Cost => Filter::None,
            // §3.2: model_selector "uses 5 previous messages as context".
            ServiceType::ModelSelector { .. } => Filter::LastK(5),
            ServiceType::SmartContext { k, model } => {
                if regen_count > 0 {
                    // Regeneration nudges toward quality: full last-k.
                    Filter::LastK(*k)
                } else {
                    Filter::smart_last_k(*k, *model)
                }
            }
            ServiceType::SmartCache { .. } => Filter::None,
            ServiceType::UsageBased { .. } => Filter::LastK(3),
            ServiceType::LatencyFirst => Filter::LastK(1),
        }
    }

    /// Model choice for the non-cascade service types.
    fn pick_model(&self, st: &ServiceType, req: &Request) -> Result<ModelId> {
        Ok(match st {
            ServiceType::Fixed { model, .. } => *model,
            // §3.2 quality: "the most expensive model".
            ServiceType::Quality => POOL
                .iter()
                .filter(|m| m.generation == self.config.generation)
                .max_by(|a, b| a.usd_per_mtok_in.partial_cmp(&b.usd_per_mtok_in).unwrap())
                .map(|m| m.id)
                .unwrap(),
            // §3.2 cost: "the cheapest model".
            ServiceType::Cost => POOL
                .iter()
                .filter(|m| m.generation == self.config.generation)
                .min_by(|a, b| a.usd_per_mtok_in.partial_cmp(&b.usd_per_mtok_in).unwrap())
                .map(|m| m.id)
                .unwrap(),
            ServiceType::SmartContext { .. } => match self.config.generation {
                Generation::Old => ModelId::Gpt4,
                Generation::New => ModelId::Gpt4o,
            },
            ServiceType::SmartCache { model } => *model,
            ServiceType::UsageBased { allowed, fallback } => {
                // Quota gate.
                {
                    let q = self.quotas.read().unwrap();
                    if let Some(st) = q.get(&req.user) {
                        let quota = &self.config.quota;
                        if st.requests >= quota.max_requests
                            || st.input_tokens >= quota.max_input_tokens
                            || st.output_tokens >= quota.max_output_tokens
                        {
                            self.telemetry.counters.incr("quota_rejections");
                            bail!("quota exceeded for user {}", req.user);
                        }
                    }
                }
                let wanted = req
                    .params
                    .get("model")
                    .map(|m| ModelId::parse(m))
                    .transpose()?;
                match wanted {
                    Some(m) if allowed.contains(&m) => m,
                    Some(_) => {
                        // Curated-list deny (the §5.2 "domain denylist"
                        // analogy): fall back instead of failing.
                        self.telemetry.counters.incr("model_denied");
                        *fallback
                    }
                    None => *fallback,
                }
            }
            ServiceType::LatencyFirst => ModelId::Claude3Haiku,
            ServiceType::ModelSelector { .. } => unreachable!("handled by cascade"),
        })
    }

    /// Quota usage for a user (classroom dashboards).
    pub fn quota_usage(&self, user: &str) -> (u64, u64, u64) {
        let q = self.quotas.read().unwrap();
        q.get(user)
            .map(|s| (s.requests, s.input_tokens, s.output_tokens))
            .unwrap_or((0, 0, 0))
    }
}

fn exchange_id(req: &Request, regen_count: u32) -> u64 {
    req.stable_id() ^ ((regen_count as u64) << 56)
}

/// Same-service-type regeneration: "nudge the proxy to prioritize quality
/// over cost" (§3.2).
fn escalate(st: &ServiceType, generation: Generation) -> ServiceType {
    let big = match generation {
        Generation::Old => ModelId::Gpt4,
        Generation::New => ModelId::Gpt4o,
    };
    match st {
        // §3.3: "regenerate will directly route the prompt to the more
        // expensive LLM".
        ServiceType::ModelSelector { m2, .. } => ServiceType::Fixed {
            model: m2.unwrap_or(big),
            cache: CachePolicy::Skip,
            context_k: 5,
        },
        // §3.2: "for smart_context, regenerating entails using more
        // context".
        ServiceType::SmartContext { k, .. } => ServiceType::Fixed {
            model: big,
            cache: CachePolicy::Skip,
            context_k: (*k).max(5),
        },
        ServiceType::SmartCache { .. } => ServiceType::ModelSelector {
            threshold: 8.0,
            m1: None,
            m2: None,
            verifier: None,
        },
        ServiceType::Cost => ServiceType::Quality,
        ServiceType::LatencyFirst => ServiceType::Fixed {
            model: big,
            cache: CachePolicy::Skip,
            context_k: 5,
        },
        other => other.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escalate_model_selector_goes_direct_m2() {
        let st = ServiceType::ModelSelector {
            threshold: 8.0,
            m1: None,
            m2: Some(ModelId::Gpt4),
            verifier: None,
        };
        match escalate(&st, Generation::Old) {
            ServiceType::Fixed { model, .. } => assert_eq!(model, ModelId::Gpt4),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn escalate_smart_context_adds_context() {
        let st = ServiceType::SmartContext {
            k: 1,
            model: ModelId::Claude3Haiku,
        };
        match escalate(&st, Generation::New) {
            ServiceType::Fixed {
                model, context_k, ..
            } => {
                assert_eq!(model, ModelId::Gpt4o);
                assert_eq!(context_k, 5);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn escalate_cost_becomes_quality() {
        assert_eq!(escalate(&ServiceType::Cost, Generation::New), ServiceType::Quality);
    }
}

// ---------------------------------------------------------------------
// Batch mode (§5.2 future work): "users can submit a batch of prompts to
// be processed by multiple models simultaneously ... lowering the
// development overhead of benchmarking and compositional workflows."
// ---------------------------------------------------------------------

/// One batch entry result: the same prompt resolved under several models,
/// side by side — the §5.2 benchmarking workflow as a first-class call.
#[derive(Debug)]
pub struct BatchComparison {
    pub prompt: String,
    /// (model, response) per requested model, in request order.
    pub responses: Vec<(ModelId, Response)>,
}

impl Bridge {
    /// Resolve every prompt under every model. Context and cache are
    /// bypassed (benchmarking semantics: identical isolated inputs).
    pub fn handle_batch(
        &self,
        user: &str,
        prompts: &[String],
        models: &[ModelId],
    ) -> Result<Vec<BatchComparison>> {
        let mut out = Vec::with_capacity(prompts.len());
        for (i, prompt) in prompts.iter().enumerate() {
            let mut responses = Vec::with_capacity(models.len());
            for model in models {
                let req = Request::new(user, &format!("batch-{i}-{model}"), prompt)
                    .service_type(ServiceType::Fixed {
                        model: *model,
                        cache: CachePolicy::Skip,
                        context_k: 0,
                    })
                    .no_context_update();
                responses.push((*model, self.handle(req)?));
            }
            self.telemetry.counters.incr("batch_prompts");
            out.push(BatchComparison {
                prompt: prompt.clone(),
                responses,
            });
        }
        Ok(out)
    }
}
