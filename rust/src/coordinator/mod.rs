//! The coordinator: LLMBridge's request pipeline (paper Fig 2, order
//! ②-④: cache → context manager → model adapter), regeneration,
//! per-user FIFO dispatch, quotas, and follow-up prefetching.

pub mod pipeline;

pub use pipeline::{Bridge, BridgeConfig};
