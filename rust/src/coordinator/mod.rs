//! The coordinator: LLMBridge's request pipeline (paper Fig 2, order
//! ②-④: cache → context manager → model adapter) as explicit stages
//! threaded over a [`ctx::RequestCtx`], plus regeneration, per-user FIFO
//! dispatch, quotas, and follow-up prefetching. Model choice is delegated
//! to [`crate::router`].

pub mod ctx;
pub mod pipeline;
pub mod stages;

pub use pipeline::{BatchComparison, Bridge, BridgeConfig};
