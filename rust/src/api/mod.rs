//! The LLMBridge API (paper §3.2, Table 2): a high-level, **bidirectional**
//! interface.
//!
//! * Applications *delegate* by choosing a [`ServiceType`] per request —
//!   from fully explicit (`Fixed`) to fully delegated (`ModelSelector`,
//!   `SmartContext`, `SmartCache`).
//! * The proxy is *transparent*: every [`Response`] carries [`Metadata`]
//!   describing exactly how the prompt was resolved (models used, cache
//!   outcome, context size, cost) — the LLM analog of `X-Cache`/`Age`.
//! * Applications *iterate*: `Bridge::regenerate` re-resolves a prompt,
//!   nudging the proxy toward quality (same service type) or any new
//!   preference (different service type).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::models::pricing::ModelId;
use crate::models::quality::QueryTraits;
use crate::util::json::Json;
use crate::util::{fnv1a, seed_of};

/// Cache participation for `Fixed` requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CachePolicy {
    /// Exact-match prefetch lookup only (the default fast path).
    Auto,
    /// Bypass the cache entirely.
    Skip,
    /// Serve from cache or fail over to the model.
    Semantic,
}

/// The service types shipped in the paper (§3.2) plus the usage-based and
/// latency-first types from the deployments (§5.1, §5.2).
#[derive(Clone, Debug, PartialEq)]
pub enum ServiceType {
    /// Fully explicit configuration: model, cache policy, last-k context.
    Fixed {
        model: ModelId,
        cache: CachePolicy,
        context_k: usize,
    },
    /// Most expensive model, as much context as the window allows.
    Quality,
    /// Cheapest model, no context.
    Cost,
    /// Best model under a price ceiling: the most capable model whose
    /// input price is at or under this many USD per 1M input tokens.
    Budget { max_usd_per_mtok_in: f64 },
    /// Verification-based model selection (§3.3): cheap M1 answers, a
    /// verifier scores it, expensive M2 is consulted below `threshold`.
    /// Uses last-5 context per the paper.
    ModelSelector {
        threshold: f64,
        m1: Option<ModelId>,
        m2: Option<ModelId>,
        verifier: Option<ModelId>,
    },
    /// Small model decides whether the last-k context is needed (§3.4).
    SmartContext { k: usize, model: ModelId },
    /// Small model decides whether cached content answers the prompt
    /// (§3.5), grounding its reply in retrieved facts.
    SmartCache { model: ModelId },
    /// Classroom deployment (§5.2): curated model list + token quotas.
    UsageBased {
        allowed: Vec<ModelId>,
        fallback: ModelId,
    },
    /// §5.1 "latency-centric" type: fastest model answers now, a better
    /// answer is prefetched asynchronously for "Get Better Answer".
    LatencyFirst,
}

impl Default for ServiceType {
    fn default() -> Self {
        ServiceType::ModelSelector {
            threshold: 8.0,
            m1: None,
            m2: None,
            verifier: None,
        }
    }
}

impl ServiceType {
    pub fn name(&self) -> &'static str {
        match self {
            ServiceType::Fixed { .. } => "fixed",
            ServiceType::Quality => "quality",
            ServiceType::Cost => "cost",
            ServiceType::Budget { .. } => "budget",
            ServiceType::ModelSelector { .. } => "model_selector",
            ServiceType::SmartContext { .. } => "smart_context",
            ServiceType::SmartCache { .. } => "smart_cache",
            ServiceType::UsageBased { .. } => "usage_based",
            ServiceType::LatencyFirst => "latency_first",
        }
    }

    /// Parse from the REST representation: `{"name": ..., params...}`.
    pub fn from_json(j: &Json) -> Result<ServiceType> {
        let name = j.str_of("name")?;
        Ok(match name.as_str() {
            "fixed" => ServiceType::Fixed {
                model: ModelId::parse(&j.str_of("model")?)?,
                cache: match j.get("cache").and_then(|c| c.as_str()).unwrap_or("auto") {
                    "skip" => CachePolicy::Skip,
                    "semantic" => CachePolicy::Semantic,
                    _ => CachePolicy::Auto,
                },
                context_k: j.get("context_k").and_then(|v| v.as_usize()).unwrap_or(0),
            },
            "quality" => ServiceType::Quality,
            "cost" => ServiceType::Cost,
            "budget" => ServiceType::Budget {
                max_usd_per_mtok_in: j
                    .get("max_usd_per_mtok_in")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(1.0),
            },
            "model_selector" => ServiceType::ModelSelector {
                threshold: j.get("threshold").and_then(|v| v.as_f64()).unwrap_or(8.0),
                m1: j
                    .get("m1")
                    .and_then(|v| v.as_str())
                    .map(ModelId::parse)
                    .transpose()?,
                m2: j
                    .get("m2")
                    .and_then(|v| v.as_str())
                    .map(ModelId::parse)
                    .transpose()?,
                verifier: j
                    .get("verifier")
                    .and_then(|v| v.as_str())
                    .map(ModelId::parse)
                    .transpose()?,
            },
            "smart_context" => ServiceType::SmartContext {
                k: j.get("k").and_then(|v| v.as_usize()).unwrap_or(5),
                model: j
                    .get("model")
                    .and_then(|v| v.as_str())
                    .map(ModelId::parse)
                    .transpose()?
                    .unwrap_or(ModelId::Claude3Haiku),
            },
            "smart_cache" => ServiceType::SmartCache {
                model: j
                    .get("model")
                    .and_then(|v| v.as_str())
                    .map(ModelId::parse)
                    .transpose()?
                    .unwrap_or(ModelId::Phi3Mini),
            },
            "usage_based" => {
                let allowed = j
                    .get("allowed")
                    .and_then(|a| a.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|v| v.as_str())
                            .map(ModelId::parse)
                            .collect::<Result<Vec<_>>>()
                    })
                    .transpose()?
                    .unwrap_or_else(|| {
                        vec![
                            ModelId::Gpt4oMini,
                            ModelId::Claude3Haiku,
                            ModelId::Llama38b,
                            ModelId::Phi3Mini,
                        ]
                    });
                let fallback = allowed.first().copied().unwrap_or(ModelId::Gpt4oMini);
                ServiceType::UsageBased { allowed, fallback }
            }
            "latency_first" => ServiceType::LatencyFirst,
            other => bail!("unknown service_type '{other}'"),
        })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("name", Json::str(self.name()))];
        match self {
            ServiceType::Fixed {
                model,
                cache,
                context_k,
            } => {
                pairs.push(("model", Json::str(model.as_str())));
                pairs.push((
                    "cache",
                    Json::str(match cache {
                        CachePolicy::Auto => "auto",
                        CachePolicy::Skip => "skip",
                        CachePolicy::Semantic => "semantic",
                    }),
                ));
                pairs.push(("context_k", Json::num(*context_k as f64)));
            }
            ServiceType::ModelSelector {
                threshold,
                m1,
                m2,
                verifier,
            } => {
                pairs.push(("threshold", Json::Num(*threshold)));
                if let Some(m) = m1 {
                    pairs.push(("m1", Json::str(m.as_str())));
                }
                if let Some(m) = m2 {
                    pairs.push(("m2", Json::str(m.as_str())));
                }
                if let Some(m) = verifier {
                    pairs.push(("verifier", Json::str(m.as_str())));
                }
            }
            ServiceType::SmartContext { k, model } => {
                pairs.push(("k", Json::num(*k as f64)));
                pairs.push(("model", Json::str(model.as_str())));
            }
            ServiceType::SmartCache { model } => {
                pairs.push(("model", Json::str(model.as_str())));
            }
            ServiceType::Budget { max_usd_per_mtok_in } => {
                pairs.push(("max_usd_per_mtok_in", Json::Num(*max_usd_per_mtok_in)));
            }
            ServiceType::UsageBased { allowed, fallback } => {
                pairs.push((
                    "allowed",
                    Json::Arr(allowed.iter().map(|m| Json::str(m.as_str())).collect()),
                ));
                pairs.push(("fallback", Json::str(fallback.as_str())));
            }
            _ => {}
        }
        Json::obj(pairs)
    }
}

/// An application request (`proxy.request` in Table 2).
#[derive(Clone, Debug)]
pub struct Request {
    pub user: String,
    pub conversation: String,
    pub prompt: String,
    pub service_type: ServiceType,
    /// Whether this interaction should be appended to the conversation
    /// history (§3.4: some prompts read context without updating it, e.g.
    /// TWIPS' mood detection).
    pub update_context: bool,
    /// Extra key-value parameters (Table 2's `(key, value)` pairs).
    pub params: BTreeMap<String, String>,
    /// Latent traits injected by the workload generator; `None` derives
    /// defaults from the prompt hash (see [`Request::effective_traits`]).
    pub traits: Option<QueryTraits>,
}

impl Request {
    pub fn new(user: &str, conversation: &str, prompt: &str) -> Request {
        Request {
            user: user.to_string(),
            conversation: conversation.to_string(),
            prompt: prompt.to_string(),
            service_type: ServiceType::default(),
            update_context: true,
            params: BTreeMap::new(),
            traits: None,
        }
    }

    pub fn service_type(mut self, st: ServiceType) -> Request {
        self.service_type = st;
        self
    }

    pub fn with_traits(mut self, traits: QueryTraits) -> Request {
        self.traits = Some(traits);
        self
    }

    pub fn no_context_update(mut self) -> Request {
        self.update_context = false;
        self
    }

    /// Traits used by the quality simulation: explicit if provided by the
    /// workload, otherwise derived deterministically from the prompt.
    pub fn effective_traits(&self) -> QueryTraits {
        if let Some(t) = &self.traits {
            return t.clone();
        }
        let h = fnv1a(self.prompt.as_bytes());
        let mut rng = crate::util::rng::Rng::new(h);
        QueryTraits {
            id: format!("auto-{h:016x}"),
            difficulty: rng.range_f64(0.2, 0.75),
            factual: rng.chance(0.3),
            requires_context: looks_context_dependent(&self.prompt),
        }
    }

    /// Stable id for queue grouping / regeneration bookkeeping.
    pub fn stable_id(&self) -> u64 {
        seed_of(&[&self.user, &self.conversation, &self.prompt, self.service_type.name()])
    }

    pub fn from_json(j: &Json) -> Result<Request> {
        let mut req = Request::new(
            &j.str_of("user")?,
            &j.get("conversation")
                .and_then(|v| v.as_str())
                .unwrap_or("default")
                .to_string(),
            &j.str_of("prompt")?,
        );
        if let Some(st) = j.get("service_type") {
            req.service_type = ServiceType::from_json(st)?;
        }
        if let Some(u) = j.get("update_context").and_then(|v| v.as_bool()) {
            req.update_context = u;
        }
        // Params and traits roundtrip so a journaled exchange regenerates
        // identically after a restart (params carry the explicit model
        // pin the route stage honors; traits drive the quality sim).
        if let Some(Json::Obj(map)) = j.get("params") {
            for (k, v) in map {
                if let Some(s) = v.as_str() {
                    req.params.insert(k.clone(), s.to_string());
                }
            }
        }
        if let Some(t) = j.get("traits") {
            // Lenient like the rest of this parser: a fully-formed traits
            // object (what Request::to_json emits — the WAL/snapshot
            // replay path) is adopted; anything partial or mistyped is
            // ignored rather than failing an external REST request that
            // was previously accepted.
            if let (Some(id), Some(difficulty), Some(factual), Some(requires_context)) = (
                t.get("id").and_then(|v| v.as_str()),
                t.get("difficulty").and_then(|v| v.as_f64()),
                t.get("factual").and_then(|v| v.as_bool()),
                t.get("requires_context").and_then(|v| v.as_bool()),
            ) {
                req.traits = Some(QueryTraits {
                    id: id.to_string(),
                    difficulty,
                    factual,
                    requires_context,
                });
            }
        }
        Ok(req)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("user", Json::str(self.user.clone())),
            ("conversation", Json::str(self.conversation.clone())),
            ("prompt", Json::str(self.prompt.clone())),
            ("service_type", self.service_type.to_json()),
            ("update_context", Json::Bool(self.update_context)),
        ];
        // Emitted only when present, so minimal requests serialize as
        // before.
        if !self.params.is_empty() {
            pairs.push((
                "params",
                Json::Obj(
                    self.params
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                        .collect(),
                ),
            ));
        }
        if let Some(t) = &self.traits {
            pairs.push((
                "traits",
                Json::obj(vec![
                    ("id", Json::str(t.id.clone())),
                    ("difficulty", Json::Num(t.difficulty)),
                    ("factual", Json::Bool(t.factual)),
                    ("requires_context", Json::Bool(t.requires_context)),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

/// Heuristic used for out-of-band (non-workload) prompts: short anaphoric
/// follow-ups likely need conversation context.
pub fn looks_context_dependent(prompt: &str) -> bool {
    let lower = prompt.to_lowercase();
    let openers = [
        "what about", "and ", "why", "how about", "tell me more", "more about",
        "that", "it ", "them", "explain more", "go on", "also",
    ];
    let wc = crate::runtime::tokenizer::words(prompt).len();
    wc <= 4 || openers.iter().any(|o| lower.starts_with(o))
}

/// How the cache participated in a response.
#[derive(Clone, Debug, PartialEq)]
pub enum CacheOutcome {
    /// Not consulted.
    Skipped,
    /// Consulted, nothing usable.
    Miss,
    /// Exact prefetch hit (WhatsApp follow-up buttons, §5.1).
    ExactHit,
    /// Semantic hit used to ground the response (similarity score).
    SemanticHit { score: f64 },
}

impl CacheOutcome {
    fn to_json(&self) -> Json {
        match self {
            CacheOutcome::Skipped => Json::str("skipped"),
            CacheOutcome::Miss => Json::str("miss"),
            CacheOutcome::ExactHit => Json::str("exact_hit"),
            CacheOutcome::SemanticHit { score } => Json::obj(vec![
                ("kind", Json::str("semantic_hit")),
                ("score", Json::Num(*score)),
            ]),
        }
    }
}

/// Transparency metadata (§3.2): the low-level choices made on behalf of
/// the application.
#[derive(Clone, Debug)]
pub struct Metadata {
    pub request_id: u64,
    pub service_type: String,
    /// (model, role) pairs, e.g. `("gpt-3.5-turbo", "m1")`,
    /// `("claude-3-opus", "verifier")`, `("gpt-4", "m2")`.
    pub models_used: Vec<(String, String)>,
    pub cache: CacheOutcome,
    /// Number of history messages included as context.
    pub context_messages: usize,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub cost_usd: f64,
    pub latency_ms: f64,
    pub verifier_score: Option<f64>,
    /// Milliseconds spent in delegated context-LLM calls (Fig 6c).
    pub context_llm_ms: f64,
    /// Milliseconds of LLM execution in total (excludes proxy overhead).
    pub llm_ms: f64,
    /// Simulation-only latent quality of the served response (surfaced so
    /// benches can score without re-deriving; not part of the paper API).
    pub latent_quality: f64,
    pub grounded: bool,
    pub regen_count: u32,
}

impl Metadata {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("request_id", Json::str(format!("{:016x}", self.request_id))),
            ("service_type", Json::str(self.service_type.clone())),
            (
                "models_used",
                Json::Arr(
                    self.models_used
                        .iter()
                        .map(|(m, r)| {
                            Json::obj(vec![
                                ("model", Json::str(m.clone())),
                                ("role", Json::str(r.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("cache", self.cache.to_json()),
            ("context_messages", Json::num(self.context_messages as f64)),
            ("input_tokens", Json::num(self.input_tokens as f64)),
            ("output_tokens", Json::num(self.output_tokens as f64)),
            ("cost_usd", Json::Num(self.cost_usd)),
            ("latency_ms", Json::Num(self.latency_ms)),
            (
                "verifier_score",
                self.verifier_score.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("grounded", Json::Bool(self.grounded)),
            ("regen_count", Json::num(self.regen_count as f64)),
        ])
    }
}

/// `proxy.result`: the response plus transparency metadata.
#[derive(Clone, Debug)]
pub struct Response {
    pub text: String,
    pub metadata: Metadata,
}

impl Response {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("text", Json::str(self.text.clone())),
            ("metadata", self.metadata.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_type_json_roundtrip() {
        let cases = vec![
            ServiceType::Quality,
            ServiceType::Cost,
            ServiceType::Budget {
                max_usd_per_mtok_in: 2.5,
            },
            ServiceType::Fixed {
                model: ModelId::Gpt4oMini,
                cache: CachePolicy::Skip,
                context_k: 3,
            },
            ServiceType::ModelSelector {
                threshold: 7.5,
                m1: Some(ModelId::Gpt35Turbo),
                m2: Some(ModelId::Gpt4),
                verifier: Some(ModelId::Claude3Opus),
            },
            ServiceType::SmartContext {
                k: 5,
                model: ModelId::Claude3Haiku,
            },
            ServiceType::SmartCache {
                model: ModelId::Phi3Mini,
            },
            ServiceType::LatencyFirst,
        ];
        for st in cases {
            let j = st.to_json();
            let back = ServiceType::from_json(&j).unwrap();
            assert_eq!(st, back, "{j:?}", j = j.to_string());
        }
    }

    #[test]
    fn unknown_service_type_rejected() {
        let j = Json::obj(vec![("name", Json::str("warp_speed"))]);
        assert!(ServiceType::from_json(&j).is_err());
    }

    #[test]
    fn request_json_roundtrip() {
        let j = Json::parse(
            r#"{"user":"u1","conversation":"c9","prompt":"hi there",
                "service_type":{"name":"cost"},"update_context":false}"#,
        )
        .unwrap();
        let r = Request::from_json(&j).unwrap();
        assert_eq!(r.user, "u1");
        assert_eq!(r.service_type, ServiceType::Cost);
        assert!(!r.update_context);
    }

    #[test]
    fn request_params_and_traits_roundtrip() {
        let mut req = Request::new("u1", "c1", "pin me to a model").with_traits(QueryTraits {
            id: "wl-7".into(),
            difficulty: 0.6,
            factual: true,
            requires_context: false,
        });
        req.params.insert("model".into(), "gpt-4o-mini".into());
        let back = Request::from_json(&req.to_json()).unwrap();
        assert_eq!(back.params.get("model").map(|s| s.as_str()), Some("gpt-4o-mini"));
        let t = back.traits.expect("traits survive the roundtrip");
        assert_eq!(t.id, "wl-7");
        assert_eq!(t.difficulty, 0.6);
        assert!(t.factual);
        // A minimal request serializes without the optional keys.
        let plain = Request::new("u", "c", "p").to_json().to_string();
        assert!(!plain.contains("params"));
        assert!(!plain.contains("traits"));
        // Partial or mistyped traits from external REST callers are
        // ignored, never a parse failure.
        for body in [
            r#"{"user":"u","prompt":"p","traits":{}}"#,
            r#"{"user":"u","prompt":"p","traits":null}"#,
            r#"{"user":"u","prompt":"p","traits":{"id":"x"}}"#,
        ] {
            let r = Request::from_json(&Json::parse(body).unwrap()).unwrap();
            assert!(r.traits.is_none(), "{body}");
        }
    }

    #[test]
    fn derived_traits_deterministic() {
        let r = Request::new("u", "c", "what is the capital of sudan");
        let a = r.effective_traits();
        let b = r.effective_traits();
        assert_eq!(a.difficulty, b.difficulty);
        assert_eq!(a.id, b.id);
    }

    #[test]
    fn context_dependence_heuristic() {
        assert!(looks_context_dependent("what about in sudan?"));
        assert!(looks_context_dependent("tell me more"));
        assert!(!looks_context_dependent(
            "give me a detailed history of the roman empire please"
        ));
    }

    #[test]
    fn metadata_serializes() {
        let m = Metadata {
            request_id: 42,
            service_type: "cost".into(),
            models_used: vec![("gpt-4o-mini".into(), "m1".into())],
            cache: CacheOutcome::SemanticHit { score: 0.93 },
            context_messages: 2,
            input_tokens: 10,
            output_tokens: 20,
            cost_usd: 0.0001,
            latency_ms: 12.5,
            verifier_score: Some(7.0),
            context_llm_ms: 0.0,
            llm_ms: 10.0,
            latent_quality: 8.1,
            grounded: true,
            regen_count: 0,
        };
        let j = m.to_json().to_string();
        assert!(j.contains("semantic_hit"));
        assert!(j.contains("gpt-4o-mini"));
    }
}
