//! Per-connection machinery for the server: an **incremental** HTTP/1.1
//! request parser and the nonblocking connection state machine the
//! evented loop drives.
//!
//! [`RequestParser`] is pure (bytes in, requests out) and shared by both
//! server paths: the evented loop feeds it whatever `read(2)` returned
//! and asks for complete requests; the blocking fallback wraps it in a
//! deadline-armed read loop ([`crate::server::read_request_deadline`]).
//! Because the parser drains exactly one request's bytes per yield,
//! back-to-back pipelined requests on one keep-alive connection fall out
//! naturally: leftover bytes stay buffered until the current response is
//! written and the loop asks for the next request.
//!
//! Framing limits are enforced *before* buffering the offending bytes: a
//! head that exceeds [`MAX_HEAD_BYTES`] without terminating fails with
//! [`ParseError::HeadTooLarge`] (HTTP 400), a declared body length over
//! [`MAX_BODY_BYTES`] fails with [`ParseError::BodyTooLarge`] (HTTP 413)
//! without waiting for the body to arrive, and a malformed or non-numeric
//! `Content-Length` is rejected rather than silently read as zero (which
//! would desync the keep-alive framing and misparse body bytes as the
//! next request line).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Heads larger than this are rejected (connection closed after a 400).
pub const MAX_HEAD_BYTES: usize = 64 * 1024;
/// Declared body lengths larger than this are rejected with a 413.
pub const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;
/// One `fill` call reads at most this many bytes, so a single hot
/// connection cannot monopolize the event loop; level-triggered epoll
/// re-reports the remainder on the next tick.
const FILL_QUANTUM: usize = 256 * 1024;

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
    /// Whether the connection may serve another request after this one
    /// (HTTP/1.1 default true, HTTP/1.0 default false, `Connection`
    /// header overrides either way).
    pub keep_alive: bool,
}

/// Why a connection's byte stream could not be framed into a request.
#[derive(Clone, Debug, PartialEq)]
pub enum ParseError {
    /// No header terminator within [`MAX_HEAD_BYTES`].
    HeadTooLarge,
    /// `Content-Length` declared more than [`MAX_BODY_BYTES`].
    BodyTooLarge(usize),
    /// Anything else: bad request line, non-utf8 head or body, unparsable
    /// `Content-Length`.
    Malformed(String),
}

impl ParseError {
    /// The response status the connection gets before closing.
    pub fn http_status(&self) -> u16 {
        match self {
            ParseError::BodyTooLarge(_) => 413,
            _ => 400,
        }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::HeadTooLarge => write!(f, "headers too large (> {MAX_HEAD_BYTES} bytes)"),
            ParseError::BodyTooLarge(n) => {
                write!(f, "declared body of {n} bytes exceeds {MAX_BODY_BYTES}")
            }
            ParseError::Malformed(m) => write!(f, "malformed request: {m}"),
        }
    }
}

impl std::error::Error for ParseError {}

/// A fully parsed head still waiting for its body bytes.
#[derive(Debug)]
struct PendingHead {
    method: String,
    path: String,
    content_length: usize,
    keep_alive: bool,
}

/// Incremental request framer. Feed bytes as they arrive; [`next`]
/// yields at most one complete request per call and drains exactly that
/// request's bytes, leaving pipelined successors buffered.
///
/// [`next`]: RequestParser::next
#[derive(Debug, Default)]
pub struct RequestParser {
    buf: Vec<u8>,
    /// Bytes of `buf` already scanned for a head terminator, so a
    /// byte-dribbling client costs O(n), not O(n²).
    scanned: usize,
    head: Option<PendingHead>,
}

fn find_bytes(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    if haystack.len() < needle.len() {
        return None;
    }
    haystack.windows(needle.len()).position(|w| w == needle)
}

impl RequestParser {
    pub fn new() -> RequestParser {
        RequestParser::default()
    }

    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a yielded request.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing is mid-parse: no buffered bytes, no pending
    /// head. A connection closing in this state saw a clean boundary.
    pub fn is_idle(&self) -> bool {
        self.buf.is_empty() && self.head.is_none()
    }

    /// Locate the head terminator (CRLFCRLF per spec; bare LFLF tolerated
    /// like the original line-based parser), whichever occurs first.
    fn find_head_end(&mut self) -> Option<(usize, usize)> {
        // Re-scan a 3-byte overlap in case the terminator straddled feeds.
        let from = self.scanned.saturating_sub(3);
        let window = &self.buf[from..];
        let crlf = find_bytes(window, b"\r\n\r\n").map(|p| (from + p, 4));
        let lf = find_bytes(window, b"\n\n").map(|p| (from + p, 2));
        self.scanned = self.buf.len();
        match (crlf, lf) {
            (Some(a), Some(b)) => Some(if a.0 <= b.0 { a } else { b }),
            (a, b) => a.or(b),
        }
    }

    fn parse_head(&mut self, head_end: usize, sep_len: usize) -> Result<PendingHead, ParseError> {
        let head = std::str::from_utf8(&self.buf[..head_end])
            .map_err(|_| ParseError::Malformed("non-utf8 headers".into()))?;
        let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
        let request_line = lines
            .next()
            .ok_or_else(|| ParseError::Malformed("missing request line".into()))?;
        let mut parts = request_line.split_whitespace();
        let method = parts
            .next()
            .ok_or_else(|| ParseError::Malformed("missing method".into()))?
            .to_string();
        let path = parts
            .next()
            .ok_or_else(|| ParseError::Malformed("missing path".into()))?
            .to_string();
        // HTTP/1.1 (and anything unversioned) defaults to keep-alive;
        // HTTP/1.0 defaults to close; Connection overrides both.
        let mut keep_alive = parts.next() != Some("HTTP/1.0");
        let mut content_length = 0usize;
        for header in lines {
            if let Some((k, v)) = header.split_once(':') {
                let v = v.trim();
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v.parse().map_err(|_| {
                        ParseError::Malformed(format!("unparsable content-length '{v}'"))
                    })?;
                } else if k.eq_ignore_ascii_case("connection") {
                    if v.eq_ignore_ascii_case("close") {
                        keep_alive = false;
                    } else if v.eq_ignore_ascii_case("keep-alive") {
                        keep_alive = true;
                    }
                }
            }
        }
        if content_length > MAX_BODY_BYTES {
            return Err(ParseError::BodyTooLarge(content_length));
        }
        self.buf.drain(..head_end + sep_len);
        self.scanned = 0;
        Ok(PendingHead {
            method,
            path,
            content_length,
            keep_alive,
        })
    }

    /// Yield the next complete request, `Ok(None)` when more bytes are
    /// needed. After an `Err` the stream is unframeable — respond with
    /// [`ParseError::http_status`] and close.
    pub fn next(&mut self) -> Result<Option<HttpRequest>, ParseError> {
        if self.head.is_none() {
            let Some((head_end, sep_len)) = self.find_head_end() else {
                if self.buf.len() > MAX_HEAD_BYTES {
                    return Err(ParseError::HeadTooLarge);
                }
                return Ok(None);
            };
            if head_end > MAX_HEAD_BYTES {
                return Err(ParseError::HeadTooLarge);
            }
            self.head = Some(self.parse_head(head_end, sep_len)?);
        }
        let need = self.head.as_ref().map(|h| h.content_length).unwrap_or(0);
        if self.buf.len() < need {
            return Ok(None);
        }
        let h = self.head.take().expect("checked above");
        let body: Vec<u8> = self.buf.drain(..h.content_length).collect();
        self.scanned = 0;
        let body = String::from_utf8(body)
            .map_err(|_| ParseError::Malformed("non-utf8 body".into()))?;
        Ok(Some(HttpRequest {
            method: h.method,
            path: h.path,
            body,
            keep_alive: h.keep_alive,
        }))
    }
}

/// Where a connection sits in the evented loop's lifecycle. Interest
/// masks follow the state: `Reading` watches readable, `Dispatched`
/// watches nothing (kernel socket buffer absorbs pipelined bytes — TCP
/// backpressure), `Writing` watches writable.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ConnState {
    /// Accumulating request bytes.
    Reading,
    /// A parsed request is queued or in a worker; reads are paused.
    Dispatched,
    /// A response is being flushed.
    Writing,
}

/// What a `fill` pass observed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FillOutcome {
    /// Read some bytes (peer may also have half-closed afterward).
    Progress,
    /// Nothing to read right now (`EWOULDBLOCK` immediately).
    Idle,
    /// Clean EOF with no new bytes.
    Eof,
    /// Hard I/O error — close the connection.
    Error,
}

/// What a `flush_write` pass achieved.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WriteOutcome {
    /// Outbox fully flushed.
    Done,
    /// Socket buffer full — wait for writability.
    Blocked,
    /// Hard I/O error — close the connection.
    Error,
}

/// One nonblocking connection: socket + parser + response outbox.
#[derive(Debug)]
pub struct Conn {
    pub stream: TcpStream,
    pub parser: RequestParser,
    pub state: ConnState,
    /// Set when EOF was observed; the connection closes once the
    /// in-flight response (if any) is flushed.
    pub peer_closed: bool,
    /// Whether the connection survives the current response.
    pub keep_alive_after_write: bool,
    /// Idle-sweep clock: bumped on every read/write progress.
    pub last_activity: Instant,
    /// Anti-slowloris clock: set at the first byte of a request, cleared
    /// when one completes. Unlike `last_activity`, dribbled bytes do
    /// **not** reset it, so a request must fully arrive within the
    /// server's request deadline.
    pub reading_since: Option<Instant>,
    /// Requests fully served on this connection (keep-alive reuse count
    /// is `served - 1` at close).
    pub served: u64,
    /// Accepted on the admin listener: routed through `route_admin`
    /// inline (never dispatched) and exempt from `max_conns`.
    pub admin: bool,
    outbox: Vec<u8>,
    written: usize,
}

impl Conn {
    /// Wrap an accepted stream (caller has already set nonblocking).
    pub fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            parser: RequestParser::new(),
            state: ConnState::Reading,
            peer_closed: false,
            keep_alive_after_write: false,
            last_activity: Instant::now(),
            reading_since: None,
            served: 0,
            admin: false,
            outbox: Vec::new(),
            written: 0,
        }
    }

    /// Read until `EWOULDBLOCK`, EOF, error, or the fairness quantum,
    /// feeding the parser.
    pub fn fill(&mut self) -> FillOutcome {
        let mut tmp = [0u8; 16 * 1024];
        let mut total = 0usize;
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    self.peer_closed = true;
                    return if total > 0 {
                        FillOutcome::Progress
                    } else {
                        FillOutcome::Eof
                    };
                }
                Ok(n) => {
                    self.parser.feed(&tmp[..n]);
                    self.last_activity = Instant::now();
                    if self.reading_since.is_none() {
                        self.reading_since = Some(self.last_activity);
                    }
                    total += n;
                    if total >= FILL_QUANTUM {
                        return FillOutcome::Progress;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return if total > 0 {
                        FillOutcome::Progress
                    } else {
                        FillOutcome::Idle
                    };
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return FillOutcome::Error,
            }
        }
    }

    /// Arm a response for flushing and enter `Writing`.
    pub fn start_write(&mut self, bytes: Vec<u8>, keep_alive_after: bool) {
        debug_assert!(self.outbox.is_empty(), "one response in flight per conn");
        self.outbox = bytes;
        self.written = 0;
        self.keep_alive_after_write = keep_alive_after;
        self.state = ConnState::Writing;
    }

    /// Write until done or `EWOULDBLOCK`.
    pub fn flush_write(&mut self) -> WriteOutcome {
        while self.written < self.outbox.len() {
            match self.stream.write(&self.outbox[self.written..]) {
                Ok(0) => return WriteOutcome::Error,
                Ok(n) => {
                    self.written += n;
                    self.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return WriteOutcome::Blocked;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return WriteOutcome::Error,
            }
        }
        self.outbox = Vec::new();
        self.written = 0;
        WriteOutcome::Done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_all(parser: &mut RequestParser) -> Vec<HttpRequest> {
        let mut out = Vec::new();
        while let Ok(Some(r)) = parser.next() {
            out.push(r);
        }
        out
    }

    #[test]
    fn single_request_byte_at_a_time() {
        let raw = b"POST /v1/request HTTP/1.1\r\nContent-Length: 13\r\n\r\n{\"user\":\"u1\"}";
        let mut p = RequestParser::new();
        for (i, b) in raw.iter().enumerate() {
            assert!(p.next().unwrap().is_none(), "yielded early at byte {i}");
            p.feed(&[*b]);
        }
        let req = p.next().unwrap().expect("complete request");
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/request");
        assert_eq!(req.body, "{\"user\":\"u1\"}");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert!(p.is_idle());
    }

    #[test]
    fn pipelined_requests_drain_one_at_a_time() {
        let mut p = RequestParser::new();
        p.feed(
            b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi\
              GET /b HTTP/1.1\r\n\r\n",
        );
        let reqs = parse_all(&mut p);
        assert_eq!(reqs.len(), 2);
        assert_eq!((reqs[0].method.as_str(), reqs[0].body.as_str()), ("POST", "hi"));
        assert_eq!((reqs[1].method.as_str(), reqs[1].path.as_str()), ("GET", "/b"));
        assert!(p.is_idle());
    }

    #[test]
    fn keep_alive_negotiation() {
        let cases: &[(&[u8], bool)] = &[
            (b"GET / HTTP/1.1\r\n\r\n", true),
            (b"GET / HTTP/1.0\r\n\r\n", false),
            (b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n", false),
            (b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n", true),
        ];
        for (raw, want) in cases {
            let mut p = RequestParser::new();
            p.feed(raw);
            let req = p.next().unwrap().unwrap();
            assert_eq!(req.keep_alive, *want, "{}", String::from_utf8_lossy(raw));
        }
    }

    #[test]
    fn bare_lf_separator_tolerated() {
        let mut p = RequestParser::new();
        p.feed(b"GET /health HTTP/1.1\n\n");
        assert_eq!(p.next().unwrap().unwrap().path, "/health");
    }

    #[test]
    fn oversized_head_rejected_without_terminator() {
        let mut p = RequestParser::new();
        p.feed(b"GET / HTTP/1.1\r\nX-Pad: ");
        p.feed(&vec![b'a'; MAX_HEAD_BYTES + 10]);
        assert_eq!(p.next(), Err(ParseError::HeadTooLarge));
        assert_eq!(ParseError::HeadTooLarge.http_status(), 400);
    }

    #[test]
    fn oversized_declared_body_rejected_before_body_arrives() {
        let mut p = RequestParser::new();
        let n = MAX_BODY_BYTES + 1;
        p.feed(format!("POST / HTTP/1.1\r\nContent-Length: {n}\r\n\r\n").as_bytes());
        let err = p.next().unwrap_err();
        assert_eq!(err, ParseError::BodyTooLarge(n));
        assert_eq!(err.http_status(), 413);
    }

    #[test]
    fn unparsable_content_length_rejected() {
        let mut p = RequestParser::new();
        p.feed(b"POST / HTTP/1.1\r\nContent-Length: banana\r\n\r\n");
        assert!(matches!(p.next(), Err(ParseError::Malformed(_))));
    }

    #[test]
    fn terminator_straddles_feed_boundaries() {
        let mut p = RequestParser::new();
        p.feed(b"GET /x HTTP/1.1\r\n\r");
        assert!(p.next().unwrap().is_none());
        p.feed(b"\n");
        assert_eq!(p.next().unwrap().unwrap().path, "/x");
    }

    #[test]
    fn nonblocking_conn_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let mut conn = Conn::new(server);

        // Nothing sent yet: idle, not EOF.
        assert_eq!(conn.fill(), FillOutcome::Idle);

        client.write_all(b"GET /health HTTP/1.1\r\n\r\n").unwrap();
        // Wait for delivery (loopback is fast but not synchronous).
        let deadline = Instant::now() + std::time::Duration::from_secs(2);
        loop {
            match conn.fill() {
                FillOutcome::Progress => break,
                FillOutcome::Idle if Instant::now() < deadline => {
                    std::thread::sleep(std::time::Duration::from_millis(1))
                }
                other => panic!("unexpected fill outcome {other:?}"),
            }
        }
        let req = conn.parser.next().unwrap().unwrap();
        assert_eq!(req.path, "/health");

        conn.start_write(b"HTTP/1.1 200 OK\r\nContent-Length: 0\r\n\r\n".to_vec(), false);
        assert_eq!(conn.flush_write(), WriteOutcome::Done);
        drop(conn);
        let mut got = String::new();
        client.read_to_string(&mut got).unwrap();
        assert!(got.starts_with("HTTP/1.1 200 OK"));
    }

    #[test]
    fn fill_reports_eof_on_peer_close() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::net::TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let mut conn = Conn::new(server);
        drop(client);
        let deadline = Instant::now() + std::time::Duration::from_secs(2);
        loop {
            match conn.fill() {
                FillOutcome::Eof => break,
                FillOutcome::Idle if Instant::now() < deadline => {
                    std::thread::sleep(std::time::Duration::from_millis(1))
                }
                other => panic!("unexpected fill outcome {other:?}"),
            }
        }
        assert!(conn.peer_closed);
    }
}
