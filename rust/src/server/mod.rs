//! REST server — the interface the classroom deployment used (§5.2),
//! grown into an evented front door shaped for the ROADMAP's
//! millions-of-users target.
//!
//! Two interchangeable transport paths serve the same routes:
//!
//! * **Evented** (`evloop.rs`, Linux default): a nonblocking epoll
//!   readiness loop (raw-syscall shim, [`crate::util::epoll`]) drives
//!   per-connection state machines with HTTP/1.1 keep-alive, incremental
//!   parsing ([`RequestParser`]), bounded per-user backpressure, and
//!   load-shedding admission control that answers 429 *before* queues
//!   melt. Worker threads are a dispatch pool fed fully-parsed requests
//!   through the per-user FIFO substrate; responses travel back to the
//!   loop over a wakeup pipe.
//! * **Threaded** (`threaded.rs`, portable fallback): the original
//!   blocking-socket worker pool — the acceptor enqueues raw
//!   connections, workers parse and re-enqueue under the per-user group,
//!   one request per connection (`Connection: close`).
//!
//! Both paths preserve the paper's per-user **serialization** guarantee
//! end to end (the SQS exclusive-delivery semantics, via
//! [`crate::queuing::FifoQueue`]): at most one in-flight request per
//! user, queue order thereafter. A user's requests enter their queue in
//! parse-completion order, which across separate connections can differ
//! from accept order — same as concurrent clients racing the paper's SQS
//! enqueue.
//!
//! **Admission control vs quota 429s.** The server sheds with HTTP 429
//! in three places *before* any bridge work happens: at accept when
//! [`ServerConfig::max_conns`] live connections exist, at dispatch when
//! in-flight requests reach [`ServerConfig::shed_watermark`], and at
//! enqueue when one user's queue is at
//! [`ServerConfig::per_user_queue_cap`]. These shed bodies carry
//! `"reason":"admission"` — distinct from the per-user *quota* 429
//! ([`crate::error::BridgeError::QuotaExceeded`]) raised inside the
//! pipeline, whose body names the user. Shed counts surface in
//! `/v1/metrics` (`server_shed_*` counters).
//!
//! Routes:
//! * `POST /v1/request`     — body: [`crate::api::Request`] JSON.
//! * `POST /v1/regenerate`  — body: `{"request_id": "<hex>", "service_type": {...}?}`.
//! * `GET  /v1/metrics`     — telemetry snapshot.
//! * `GET  /health`         — liveness (always 200 while the process serves).
//! * `GET  /ready`          — readiness: restore complete (implied by a
//!   constructed [`Bridge`] — `open_with` replays WAL + snapshot before
//!   returning), not draining, and in-flight load below the shed
//!   watermark; 503 otherwise.
//!
//! [`Server::stop`] is graceful on both paths: stop accepting, drain
//! in-flight connections (bounded by [`ServerConfig::drain_deadline`] on
//! the evented path), then fsync the WAL so a clean exit loses nothing.

mod conn;
#[cfg(target_os = "linux")]
mod evloop;
mod threaded;

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

pub use conn::{
    Conn, ConnState, FillOutcome, HttpRequest, ParseError, RequestParser, WriteOutcome,
    MAX_BODY_BYTES, MAX_HEAD_BYTES,
};

use crate::api::{Request, ServiceType};
use crate::coordinator::Bridge;
use crate::error::BridgeError;
use crate::util::json::Json;

/// Which transport path serves connections.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerBackend {
    /// Evented on Linux, threaded elsewhere.
    Auto,
    /// Force the epoll readiness loop (errors off-Linux).
    Evented,
    /// Force the portable blocking worker pool.
    Threaded,
}

/// Server tuning knobs. `Default` matches the CLI defaults.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Dispatch-pool threads (both paths).
    pub workers: usize,
    /// Live-connection ceiling (evented path); excess accepts are
    /// answered 429 and closed.
    pub max_conns: usize,
    /// In-flight dispatched-request watermark: at or above it, newly
    /// parsed requests shed with an admission 429 instead of queueing.
    pub shed_watermark: usize,
    /// Per-user queue-depth bound (including the in-flight request).
    pub per_user_queue_cap: usize,
    /// Idle keep-alive connections are closed after this long.
    pub keepalive_timeout: Duration,
    /// A single request's bytes must fully arrive within this budget
    /// (anti-slowloris; mirrors the threaded path's read deadline).
    pub request_deadline: Duration,
    /// Graceful-stop bound for draining in-flight work (evented path).
    pub drain_deadline: Duration,
    /// Transport selection.
    pub backend: ServerBackend,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            workers: 4,
            max_conns: 4096,
            shed_watermark: 512,
            per_user_queue_cap: 32,
            keepalive_timeout: Duration::from_secs(30),
            request_deadline: Duration::from_secs(10),
            drain_deadline: Duration::from_secs(5),
            backend: ServerBackend::Auto,
        }
    }
}

/// Load/lifecycle state shared between the transport path and the
/// `/ready` endpoint: the in-flight dispatched-request count (the
/// admission watermark input) and the draining latch.
pub struct ServerState {
    draining: AtomicBool,
    inflight: AtomicUsize,
    shed_watermark: usize,
}

impl ServerState {
    pub fn new(shed_watermark: usize) -> ServerState {
        ServerState {
            draining: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            shed_watermark: shed_watermark.max(1),
        }
    }

    /// Requests dispatched to the worker pool and not yet responded.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    /// Below the shed watermark — new dispatches are admitted.
    pub fn admits(&self) -> bool {
        self.inflight() < self.shed_watermark
    }

    /// Ready to take traffic: not draining and below the watermark.
    pub fn ready(&self) -> bool {
        !self.is_draining() && self.admits()
    }

    pub(crate) fn set_draining(&self) {
        self.draining.store(true, Ordering::Relaxed);
    }

    pub(crate) fn begin_dispatch(&self) {
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn end_dispatch(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Read one HTTP/1.1 request from the stream (no deadline; see
/// [`read_request_deadline`]).
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    read_request_deadline(stream, None)
}

/// Re-arm the socket timeout with the remaining budget before a read.
fn arm_deadline(stream: &TcpStream, deadline: Option<std::time::Instant>) -> Result<()> {
    if let Some(d) = deadline {
        match d.checked_duration_since(std::time::Instant::now()) {
            Some(left) if !left.is_zero() => stream.set_read_timeout(Some(left))?,
            _ => bail!("request read deadline exceeded"),
        }
    }
    Ok(())
}

/// Read one HTTP/1.1 request on a **blocking** socket — the threaded
/// path's entry into the same incremental [`RequestParser`] the evented
/// loop uses. `deadline` bounds the TOTAL wall time across every read
/// (the socket timeout is re-armed with the remaining budget before each
/// one), so a byte-dribbling client cannot hold a worker beyond it.
pub fn read_request_deadline(
    stream: &mut TcpStream,
    deadline: Option<std::time::Instant>,
) -> Result<HttpRequest> {
    let mut parser = RequestParser::new();
    let mut tmp = [0u8; 4096];
    loop {
        if let Some(req) = parser.next()? {
            return Ok(req);
        }
        arm_deadline(stream, deadline)?;
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        parser.feed(&tmp[..n]);
    }
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

/// Serialize a response. `keep_alive` controls the `Connection` header —
/// the evented path holds connections open between requests, the
/// threaded path always closes.
pub fn render_response(status: u16, body: &str, keep_alive: bool) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {}\r\n\r\n{body}",
        reason_phrase(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    )
    .into_bytes()
}

/// Write a `Connection: close` response on a blocking socket.
pub fn write_response(stream: &mut TcpStream, status: u16, body: &str) -> Result<()> {
    use std::io::Write;
    stream.write_all(&render_response(status, body, false))?;
    Ok(())
}

/// The admission-control shed body; `"reason":"admission"` distinguishes
/// it from the pipeline's per-user quota 429.
pub(crate) fn admission_shed_body() -> String {
    r#"{"error":"server overloaded; request shed by admission control","reason":"admission"}"#
        .to_string()
}

fn err_body(e: &BridgeError) -> String {
    Json::obj(vec![("error", Json::str(e.to_string()))]).to_string()
}

fn respond(result: Result<String, BridgeError>) -> (u16, String) {
    match result {
        Ok(body) => (200, body),
        Err(e) => (e.http_status(), err_body(&e)),
    }
}

/// The `/ready` probe: 200 only when restore is complete (always true
/// once a [`Bridge`] exists), the server is not draining, and in-flight
/// load sits below the shed watermark.
fn ready_response(state: &ServerState) -> (u16, String) {
    if state.is_draining() {
        return (503, r#"{"status":"draining"}"#.to_string());
    }
    let inflight = state.inflight();
    if !state.admits() {
        return (
            503,
            Json::obj(vec![
                ("status", Json::str("overloaded")),
                ("inflight", Json::num(inflight as f64)),
            ])
            .to_string(),
        );
    }
    (
        200,
        Json::obj(vec![
            ("status", Json::str("ready")),
            ("restore", Json::str("complete")),
            ("inflight", Json::num(inflight as f64)),
        ])
        .to_string(),
    )
}

/// Dispatch one parsed request against the bridge (pure, testable).
/// Status codes come from [`BridgeError::http_status`] — no string
/// matching on error messages.
pub fn route(bridge: &Bridge, req: &HttpRequest) -> (u16, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => (200, r#"{"status":"ok"}"#.to_string()),
        ("GET", "/v1/metrics") => (200, bridge.telemetry().to_json().to_string()),
        ("POST", "/v1/request") => respond(handle_request(bridge, &req.body)),
        ("POST", "/v1/regenerate") => respond(handle_regenerate(bridge, &req.body)),
        _ => (404, r#"{"error":"not found"}"#.to_string()),
    }
}

/// [`route`] plus the server-state routes (`/ready`) — what both
/// transport paths actually dispatch.
pub fn route_server(bridge: &Bridge, state: &ServerState, req: &HttpRequest) -> (u16, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/ready") => ready_response(state),
        _ => route(bridge, req),
    }
}

fn handle_request(bridge: &Bridge, body: &str) -> Result<String, BridgeError> {
    let j = Json::parse(body).map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let req = Request::from_json(&j).map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let resp = bridge.handle(req)?;
    Ok(resp.to_json().to_string())
}

fn handle_regenerate(bridge: &Bridge, body: &str) -> Result<String, BridgeError> {
    let j = Json::parse(body).map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let id_hex = j
        .str_of("request_id")
        .map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let id = u64::from_str_radix(&id_hex, 16)
        .map_err(|_| BridgeError::bad_request(format!("bad request_id '{id_hex}'")))?;
    let st = j
        .get("service_type")
        .map(ServiceType::from_json)
        .transpose()
        .map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let resp = bridge.regenerate(id, st)?;
    Ok(resp.to_json().to_string())
}

/// Janitor: background maintenance off the request paths —
/// (a) semantic-cache index rebuilds (flat→IVF migration past the row
/// threshold, drift-triggered retrains; the k-means runs with no index
/// lock held), and (b) the WAL-compaction trigger (size-keyed) when a
/// data dir is configured. Cache reads are never blocked by either;
/// journaled *mutations* quiesce for a compaction capture's duration
/// (see persist module docs), which this thread pays instead of a
/// request thread. Compaction failures back off exponentially (capped at
/// 30s) so a full disk doesn't retry a gate-exclusive snapshot capture
/// 4x per second.
fn spawn_janitor(bridge: Arc<Bridge>, stop: Arc<AtomicBool>) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        // Fixed 250ms tick for index maintenance; compaction failures
        // back off via their own cooldown so a full disk never slows
        // in-memory index rebuilds.
        const TICK_MS: u64 = 250;
        let mut compact_backoff_ms: u64 = TICK_MS;
        let mut compact_cooldown_ms: u64 = 0;
        'outer: loop {
            // Sleep in short slices so stop() stays responsive.
            let mut slept = 0;
            while slept < TICK_MS {
                if stop.load(Ordering::Relaxed) {
                    break 'outer;
                }
                std::thread::sleep(Duration::from_millis(50));
                slept += 50;
            }
            bridge.maybe_rebuild_index();
            if bridge.persistence().is_none() {
                continue;
            }
            if compact_cooldown_ms > 0 {
                compact_cooldown_ms = compact_cooldown_ms.saturating_sub(TICK_MS);
                continue;
            }
            match bridge.maybe_compact() {
                Ok(_) => compact_backoff_ms = TICK_MS,
                Err(e) => {
                    compact_backoff_ms = (compact_backoff_ms * 2).min(30_000);
                    compact_cooldown_ms = compact_backoff_ms;
                    eprintln!(
                        "persist: background compaction failed \
                         (retrying in {compact_backoff_ms}ms): {e}"
                    );
                }
            }
        }
    })
}

enum Inner {
    #[cfg(target_os = "linux")]
    Evented(evloop::EventedHandle),
    Threaded(threaded::ThreadedHandle),
}

/// A running server. [`Server::stop`] shuts down gracefully: stop
/// accepting, drain, flush the WAL.
pub struct Server {
    pub addr: std::net::SocketAddr,
    bridge: Arc<Bridge>,
    state: Arc<ServerState>,
    inner: Inner,
    janitor_stop: Arc<AtomicBool>,
    janitor: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start with default tuning (the historical signature).
    pub fn start(bridge: Arc<Bridge>, bind: &str, workers: usize) -> Result<Server> {
        Server::start_with(
            bridge,
            bind,
            ServerConfig {
                workers,
                ..ServerConfig::default()
            },
        )
    }

    pub fn start_with(bridge: Arc<Bridge>, bind: &str, config: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ServerState::new(config.shed_watermark));
        let evented = match config.backend {
            ServerBackend::Auto => cfg!(target_os = "linux"),
            ServerBackend::Evented => true,
            ServerBackend::Threaded => false,
        };
        let inner = if evented {
            #[cfg(target_os = "linux")]
            {
                Inner::Evented(evloop::start(
                    bridge.clone(),
                    listener,
                    state.clone(),
                    config,
                )?)
            }
            #[cfg(not(target_os = "linux"))]
            {
                bail!("evented backend requires Linux (epoll); use ServerBackend::Threaded")
            }
        } else {
            Inner::Threaded(threaded::start(
                bridge.clone(),
                listener,
                state.clone(),
                config,
            )?)
        };
        let janitor_stop = Arc::new(AtomicBool::new(false));
        let janitor = Some(spawn_janitor(bridge.clone(), janitor_stop.clone()));
        Ok(Server {
            addr,
            bridge,
            state,
            inner,
            janitor_stop,
            janitor,
        })
    }

    /// The `/ready` view, callable in-process.
    pub fn ready(&self) -> bool {
        self.state.ready()
    }

    /// Graceful shutdown: stop accepting, drain in-flight connections
    /// (deadline-bounded on the evented path), stop the janitor, and
    /// fsync the WAL so a clean exit is durable to the last write.
    pub fn stop(mut self) {
        self.state.set_draining();
        match self.inner {
            #[cfg(target_os = "linux")]
            Inner::Evented(h) => h.stop(),
            Inner::Threaded(h) => h.stop(),
        }
        self.janitor_stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.janitor.take() {
            let _ = j.join();
        }
        if let Some(p) = self.bridge.persistence() {
            if let Err(e) = p.sync_wal() {
                eprintln!("server: WAL flush on shutdown failed: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn http_parse_roundtrip() {
        // Loopback pair to test the parser without the full server.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_request(&mut s).unwrap()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(
            b"POST /v1/request HTTP/1.1\r\nContent-Length: 13\r\n\r\n{\"user\":\"u1\"}",
        )
        .unwrap();
        let req = h.join().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/request");
        assert_eq!(req.body, "{\"user\":\"u1\"}");
        assert!(req.keep_alive);
    }

    #[test]
    fn write_response_shape() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            write_response(&mut s, 200, r#"{"x":1}"#).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let mut buf = String::new();
        c.read_to_string(&mut buf).unwrap();
        h.join().unwrap();
        assert!(buf.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(buf.ends_with(r#"{"x":1}"#));
        assert!(buf.contains("Content-Length: 7"));
        assert!(buf.contains("Connection: close"));
    }

    #[test]
    fn render_response_keep_alive_header() {
        let ka = String::from_utf8(render_response(200, "{}", true)).unwrap();
        assert!(ka.contains("Connection: keep-alive"));
        let cl = String::from_utf8(render_response(413, "{}", false)).unwrap();
        assert!(cl.starts_with("HTTP/1.1 413 Payload Too Large"));
        assert!(cl.contains("Connection: close"));
    }

    #[test]
    fn error_statuses_are_typed() {
        assert_eq!(
            respond(Err(BridgeError::QuotaExceeded { user: "u".into() })).0,
            429
        );
        assert_eq!(respond(Err(BridgeError::UnknownRequest(1))).0, 404);
        assert_eq!(respond(Err(BridgeError::bad_request("x"))).0, 400);
        assert_eq!(
            respond(Err(BridgeError::Internal(anyhow::anyhow!("x")))).0,
            500
        );
        // Error bodies carry the message, not a guessed substring.
        let (_, body) = respond(Err(BridgeError::QuotaExceeded { user: "s1".into() }));
        assert!(body.contains("quota exceeded for user s1"));
    }

    #[test]
    fn ready_reflects_draining_and_watermark() {
        let state = ServerState::new(2);
        let (code, body) = ready_response(&state);
        assert_eq!(code, 200, "{body}");
        assert!(body.contains("\"restore\""));

        state.begin_dispatch();
        state.begin_dispatch();
        assert!(!state.admits());
        let (code, body) = ready_response(&state);
        assert_eq!(code, 503);
        assert!(body.contains("overloaded"));

        state.end_dispatch();
        assert!(state.ready());
        state.set_draining();
        let (code, body) = ready_response(&state);
        assert_eq!(code, 503);
        assert!(body.contains("draining"));
    }
}
