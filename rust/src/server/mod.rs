//! REST server — the interface the classroom deployment used (§5.2):
//! a hand-rolled HTTP/1.1 server on `std::net` with a worker pool fed by
//! the per-user FIFO queue substrate (the paper's SQS per-user
//! exclusive-delivery guarantee, end to end).
//!
//! The acceptor thread only accepts: request parsing happens on the
//! workers, so one slow-writing client can never stall accepts
//! (head-of-line blocking). Each connection flows through two queue hops
//! on the same FIFO substrate — a connection-unique "raw" group while
//! unparsed, then the per-user group once the body names a user. The
//! per-user guarantee is *serialization* (at most one in-flight request
//! per user, queue order thereafter); a user's requests enter their
//! queue in parse-completion order, which across separate connections
//! can differ from accept order — same as concurrent clients racing the
//! paper's SQS enqueue.
//!
//! Routes:
//! * `POST /v1/request`     — body: [`crate::api::Request`] JSON.
//! * `POST /v1/regenerate`  — body: `{"request_id": "<hex>", "service_type": {...}?}`.
//! * `GET  /v1/metrics`     — telemetry snapshot.
//! * `GET  /health`         — liveness.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::api::{Request, ServiceType};
use crate::coordinator::Bridge;
use crate::error::BridgeError;
use crate::queuing::FifoQueue;
use crate::util::json::Json;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Read one HTTP/1.1 request from the stream (no deadline; see
/// [`read_request_deadline`]).
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    read_request_deadline(stream, None)
}

/// Re-arm the socket timeout with the remaining budget before a read.
fn arm_deadline(stream: &TcpStream, deadline: Option<std::time::Instant>) -> Result<()> {
    if let Some(d) = deadline {
        match d.checked_duration_since(std::time::Instant::now()) {
            Some(left) if !left.is_zero() => stream.set_read_timeout(Some(left))?,
            _ => bail!("request read deadline exceeded"),
        }
    }
    Ok(())
}

fn find_bytes(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one HTTP/1.1 request. `deadline` bounds the TOTAL wall time
/// across every read (the socket timeout is re-armed with the remaining
/// budget before each one), so a byte-dribbling client cannot hold a
/// worker beyond it.
pub fn read_request_deadline(
    stream: &mut TcpStream,
    deadline: Option<std::time::Instant>,
) -> Result<HttpRequest> {
    const MAX_HEAD: usize = 64 * 1024;
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    // Accumulate until the blank line ending the headers (CRLF per spec,
    // bare LF tolerated like the old line-based parser).
    let (head_end, sep_len) = loop {
        let crlf = find_bytes(&buf, b"\r\n\r\n").map(|p| (p, 4));
        let lf = find_bytes(&buf, b"\n\n").map(|p| (p, 2));
        match (crlf, lf) {
            (Some(a), Some(b)) => break if a.0 <= b.0 { a } else { b },
            (Some(a), None) => break a,
            (None, Some(b)) => break b,
            (None, None) => {}
        }
        if buf.len() > MAX_HEAD {
            bail!("headers too large");
        }
        arm_deadline(stream, deadline)?;
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-headers");
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("non-utf8 headers")?;
    let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
    let request_line = lines.next().context("missing request line")?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let mut content_length = 0usize;
    for header in lines {
        if let Some((k, v)) = header.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    if content_length > 4 * 1024 * 1024 {
        bail!("body too large");
    }
    let mut body = buf[head_end + sep_len..].to_vec();
    while body.len() < content_length {
        arm_deadline(stream, deadline)?;
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8(body)?,
    })
}

pub fn write_response(stream: &mut TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        _ => "Internal Server Error",
    };
    let msg = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(msg.as_bytes())?;
    Ok(())
}

fn err_body(e: &BridgeError) -> String {
    Json::obj(vec![("error", Json::str(e.to_string()))]).to_string()
}

fn respond(result: Result<String, BridgeError>) -> (u16, String) {
    match result {
        Ok(body) => (200, body),
        Err(e) => (e.http_status(), err_body(&e)),
    }
}

/// Dispatch one parsed request against the bridge (pure, testable).
/// Status codes come from [`BridgeError::http_status`] — no string
/// matching on error messages.
pub fn route(bridge: &Bridge, req: &HttpRequest) -> (u16, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => (200, r#"{"status":"ok"}"#.to_string()),
        ("GET", "/v1/metrics") => (200, bridge.telemetry().to_json().to_string()),
        ("POST", "/v1/request") => respond(handle_request(bridge, &req.body)),
        ("POST", "/v1/regenerate") => respond(handle_regenerate(bridge, &req.body)),
        _ => (404, r#"{"error":"not found"}"#.to_string()),
    }
}

fn handle_request(bridge: &Bridge, body: &str) -> Result<String, BridgeError> {
    let j = Json::parse(body).map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let req = Request::from_json(&j).map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let resp = bridge.handle(req)?;
    Ok(resp.to_json().to_string())
}

fn handle_regenerate(bridge: &Bridge, body: &str) -> Result<String, BridgeError> {
    let j = Json::parse(body).map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let id_hex = j
        .str_of("request_id")
        .map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let id = u64::from_str_radix(&id_hex, 16)
        .map_err(|_| BridgeError::bad_request(format!("bad request_id '{id_hex}'")))?;
    let st = j
        .get("service_type")
        .map(ServiceType::from_json)
        .transpose()
        .map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let resp = bridge.regenerate(id, st)?;
    Ok(resp.to_json().to_string())
}

/// A connection's place in the two-hop worker flow.
enum Conn {
    /// Accepted, not yet parsed (queued under a connection-unique group).
    Raw(TcpStream),
    /// Parsed, awaiting dispatch (queued under the per-user group).
    Ready(TcpStream, HttpRequest),
}

/// Serve until `stop` flips. The acceptor enqueues raw connections; the
/// `workers` threads parse them, re-enqueue under the per-user FIFO group
/// (user extracted from the body when present), and handle them.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    pub fn start(bridge: Arc<Bridge>, bind: &str, workers: usize) -> Result<Server> {
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue: Arc<FifoQueue<u64>> = Arc::new(FifoQueue::new());
        // Connection registry: id -> state.
        let conns: Arc<std::sync::Mutex<std::collections::HashMap<u64, Conn>>> =
            Arc::new(std::sync::Mutex::new(std::collections::HashMap::new()));
        let mut join = Vec::new();

        // Acceptor: accept, register, enqueue — never reads the socket, so
        // a client that dribbles its request bytes can't block accepts.
        {
            let stop = stop.clone();
            let queue = queue.clone();
            let conns = conns.clone();
            join.push(std::thread::spawn(move || {
                let mut next_id = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nonblocking(false).ok();
                            // Bound response writes to unresponsive clients.
                            stream
                                .set_write_timeout(Some(std::time::Duration::from_secs(10)))
                                .ok();
                            next_id += 1;
                            conns.lock().unwrap().insert(next_id, Conn::Raw(stream));
                            // Group naming doubles as scheduling policy:
                            // FifoQueue::pop scans groups in key order, so
                            // dispatch groups ("d:...") always win over
                            // parse groups ("p:...") — a flood of new
                            // connections can't starve parsed requests —
                            // and prefixing keeps client-chosen user names
                            // out of the internal namespace.
                            queue.push(&format!("p:raw-{next_id}"), next_id);
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                queue.close();
            }));
        }

        // Janitor: background maintenance off the request paths —
        // (a) semantic-cache index rebuilds (flat→IVF migration past the
        // row threshold, drift-triggered retrains; the k-means runs with
        // no index lock held), and (b) the WAL-compaction trigger
        // (size-keyed) when a data dir is configured. Cache reads are
        // never blocked by either; journaled *mutations* quiesce for a
        // compaction capture's duration (see persist module docs), which
        // this thread pays instead of a request thread. Compaction
        // failures back off exponentially (capped at 30s) so a full disk
        // doesn't retry a gate-exclusive snapshot capture 4x per second.
        {
            let stop = stop.clone();
            let bridge = bridge.clone();
            join.push(std::thread::spawn(move || {
                // Fixed 250ms tick for index maintenance; compaction
                // failures back off via their own cooldown so a full disk
                // never slows in-memory index rebuilds.
                const TICK_MS: u64 = 250;
                let mut compact_backoff_ms: u64 = TICK_MS;
                let mut compact_cooldown_ms: u64 = 0;
                'outer: loop {
                    // Sleep in short slices so stop() stays responsive.
                    let mut slept = 0;
                    while slept < TICK_MS {
                        if stop.load(Ordering::Relaxed) {
                            break 'outer;
                        }
                        std::thread::sleep(std::time::Duration::from_millis(50));
                        slept += 50;
                    }
                    bridge.maybe_rebuild_index();
                    if bridge.persistence().is_none() {
                        continue;
                    }
                    if compact_cooldown_ms > 0 {
                        compact_cooldown_ms = compact_cooldown_ms.saturating_sub(TICK_MS);
                        continue;
                    }
                    match bridge.maybe_compact() {
                        Ok(_) => compact_backoff_ms = TICK_MS,
                        Err(e) => {
                            compact_backoff_ms = (compact_backoff_ms * 2).min(30_000);
                            compact_cooldown_ms = compact_backoff_ms;
                            eprintln!(
                                "persist: background compaction failed \
                                 (retrying in {compact_backoff_ms}ms): {e}"
                            );
                        }
                    }
                }
            }));
        }

        // Workers: a raw pop parses and re-enqueues under the user group;
        // a ready pop dispatches. Raw groups are connection-unique, so
        // parsing parallelizes; ready groups serialize per user (the SQS
        // per-user exclusive-delivery guarantee).
        for _ in 0..workers.max(1) {
            let queue = queue.clone();
            let conns = conns.clone();
            let bridge = bridge.clone();
            join.push(std::thread::spawn(move || {
                while let Some(msg) = queue.pop() {
                    let entry = conns.lock().unwrap().remove(&msg.payload);
                    match entry {
                        Some(Conn::Raw(mut stream)) => match read_request_deadline(
                            &mut stream,
                            Some(std::time::Instant::now() + std::time::Duration::from_secs(10)),
                        ) {
                            Ok(req) => {
                                // FIFO group = user when parseable, else
                                // connection-unique (no ordering need).
                                let group = Json::parse(&req.body)
                                    .ok()
                                    .and_then(|j| j.str_of("user").ok())
                                    .map(|user| format!("d:u:{user}"))
                                    .unwrap_or_else(|| format!("d:a:{}", msg.payload));
                                conns
                                    .lock()
                                    .unwrap()
                                    .insert(msg.payload, Conn::Ready(stream, req));
                                queue.push(&group, msg.payload);
                            }
                            Err(_) => {
                                let _ = write_response(
                                    &mut stream,
                                    400,
                                    r#"{"error":"bad request"}"#,
                                );
                            }
                        },
                        Some(Conn::Ready(mut stream, req)) => {
                            let (status, body) = route(&bridge, &req);
                            let _ = write_response(&mut stream, status, &body);
                        }
                        None => {}
                    }
                    queue.ack(msg.id, &msg.group);
                }
            }));
        }

        Ok(Server { addr, stop, join })
    }

    pub fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.join {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_parse_roundtrip() {
        // Loopback pair to test the parser without the full server.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_request(&mut s).unwrap()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(
            b"POST /v1/request HTTP/1.1\r\nContent-Length: 13\r\n\r\n{\"user\":\"u1\"}",
        )
        .unwrap();
        let req = h.join().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/request");
        assert_eq!(req.body, "{\"user\":\"u1\"}");
    }

    #[test]
    fn write_response_shape() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            write_response(&mut s, 200, r#"{"x":1}"#).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let mut buf = String::new();
        c.read_to_string(&mut buf).unwrap();
        h.join().unwrap();
        assert!(buf.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(buf.ends_with(r#"{"x":1}"#));
        assert!(buf.contains("Content-Length: 7"));
    }

    #[test]
    fn error_statuses_are_typed() {
        assert_eq!(
            respond(Err(BridgeError::QuotaExceeded { user: "u".into() })).0,
            429
        );
        assert_eq!(respond(Err(BridgeError::UnknownRequest(1))).0, 404);
        assert_eq!(respond(Err(BridgeError::bad_request("x"))).0, 400);
        assert_eq!(
            respond(Err(BridgeError::Internal(anyhow::anyhow!("x")))).0,
            500
        );
        // Error bodies carry the message, not a guessed substring.
        let (_, body) = respond(Err(BridgeError::QuotaExceeded { user: "s1".into() }));
        assert!(body.contains("quota exceeded for user s1"));
    }
}
