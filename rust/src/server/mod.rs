//! REST server — the interface the classroom deployment used (§5.2):
//! a hand-rolled HTTP/1.1 server on `std::net` with a worker pool fed by
//! the per-user FIFO queue substrate (so the paper's SQS ordering guarantee
//! holds end to end).
//!
//! Routes:
//! * `POST /v1/request`     — body: [`crate::api::Request`] JSON.
//! * `POST /v1/regenerate`  — body: `{"request_id": "<hex>", "service_type": {...}?}`.
//! * `GET  /v1/metrics`     — telemetry snapshot.
//! * `GET  /health`         — liveness.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::api::{Request, ServiceType};
use crate::coordinator::Bridge;
use crate::queuing::FifoQueue;
use crate::util::json::Json;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Read one HTTP/1.1 request from the stream.
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header)?;
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((k, v)) = header.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    if content_length > 4 * 1024 * 1024 {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8(body)?,
    })
}

pub fn write_response(stream: &mut TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        _ => "Internal Server Error",
    };
    let msg = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(msg.as_bytes())?;
    Ok(())
}

fn err_body(e: &anyhow::Error) -> String {
    Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string()
}

/// Dispatch one parsed request against the bridge (pure, testable).
pub fn route(bridge: &Bridge, req: &HttpRequest) -> (u16, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => (200, r#"{"status":"ok"}"#.to_string()),
        ("GET", "/v1/metrics") => (200, bridge.telemetry().to_json().to_string()),
        ("POST", "/v1/request") => match handle_request(bridge, &req.body) {
            Ok(body) => (200, body),
            Err(e) => {
                let status = if format!("{e:#}").contains("quota") { 429 } else { 400 };
                (status, err_body(&e))
            }
        },
        ("POST", "/v1/regenerate") => match handle_regenerate(bridge, &req.body) {
            Ok(body) => (200, body),
            Err(e) => (400, err_body(&e)),
        },
        _ => (404, r#"{"error":"not found"}"#.to_string()),
    }
}

fn handle_request(bridge: &Bridge, body: &str) -> Result<String> {
    let j = Json::parse(body)?;
    let req = Request::from_json(&j)?;
    let resp = bridge.handle(req)?;
    Ok(resp.to_json().to_string())
}

fn handle_regenerate(bridge: &Bridge, body: &str) -> Result<String> {
    let j = Json::parse(body)?;
    let id_hex = j.str_of("request_id")?;
    let id = u64::from_str_radix(&id_hex, 16)
        .map_err(|_| anyhow!("bad request_id '{id_hex}'"))?;
    let st = j
        .get("service_type")
        .map(ServiceType::from_json)
        .transpose()?;
    let resp = bridge.regenerate(id, st)?;
    Ok(resp.to_json().to_string())
}

/// Serve until `stop` flips. Each accepted connection is enqueued on the
/// per-user FIFO (user extracted from the body when present) and handled
/// by `workers` threads.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    pub fn start(bridge: Arc<Bridge>, bind: &str, workers: usize) -> Result<Server> {
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue: Arc<FifoQueue<u64>> = Arc::new(FifoQueue::new());
        // Connection registry: id -> stream.
        let conns: Arc<std::sync::Mutex<std::collections::HashMap<u64, (TcpStream, HttpRequest)>>> =
            Arc::new(std::sync::Mutex::new(std::collections::HashMap::new()));
        let mut join = Vec::new();

        // Acceptor.
        {
            let stop = stop.clone();
            let queue = queue.clone();
            let conns = conns.clone();
            join.push(std::thread::spawn(move || {
                let mut next_id = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            stream.set_nonblocking(false).ok();
                            match read_request(&mut stream) {
                                Ok(req) => {
                                    // FIFO group = user when parseable, else
                                    // connection-unique (no ordering need).
                                    let group = Json::parse(&req.body)
                                        .ok()
                                        .and_then(|j| j.str_of("user").ok())
                                        .unwrap_or_else(|| format!("anon-{next_id}"));
                                    next_id += 1;
                                    conns.lock().unwrap().insert(next_id, (stream, req));
                                    queue.push(&group, next_id);
                                }
                                Err(_) => {
                                    let _ = write_response(
                                        &mut stream,
                                        400,
                                        r#"{"error":"bad request"}"#,
                                    );
                                }
                            }
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                queue.close();
            }));
        }

        // Workers.
        for _ in 0..workers.max(1) {
            let queue = queue.clone();
            let conns = conns.clone();
            let bridge = bridge.clone();
            join.push(std::thread::spawn(move || {
                while let Some(msg) = queue.pop() {
                    let entry = conns.lock().unwrap().remove(&msg.payload);
                    if let Some((mut stream, req)) = entry {
                        let (status, body) = route(&bridge, &req);
                        let _ = write_response(&mut stream, status, &body);
                    }
                    queue.ack(msg.id, &msg.group);
                }
            }));
        }

        Ok(Server { addr, stop, join })
    }

    pub fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.join {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_parse_roundtrip() {
        // Loopback pair to test the parser without the full server.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_request(&mut s).unwrap()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(
            b"POST /v1/request HTTP/1.1\r\nContent-Length: 13\r\n\r\n{\"user\":\"u1\"}",
        )
        .unwrap();
        let req = h.join().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/request");
        assert_eq!(req.body, "{\"user\":\"u1\"}");
    }

    #[test]
    fn write_response_shape() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            write_response(&mut s, 200, r#"{"x":1}"#).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let mut buf = String::new();
        c.read_to_string(&mut buf).unwrap();
        h.join().unwrap();
        assert!(buf.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(buf.ends_with(r#"{"x":1}"#));
        assert!(buf.contains("Content-Length: 7"));
    }
}
