//! REST server — the interface the classroom deployment used (§5.2),
//! grown into an evented front door shaped for the ROADMAP's
//! millions-of-users target.
//!
//! Two interchangeable transport paths serve the same routes:
//!
//! * **Evented** (`evloop.rs`, Linux default): a nonblocking epoll
//!   readiness loop (raw-syscall shim, [`crate::util::epoll`]) drives
//!   per-connection state machines with HTTP/1.1 keep-alive, incremental
//!   parsing ([`RequestParser`]), bounded per-user backpressure, and
//!   load-shedding admission control that answers 429 *before* queues
//!   melt. Worker threads are a dispatch pool fed fully-parsed requests
//!   through the per-user FIFO substrate; responses travel back to the
//!   loop over a wakeup pipe.
//! * **Threaded** (`threaded.rs`, portable fallback): the original
//!   blocking-socket worker pool — the acceptor enqueues raw
//!   connections, workers parse and re-enqueue under the per-user group,
//!   one request per connection (`Connection: close`).
//!
//! Both paths preserve the paper's per-user **serialization** guarantee
//! end to end (the SQS exclusive-delivery semantics, via
//! [`crate::queuing::FifoQueue`]): at most one in-flight request per
//! user, queue order thereafter. A user's requests enter their queue in
//! parse-completion order, which across separate connections can differ
//! from accept order — same as concurrent clients racing the paper's SQS
//! enqueue.
//!
//! **The three 429s and the two 503s.** The server sheds with HTTP 429
//! in three distinguishable ways, each with a machine-readable
//! `"reason"` in the body:
//!
//! * `"admission"` — server-wide overload, *before* any bridge work:
//!   at accept when [`ServerConfig::max_conns`] live connections exist,
//!   at dispatch when in-flight requests reach the shed watermark, and
//!   at enqueue when one user's queue is at
//!   [`ServerConfig::per_user_queue_cap`].
//! * `"rate"` — this user's token bucket is empty
//!   ([`crate::ops::RateLimiter`]; `--rate-per-sec`/`--rate-burst`),
//!   checked in the loop ahead of the quota gate, with `Retry-After`.
//! * `"quota"` — the pipeline's per-user daily cap
//!   ([`crate::error::BridgeError::QuotaExceeded`]); body names the user.
//!
//! Backend sickness sheds 503 the same way: `"breaker"` (the model's
//! circuit breaker is open; `Retry-After` = remaining cooldown) and
//! `"timeout"` (one engine RPC exceeded `--engine-timeout-secs`). Shed
//! counts surface in `/v1/metrics` (`server_shed_*`, `breaker_*`).
//!
//! Routes (data port):
//! * `POST /v1/request`     — body: [`crate::api::Request`] JSON.
//! * `POST /v1/regenerate`  — body: `{"request_id": "<hex>", "service_type": {...}?}`.
//! * `GET  /v1/metrics`     — telemetry snapshot.
//! * `GET  /health`         — liveness (always 200 while the process serves).
//! * `GET  /ready`          — readiness: restore complete (implied by a
//!   constructed [`Bridge`] — `open_with` replays WAL + snapshot before
//!   returning), not draining, and in-flight load below the shed
//!   watermark; 503 otherwise.
//!
//! Routes (admin port, `--admin-port`; see [`route_admin`]): `GET
//! /admin/cache` (index tier/rows/bytes + hit/miss counters), `DELETE
//! /admin/cache?key=` / `DELETE /admin/cache` (invalidate one exact
//! entry / clear everything — both journaled through the WAL), `GET
//! /admin/breaker`, `GET /admin/sync` (replication status; see
//! [`crate::sync`]), `POST /admin/config` (staged hot-reload), plus
//! `/health` and `/v1/metrics`. On the evented path the admin listener
//! is multiplexed by the same epoll loop and answered inline, so it
//! stays responsive while the data port sheds; admin connections are
//! exempt from `max_conns`. Hot-reload is validate-then-swap: the new
//! [`crate::ops::OpsConfig`] is built and checked completely, then
//! published as one `Arc` swap — a request loads the snapshot once, so
//! it observes either the old config or the new one, never a mix.
//!
//! [`Server::stop`] is graceful on both paths: stop accepting, drain
//! in-flight connections (bounded by [`ServerConfig::drain_deadline`] on
//! the evented path), then fsync the WAL so a clean exit loses nothing.

mod conn;
#[cfg(target_os = "linux")]
mod evloop;
mod threaded;

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, RwLock};
use std::time::Duration;

use anyhow::{bail, Result};

pub use conn::{
    Conn, ConnState, FillOutcome, HttpRequest, ParseError, RequestParser, WriteOutcome,
    MAX_BODY_BYTES, MAX_HEAD_BYTES,
};

use crate::api::{Request, ServiceType};
use crate::coordinator::Bridge;
use crate::error::BridgeError;
use crate::ops::{OpsConfig, RateLimiter};
use crate::util::json::Json;

/// Lock a mutex, recovering from poisoning. A worker that panicked while
/// holding the lock completed (or abandoned) a single queue push — the
/// data is a `Vec` of finished completions, valid either way — so the
/// loop must keep serving rather than propagate the panic and kill the
/// server (the PR 8 headline bug).
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Which transport path serves connections.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerBackend {
    /// Evented on Linux, threaded elsewhere.
    Auto,
    /// Force the epoll readiness loop (errors off-Linux).
    Evented,
    /// Force the portable blocking worker pool.
    Threaded,
}

/// Server tuning knobs. `Default` matches the CLI defaults.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Dispatch-pool threads (both paths).
    pub workers: usize,
    /// Live-connection ceiling (evented path); excess accepts are
    /// answered 429 and closed.
    pub max_conns: usize,
    /// In-flight dispatched-request watermark: at or above it, newly
    /// parsed requests shed with an admission 429 instead of queueing.
    pub shed_watermark: usize,
    /// Per-user queue-depth bound (including the in-flight request).
    pub per_user_queue_cap: usize,
    /// Idle keep-alive connections are closed after this long.
    pub keepalive_timeout: Duration,
    /// A single request's bytes must fully arrive within this budget
    /// (anti-slowloris; mirrors the threaded path's read deadline).
    pub request_deadline: Duration,
    /// Graceful-stop bound for draining in-flight work (evented path).
    pub drain_deadline: Duration,
    /// Transport selection.
    pub backend: ServerBackend,
    /// Per-user token-bucket refill rate (`0.0` disables rate limiting).
    pub rate_per_sec: f64,
    /// Per-user token-bucket burst capacity.
    pub rate_burst: f64,
    /// Bind address for the admin listener (`--admin-port`); `None`
    /// disables the admin surface.
    pub admin_bind: Option<String>,
    /// Peer replication wiring (`--node-id`/`--sync-port`/`--peer`);
    /// `None` (the default) starts no sync threads at all — see
    /// [`crate::sync`].
    pub sync: Option<crate::sync::SyncConfig>,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            workers: 4,
            max_conns: 4096,
            shed_watermark: 512,
            per_user_queue_cap: 32,
            keepalive_timeout: Duration::from_secs(30),
            request_deadline: Duration::from_secs(10),
            drain_deadline: Duration::from_secs(5),
            backend: ServerBackend::Auto,
            rate_per_sec: 0.0,
            rate_burst: 16.0,
            admin_bind: None,
            sync: None,
        }
    }
}

/// Load/lifecycle state shared between the transport path and the
/// `/ready` endpoint: the in-flight dispatched-request count (the
/// admission watermark input), the draining latch, the hot-reloadable
/// [`OpsConfig`] snapshot, and the per-user rate limiter.
pub struct ServerState {
    draining: AtomicBool,
    inflight: AtomicUsize,
    /// Current ops tunables. Swapped whole by `POST /admin/config`;
    /// request paths load the `Arc` once and read every field from that
    /// snapshot, so no request observes a half-applied config.
    ops: RwLock<Arc<OpsConfig>>,
    rate: RateLimiter,
    /// Status view of the replication service, set by [`Server::start_with`]
    /// when sync is configured; what `GET /admin/sync` reads.
    sync: RwLock<Option<crate::sync::SyncHandle>>,
}

impl ServerState {
    pub fn new(shed_watermark: usize) -> ServerState {
        ServerState::with_ops(OpsConfig {
            shed_watermark: shed_watermark.max(1),
            ..OpsConfig::default()
        })
    }

    pub fn with_ops(ops: OpsConfig) -> ServerState {
        ServerState {
            draining: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            ops: RwLock::new(Arc::new(ops)),
            rate: RateLimiter::new(),
            sync: RwLock::new(None),
        }
    }

    fn from_config(config: &ServerConfig) -> ServerState {
        ServerState::with_ops(OpsConfig {
            shed_watermark: config.shed_watermark.max(1),
            rate_per_sec: config.rate_per_sec,
            rate_burst: config.rate_burst,
        })
    }

    /// The current ops-config snapshot (one `Arc` clone).
    pub fn ops_config(&self) -> Arc<OpsConfig> {
        self.ops
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    /// Publish a new, fully-validated ops config (the hot-reload swap).
    pub fn set_ops_config(&self, ops: OpsConfig) {
        *self.ops.write().unwrap_or_else(PoisonError::into_inner) = Arc::new(ops);
    }

    /// Requests dispatched to the worker pool and not yet responded.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    /// Below the shed watermark of an already-loaded config snapshot.
    pub fn admits_under(&self, ops: &OpsConfig) -> bool {
        self.inflight() < ops.shed_watermark
    }

    /// Below the shed watermark — new dispatches are admitted.
    pub fn admits(&self) -> bool {
        self.admits_under(&self.ops_config())
    }

    /// Spend one rate-limit token for `user` under a loaded config
    /// snapshot; `Err(secs)` is the `Retry-After` hint.
    pub fn rate_acquire(&self, ops: &OpsConfig, user: &str) -> std::result::Result<(), u64> {
        self.rate.try_acquire(ops.rate_per_sec, ops.rate_burst, user)
    }

    /// Ready to take traffic: not draining and below the watermark.
    pub fn ready(&self) -> bool {
        !self.is_draining() && self.admits()
    }

    /// Publish the replication service's status handle (boot-time, once).
    pub fn set_sync_handle(&self, handle: crate::sync::SyncHandle) {
        *self.sync.write().unwrap_or_else(PoisonError::into_inner) = Some(handle);
    }

    /// The replication status view, when sync is configured.
    pub fn sync_handle(&self) -> Option<crate::sync::SyncHandle> {
        self.sync
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    pub(crate) fn set_draining(&self) {
        self.draining.store(true, Ordering::Relaxed);
    }

    pub(crate) fn begin_dispatch(&self) {
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn end_dispatch(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Read one HTTP/1.1 request from the stream (no deadline; see
/// [`read_request_deadline`]).
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    read_request_deadline(stream, None)
}

/// Re-arm the socket timeout with the remaining budget before a read.
fn arm_deadline(stream: &TcpStream, deadline: Option<std::time::Instant>) -> Result<()> {
    if let Some(d) = deadline {
        match d.checked_duration_since(std::time::Instant::now()) {
            Some(left) if !left.is_zero() => stream.set_read_timeout(Some(left))?,
            _ => bail!("request read deadline exceeded"),
        }
    }
    Ok(())
}

/// Read one HTTP/1.1 request on a **blocking** socket — the threaded
/// path's entry into the same incremental [`RequestParser`] the evented
/// loop uses. `deadline` bounds the TOTAL wall time across every read
/// (the socket timeout is re-armed with the remaining budget before each
/// one), so a byte-dribbling client cannot hold a worker beyond it.
pub fn read_request_deadline(
    stream: &mut TcpStream,
    deadline: Option<std::time::Instant>,
) -> Result<HttpRequest> {
    let mut parser = RequestParser::new();
    let mut tmp = [0u8; 4096];
    loop {
        if let Some(req) = parser.next()? {
            return Ok(req);
        }
        arm_deadline(stream, deadline)?;
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        parser.feed(&tmp[..n]);
    }
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

/// One routed response: status, JSON body, and an optional `Retry-After`
/// hint (breaker 503s and rate-limit 429s carry one).
#[derive(Clone, Debug)]
pub struct Reply {
    pub status: u16,
    pub body: String,
    pub retry_after: Option<u64>,
}

impl Reply {
    pub fn new(status: u16, body: impl Into<String>) -> Reply {
        Reply {
            status,
            body: body.into(),
            retry_after: None,
        }
    }

    pub fn with_retry_after(mut self, secs: u64) -> Reply {
        self.retry_after = Some(secs);
        self
    }
}

/// Serialize a [`Reply`]. `keep_alive` controls the `Connection` header —
/// the evented path holds connections open between requests, the
/// threaded path always closes.
pub fn render_reply(reply: &Reply, keep_alive: bool) -> Vec<u8> {
    let retry = match reply.retry_after {
        Some(secs) => format!("Retry-After: {secs}\r\n"),
        None => String::new(),
    };
    format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n{retry}Connection: {}\r\n\r\n{}",
        reply.status,
        reason_phrase(reply.status),
        reply.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
        reply.body,
    )
    .into_bytes()
}

/// [`render_reply`] for header-less callers (parse errors, probes).
pub fn render_response(status: u16, body: &str, keep_alive: bool) -> Vec<u8> {
    render_reply(&Reply::new(status, body), keep_alive)
}

/// Write a `Connection: close` response on a blocking socket.
pub fn write_response(stream: &mut TcpStream, status: u16, body: &str) -> Result<()> {
    use std::io::Write;
    stream.write_all(&render_response(status, body, false))?;
    Ok(())
}

/// [`write_response`] for a full [`Reply`] (carries `Retry-After`).
pub fn write_reply(stream: &mut TcpStream, reply: &Reply) -> Result<()> {
    use std::io::Write;
    stream.write_all(&render_reply(reply, false))?;
    Ok(())
}

/// The admission-control shed body; `"reason":"admission"` distinguishes
/// it from the rate-limit and per-user quota 429s.
pub(crate) fn admission_shed_body() -> String {
    r#"{"error":"server overloaded; request shed by admission control","reason":"admission"}"#
        .to_string()
}

/// The rate-limit shed reply: 429 + `"reason":"rate"` + `Retry-After`.
pub(crate) fn rate_shed_reply(user: &str, retry_secs: u64) -> Reply {
    let body = Json::obj(vec![
        (
            "error",
            Json::str(format!("rate limit exceeded for user {user}")),
        ),
        ("reason", Json::str("rate")),
        ("retry_after_secs", Json::num(retry_secs as f64)),
    ])
    .to_string();
    Reply::new(429, body).with_retry_after(retry_secs)
}

fn err_body(e: &BridgeError) -> String {
    let mut fields = vec![("error", Json::str(e.to_string()))];
    if let Some(reason) = e.reason() {
        fields.push(("reason", Json::str(reason)));
    }
    Json::obj(fields).to_string()
}

fn respond(result: Result<String, BridgeError>) -> Reply {
    match result {
        Ok(body) => Reply::new(200, body),
        Err(e) => {
            let mut reply = Reply::new(e.http_status(), err_body(&e));
            reply.retry_after = e.retry_after_secs();
            reply
        }
    }
}

/// The `/ready` probe: 200 only when restore is complete (always true
/// once a [`Bridge`] exists), the server is not draining, and in-flight
/// load sits below the shed watermark.
fn ready_response(state: &ServerState) -> Reply {
    if state.is_draining() {
        return Reply::new(503, r#"{"status":"draining"}"#);
    }
    let inflight = state.inflight();
    if !state.admits() {
        return Reply::new(
            503,
            Json::obj(vec![
                ("status", Json::str("overloaded")),
                ("inflight", Json::num(inflight as f64)),
            ])
            .to_string(),
        );
    }
    Reply::new(
        200,
        Json::obj(vec![
            ("status", Json::str("ready")),
            ("restore", Json::str("complete")),
            ("inflight", Json::num(inflight as f64)),
        ])
        .to_string(),
    )
}

/// Dispatch one parsed request against the bridge (pure, testable).
/// Status codes come from [`BridgeError::http_status`] — no string
/// matching on error messages.
pub fn route(bridge: &Bridge, req: &HttpRequest) -> Reply {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => Reply::new(200, r#"{"status":"ok"}"#),
        ("GET", "/v1/metrics") => Reply::new(200, bridge.telemetry().to_json().to_string()),
        ("POST", "/v1/request") => respond(handle_request(bridge, &req.body)),
        ("POST", "/v1/regenerate") => respond(handle_regenerate(bridge, &req.body)),
        // Failpoint for the panic-isolation regression tests: a worker
        // that unwinds here must 500 this connection and keep serving.
        ("POST", "/v1/test/panic") if crate::util::failpoints_enabled() => {
            panic!("failpoint: injected handler panic")
        }
        _ => Reply::new(404, r#"{"error":"not found"}"#),
    }
}

/// [`route`] plus the server-state routes (`/ready`) — what both
/// transport paths actually dispatch.
pub fn route_server(bridge: &Bridge, state: &ServerState, req: &HttpRequest) -> Reply {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/ready") => ready_response(state),
        _ => route(bridge, req),
    }
}

/// Split a request-line path into `(path, query)` — [`HttpRequest::path`]
/// carries the raw request-line token, query string included.
fn split_query(raw: &str) -> (&str, Option<&str>) {
    match raw.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (raw, None),
    }
}

/// Extract one `key=value` pair from a query string, percent-decoded.
fn query_param(query: Option<&str>, name: &str) -> Option<String> {
    query?
        .split('&')
        .filter_map(|pair| pair.split_once('='))
        .find(|(k, _)| *k == name)
        .map(|(_, v)| percent_decode(v))
}

/// Minimal `%XX` + `+` decoding for query-string values.
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' if i + 2 < bytes.len() => {
                let hex = std::str::from_utf8(&bytes[i + 1..i + 3])
                    .ok()
                    .and_then(|h| u8::from_str_radix(h, 16).ok());
                match hex {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Dispatch one request on the **admin** listener (`--admin-port`).
/// Never routed to the worker pool: the evented loop answers admin
/// requests inline, so the surface stays responsive while the data port
/// sheds under overload. (Consequence: the rare `DELETE /admin/cache`
/// full clear briefly occupies the loop if it races a WAL compaction's
/// exclusive gate; accepted for an admin-initiated, admin-rate action.)
pub fn route_admin(bridge: &Bridge, state: &ServerState, req: &HttpRequest) -> Reply {
    let (path, query) = split_query(&req.path);
    match (req.method.as_str(), path) {
        ("GET", "/health") => Reply::new(200, r#"{"status":"ok"}"#),
        ("GET", "/ready") => ready_response(state),
        ("GET", "/v1/metrics") => Reply::new(200, bridge.telemetry().to_json().to_string()),
        ("GET", "/admin/cache") => admin_cache_stats(bridge),
        ("DELETE", "/admin/cache") => admin_cache_invalidate(bridge, query),
        ("GET", "/admin/breaker") => admin_breaker_snapshot(bridge),
        ("GET", "/admin/sync") => admin_sync_status(state),
        ("POST", "/admin/config") => admin_config_reload(bridge, state, &req.body),
        _ => Reply::new(404, r#"{"error":"not found"}"#),
    }
}

/// `GET /admin/sync`: replication status — node identity, peer wiring,
/// write clock, per-origin high-water marks, and round/entry counters.
/// `{"enabled":false}` on an unreplicated node (still 200: asking "is
/// sync on?" is a valid question with a valid answer).
fn admin_sync_status(state: &ServerState) -> Reply {
    match state.sync_handle() {
        Some(h) => Reply::new(200, h.status().to_string()),
        None => Reply::new(200, r#"{"enabled":false}"#),
    }
}

/// `GET /admin/cache`: index tier, rows, vector bytes, entry counts, and
/// the hit/miss counters — proxima's cache-inspection idiom.
fn admin_cache_stats(bridge: &Bridge) -> Reply {
    let cache = bridge.cache();
    let stats = cache.index_stats();
    let counters = &bridge.telemetry().counters;
    let body = Json::obj(vec![
        ("tier", Json::str(stats.tier)),
        ("rows", Json::num(stats.rows as f64)),
        ("trained", Json::Bool(stats.trained)),
        ("nlist", Json::num(stats.nlist as f64)),
        ("vector_bytes", Json::num(stats.vector_bytes as f64)),
        ("objects", Json::num(cache.len_objects() as f64)),
        ("keys", Json::num(cache.len_keys() as f64)),
        ("exact", Json::num(cache.len_exact() as f64)),
        (
            "exact_hits",
            Json::num(counters.get("cache_exact_hits") as f64),
        ),
        (
            "semantic_hits",
            Json::num(counters.get("cache_semantic_hits") as f64),
        ),
        ("misses", Json::num(counters.get("cache_misses") as f64)),
    ])
    .to_string();
    Reply::new(200, body)
}

/// `DELETE /admin/cache?key=<prompt>` invalidates one exact entry;
/// `DELETE /admin/cache` clears everything. Both go through the cache's
/// journaled mutation paths, so with a data dir configured the
/// invalidation is WAL-durable and survives a restart.
fn admin_cache_invalidate(bridge: &Bridge, query: Option<&str>) -> Reply {
    match query_param(query, "key") {
        Some(key) if !key.is_empty() => {
            let removed = bridge.cache().remove_exact(&key);
            bridge
                .telemetry()
                .counters
                .incr("admin_cache_invalidations");
            Reply::new(
                200,
                Json::obj(vec![("removed", Json::Bool(removed))]).to_string(),
            )
        }
        Some(_) => Reply::new(400, r#"{"error":"empty key"}"#),
        None => {
            bridge.cache().clear();
            bridge.telemetry().counters.incr("admin_cache_clears");
            Reply::new(200, r#"{"cleared":true}"#)
        }
    }
}

/// `GET /admin/breaker`: config plus every model's breaker line.
fn admin_breaker_snapshot(bridge: &Bridge) -> Reply {
    let breaker = bridge.breaker();
    let config = breaker.config();
    let models: Vec<(String, Json)> = breaker
        .snapshot()
        .into_iter()
        .map(|line| {
            (
                line.model,
                Json::obj(vec![
                    ("state", Json::str(line.state)),
                    (
                        "consecutive_failures",
                        Json::num(line.consecutive_failures as f64),
                    ),
                    ("trips", Json::num(line.trips as f64)),
                    (
                        "retry_after_secs",
                        Json::num(line.retry_after_secs as f64),
                    ),
                ]),
            )
        })
        .collect();
    let models_obj = Json::obj(
        models
            .iter()
            .map(|(name, j)| (name.as_str(), j.clone()))
            .collect(),
    );
    let body = Json::obj(vec![
        ("threshold", Json::num(config.threshold as f64)),
        (
            "cooldown_secs",
            Json::num(config.cooldown.as_secs_f64()),
        ),
        ("models", models_obj),
    ])
    .to_string();
    Reply::new(200, body)
}

/// `POST /admin/config`: staged hot-reload of the ops tunables. The new
/// config is built from the current snapshot plus the request's fields
/// and validated completely; only then is it published — one `Arc` swap
/// for the server knobs, one call for the breaker, one atomic store for
/// the model-pool `"generation"` (`"old"`/`"new"`, read once per request
/// by the router) — so no request observes a half-applied config
/// (validate → swap, the Chameleon happens-before framing). An unknown
/// field or invalid value rejects the whole request with 400 and changes
/// nothing.
fn admin_config_reload(bridge: &Bridge, state: &ServerState, body: &str) -> Reply {
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return Reply::new(400, err_body(&BridgeError::bad_request(format!("{e:#}")))),
    };
    let fields = match &j {
        Json::Obj(map) => map,
        _ => return Reply::new(400, r#"{"error":"config body must be an object"}"#),
    };

    // Stage: copy current configs, overlay request fields, validate.
    let mut ops = (*state.ops_config()).clone();
    let mut breaker = bridge.breaker().config();
    let mut generation: Option<crate::models::pricing::Generation> = None;
    for (key, value) in fields {
        let bad = |msg: &str| Reply::new(400, err_body(&BridgeError::bad_request(msg)));
        match key.as_str() {
            "shed_watermark" => match value.as_usize() {
                Some(v) if v >= 1 => ops.shed_watermark = v,
                _ => return bad("shed_watermark must be an integer >= 1"),
            },
            "rate_per_sec" => match value.as_f64() {
                Some(v) if v >= 0.0 => ops.rate_per_sec = v,
                _ => return bad("rate_per_sec must be a number >= 0"),
            },
            "rate_burst" => match value.as_f64() {
                Some(v) if v >= 1.0 => ops.rate_burst = v,
                _ => return bad("rate_burst must be a number >= 1"),
            },
            "breaker_threshold" => match value.as_usize() {
                Some(v) if v >= 1 => breaker.threshold = v as u32,
                _ => return bad("breaker_threshold must be an integer >= 1"),
            },
            "breaker_cooldown_secs" => match value.as_f64() {
                Some(v) if v > 0.0 => {
                    breaker.cooldown = Duration::from_secs_f64(v);
                }
                _ => return bad("breaker_cooldown_secs must be a number > 0"),
            },
            "generation" => match value.as_str() {
                Some("old") => generation = Some(crate::models::pricing::Generation::Old),
                Some("new") => generation = Some(crate::models::pricing::Generation::New),
                _ => return bad("generation must be \"old\" or \"new\""),
            },
            other => {
                return bad(&format!("unknown config field '{other}'"));
            }
        }
    }

    // Swap: everything validated; publish atomically per subsystem. The
    // generation swap is a single atomic store read once per request, so
    // in-flight requests finish on the pool they admitted with and no
    // response can mix old- and new-generation models.
    bridge.breaker().set_config(breaker);
    state.set_ops_config(ops.clone());
    if let Some(g) = generation {
        bridge.set_generation(g);
    }
    bridge.telemetry().counters.incr("admin_config_reloads");
    let live_generation = match bridge.generation() {
        crate::models::pricing::Generation::Old => "old",
        crate::models::pricing::Generation::New => "new",
    };
    Reply::new(
        200,
        Json::obj(vec![
            ("applied", Json::Bool(true)),
            ("shed_watermark", Json::num(ops.shed_watermark as f64)),
            ("rate_per_sec", Json::num(ops.rate_per_sec)),
            ("rate_burst", Json::num(ops.rate_burst)),
            ("breaker_threshold", Json::num(breaker.threshold as f64)),
            (
                "breaker_cooldown_secs",
                Json::num(breaker.cooldown.as_secs_f64()),
            ),
            ("generation", Json::str(live_generation)),
        ])
        .to_string(),
    )
}

fn handle_request(bridge: &Bridge, body: &str) -> Result<String, BridgeError> {
    let j = Json::parse(body).map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let req = Request::from_json(&j).map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let resp = bridge.handle(req)?;
    Ok(resp.to_json().to_string())
}

fn handle_regenerate(bridge: &Bridge, body: &str) -> Result<String, BridgeError> {
    let j = Json::parse(body).map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let id_hex = j
        .str_of("request_id")
        .map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let id = u64::from_str_radix(&id_hex, 16)
        .map_err(|_| BridgeError::bad_request(format!("bad request_id '{id_hex}'")))?;
    let st = j
        .get("service_type")
        .map(ServiceType::from_json)
        .transpose()
        .map_err(|e| BridgeError::bad_request(format!("{e:#}")))?;
    let resp = bridge.regenerate(id, st)?;
    Ok(resp.to_json().to_string())
}

/// Janitor: background maintenance off the request paths —
/// (a) semantic-cache index rebuilds (flat→IVF migration past the row
/// threshold, drift-triggered retrains; the k-means runs with no index
/// lock held), and (b) the WAL-compaction trigger (size-keyed) when a
/// data dir is configured. Cache reads are never blocked by either;
/// journaled *mutations* quiesce for a compaction capture's duration
/// (see persist module docs), which this thread pays instead of a
/// request thread. Compaction failures back off exponentially (capped at
/// 30s) so a full disk doesn't retry a gate-exclusive snapshot capture
/// 4x per second.
fn spawn_janitor(bridge: Arc<Bridge>, stop: Arc<AtomicBool>) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        // Fixed 250ms tick for index maintenance; compaction failures
        // back off via their own cooldown so a full disk never slows
        // in-memory index rebuilds.
        const TICK_MS: u64 = 250;
        let mut compact_backoff_ms: u64 = TICK_MS;
        let mut compact_cooldown_ms: u64 = 0;
        'outer: loop {
            // Sleep in short slices so stop() stays responsive.
            let mut slept = 0;
            while slept < TICK_MS {
                if stop.load(Ordering::Relaxed) {
                    break 'outer;
                }
                std::thread::sleep(Duration::from_millis(50));
                slept += 50;
            }
            bridge.maybe_rebuild_index();
            if bridge.persistence().is_none() {
                continue;
            }
            if compact_cooldown_ms > 0 {
                compact_cooldown_ms = compact_cooldown_ms.saturating_sub(TICK_MS);
                continue;
            }
            match bridge.maybe_compact() {
                Ok(_) => compact_backoff_ms = TICK_MS,
                Err(e) => {
                    compact_backoff_ms = (compact_backoff_ms * 2).min(30_000);
                    compact_cooldown_ms = compact_backoff_ms;
                    eprintln!(
                        "persist: background compaction failed \
                         (retrying in {compact_backoff_ms}ms): {e}"
                    );
                }
            }
        }
    })
}

enum Inner {
    #[cfg(target_os = "linux")]
    Evented(evloop::EventedHandle),
    Threaded(threaded::ThreadedHandle),
}

/// A running server. [`Server::stop`] shuts down gracefully: stop
/// accepting, drain, flush the WAL.
pub struct Server {
    pub addr: std::net::SocketAddr,
    /// Where the admin surface listens, when `admin_bind` was configured.
    pub admin_addr: Option<std::net::SocketAddr>,
    bridge: Arc<Bridge>,
    state: Arc<ServerState>,
    inner: Inner,
    janitor_stop: Arc<AtomicBool>,
    janitor: Option<std::thread::JoinHandle<()>>,
    /// Replication service, when configured; stopped before the WAL flush.
    sync: Option<crate::sync::SyncService>,
}

impl Server {
    /// Start with default tuning (the historical signature).
    pub fn start(bridge: Arc<Bridge>, bind: &str, workers: usize) -> Result<Server> {
        Server::start_with(
            bridge,
            bind,
            ServerConfig {
                workers,
                ..ServerConfig::default()
            },
        )
    }

    pub fn start_with(bridge: Arc<Bridge>, bind: &str, config: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let admin_listener = match &config.admin_bind {
            Some(bind) => Some(TcpListener::bind(bind)?),
            None => None,
        };
        let admin_addr = match &admin_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        let state = Arc::new(ServerState::from_config(&config));
        // Replication starts (and its listener binds) before the
        // transports so a bad --sync-port fails boot, and its status
        // handle is published before any admin request can arrive.
        let sync = match config.sync.clone() {
            Some(sync_cfg) => {
                let service = crate::sync::SyncService::start(bridge.clone(), sync_cfg)?;
                state.set_sync_handle(service.handle());
                Some(service)
            }
            None => None,
        };
        let evented = match config.backend {
            ServerBackend::Auto => cfg!(target_os = "linux"),
            ServerBackend::Evented => true,
            ServerBackend::Threaded => false,
        };
        let inner = if evented {
            #[cfg(target_os = "linux")]
            {
                Inner::Evented(evloop::start(
                    bridge.clone(),
                    listener,
                    admin_listener,
                    state.clone(),
                    config,
                )?)
            }
            #[cfg(not(target_os = "linux"))]
            {
                bail!("evented backend requires Linux (epoll); use ServerBackend::Threaded")
            }
        } else {
            Inner::Threaded(threaded::start(
                bridge.clone(),
                listener,
                admin_listener,
                state.clone(),
                config,
            )?)
        };
        let janitor_stop = Arc::new(AtomicBool::new(false));
        let janitor = Some(spawn_janitor(bridge.clone(), janitor_stop.clone()));
        Ok(Server {
            addr,
            admin_addr,
            bridge,
            state,
            inner,
            janitor_stop,
            janitor,
            sync,
        })
    }

    /// The sync listener's bound address, when replication is configured
    /// (resolves `--sync-port 0` for tests).
    pub fn sync_addr(&self) -> Option<std::net::SocketAddr> {
        self.sync.as_ref().and_then(|s| s.listen_addr())
    }

    /// Dial the configured peer and run one anti-entropy round now
    /// (deterministic quiesce for tests and the CLI).
    pub fn sync_now(&self) -> Result<crate::sync::RoundReport> {
        match &self.sync {
            Some(s) => s.run_round_now(),
            None => bail!("replication is not configured"),
        }
    }

    /// The `/ready` view, callable in-process.
    pub fn ready(&self) -> bool {
        self.state.ready()
    }

    /// Graceful shutdown: stop accepting, drain in-flight connections
    /// (deadline-bounded on the evented path), stop the janitor, and
    /// fsync the WAL so a clean exit is durable to the last write.
    pub fn stop(mut self) {
        self.state.set_draining();
        match self.inner {
            #[cfg(target_os = "linux")]
            Inner::Evented(h) => h.stop(),
            Inner::Threaded(h) => h.stop(),
        }
        self.janitor_stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.janitor.take() {
            let _ = j.join();
        }
        if let Some(mut s) = self.sync.take() {
            s.stop();
        }
        if let Some(p) = self.bridge.persistence() {
            if let Err(e) = p.sync_wal() {
                eprintln!("server: WAL flush on shutdown failed: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn http_parse_roundtrip() {
        // Loopback pair to test the parser without the full server.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_request(&mut s).unwrap()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(
            b"POST /v1/request HTTP/1.1\r\nContent-Length: 13\r\n\r\n{\"user\":\"u1\"}",
        )
        .unwrap();
        let req = h.join().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/request");
        assert_eq!(req.body, "{\"user\":\"u1\"}");
        assert!(req.keep_alive);
    }

    #[test]
    fn write_response_shape() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            write_response(&mut s, 200, r#"{"x":1}"#).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let mut buf = String::new();
        c.read_to_string(&mut buf).unwrap();
        h.join().unwrap();
        assert!(buf.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(buf.ends_with(r#"{"x":1}"#));
        assert!(buf.contains("Content-Length: 7"));
        assert!(buf.contains("Connection: close"));
    }

    #[test]
    fn render_response_keep_alive_header() {
        let ka = String::from_utf8(render_response(200, "{}", true)).unwrap();
        assert!(ka.contains("Connection: keep-alive"));
        let cl = String::from_utf8(render_response(413, "{}", false)).unwrap();
        assert!(cl.starts_with("HTTP/1.1 413 Payload Too Large"));
        assert!(cl.contains("Connection: close"));
    }

    #[test]
    fn render_reply_emits_retry_after() {
        let plain = String::from_utf8(render_reply(&Reply::new(200, "{}"), true)).unwrap();
        assert!(!plain.contains("Retry-After"));
        let shed = String::from_utf8(render_reply(
            &Reply::new(503, "{}").with_retry_after(7),
            false,
        ))
        .unwrap();
        assert!(shed.contains("Retry-After: 7\r\n"));
        assert!(shed.contains("Content-Length: 2"));
        // Header block still well-formed: one blank line before the body.
        assert!(shed.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn error_statuses_are_typed() {
        assert_eq!(
            respond(Err(BridgeError::QuotaExceeded { user: "u".into() })).status,
            429
        );
        assert_eq!(respond(Err(BridgeError::UnknownRequest(1))).status, 404);
        assert_eq!(respond(Err(BridgeError::bad_request("x"))).status, 400);
        assert_eq!(
            respond(Err(BridgeError::Internal(anyhow::anyhow!("x")))).status,
            500
        );
        // Error bodies carry the message, not a guessed substring.
        let reply = respond(Err(BridgeError::QuotaExceeded { user: "s1".into() }));
        assert!(reply.body.contains("quota exceeded for user s1"));
        assert!(reply.body.contains(r#""reason":"quota""#));
        // Breaker 503s carry reason + Retry-After.
        let open = respond(Err(BridgeError::BreakerOpen {
            model: "gpt-4o-mini".into(),
            retry_after_secs: 9,
        }));
        assert_eq!(open.status, 503);
        assert_eq!(open.retry_after, Some(9));
        assert!(open.body.contains(r#""reason":"breaker""#));
    }

    #[test]
    fn shed_reasons_are_distinct() {
        assert!(admission_shed_body().contains(r#""reason":"admission""#));
        let rate = rate_shed_reply("u1", 3);
        assert_eq!(rate.status, 429);
        assert_eq!(rate.retry_after, Some(3));
        assert!(rate.body.contains(r#""reason":"rate""#));
        assert!(rate.body.contains("u1"));
    }

    #[test]
    fn ready_reflects_draining_and_watermark() {
        let state = ServerState::new(2);
        let reply = ready_response(&state);
        assert_eq!(reply.status, 200, "{}", reply.body);
        assert!(reply.body.contains("\"restore\""));

        state.begin_dispatch();
        state.begin_dispatch();
        assert!(!state.admits());
        let reply = ready_response(&state);
        assert_eq!(reply.status, 503);
        assert!(reply.body.contains("overloaded"));

        state.end_dispatch();
        assert!(state.ready());
        state.set_draining();
        let reply = ready_response(&state);
        assert_eq!(reply.status, 503);
        assert!(reply.body.contains("draining"));
    }

    #[test]
    fn query_helpers_decode() {
        let (path, query) = split_query("/admin/cache?key=what%20is%20rust%3F&x=1");
        assert_eq!(path, "/admin/cache");
        assert_eq!(
            query_param(query, "key").as_deref(),
            Some("what is rust?")
        );
        assert_eq!(query_param(query, "x").as_deref(), Some("1"));
        assert_eq!(query_param(query, "missing"), None);
        let (path, query) = split_query("/admin/cache");
        assert_eq!(path, "/admin/cache");
        assert!(query.is_none());
        assert_eq!(percent_decode("a+b%2Bc"), "a b+c");
        // Malformed escapes pass through rather than erroring.
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%zz"), "%zz");
    }

    #[test]
    fn ops_config_swap_is_whole_snapshot() {
        let state = ServerState::new(4);
        let before = state.ops_config();
        assert_eq!(before.shed_watermark, 4);
        assert_eq!(before.rate_per_sec, 0.0);
        state.set_ops_config(OpsConfig {
            shed_watermark: 9,
            rate_per_sec: 2.5,
            rate_burst: 5.0,
        });
        // The old snapshot is unchanged (readers holding it see a
        // consistent config); a fresh load sees the new one whole.
        assert_eq!(before.shed_watermark, 4);
        let after = state.ops_config();
        assert_eq!(after.shed_watermark, 9);
        assert_eq!(after.rate_per_sec, 2.5);
        assert_eq!(after.rate_burst, 5.0);
    }

    #[test]
    fn lock_unpoisoned_recovers() {
        let m = Arc::new(Mutex::new(vec![1u32]));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        let mut g = lock_unpoisoned(&m);
        g.push(2);
        assert_eq!(*g, vec![1, 2]);
    }
}
