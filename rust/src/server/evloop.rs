//! The evented readiness loop — epoll-driven nonblocking connections
//! with HTTP/1.1 keep-alive, backpressure, and load-shedding admission
//! control. Linux-only (raw-syscall shim, [`crate::util::epoll`]); other
//! platforms fall back to the threaded path.
//!
//! # The per-connection state machine
//!
//! ```text
//!             accept (< max_conns, else 429 + close)
//!                │
//!                ▼        parser yields a request
//!          ┌──────────┐   ──────────────────────────►  admission?
//!   ┌────► │ Reading  │                                  │
//!   │      └──────────┘   interest: EPOLLIN              │ admitted: push to
//!   │            │                                       │ per-user FIFO group
//!   │            │ shed / parse error                    ▼
//!   │            │ (429 / 400/413)                ┌────────────┐
//!   │            │                                │ Dispatched │ interest: none
//!   │            │                                └────────────┘ (kernel socket
//!   │            │                                       │        buffer is the
//!   │            │              worker: route() +        │        backpressure)
//!   │            │              completion via wakeup    │
//!   │            ▼              pipe                     ▼
//!   │      ┌──────────┐ ◄────────────────────────────────┘
//!   └──────│ Writing  │   interest: EPOLLOUT (only while blocked)
//!  keep-   └──────────┘
//!  alive         │ Connection: close / peer EOF / drain
//!                ▼
//!              close
//! ```
//!
//! Invariants:
//!
//! * **One request in flight per connection.** While `Dispatched` or
//!   `Writing`, the loop reads nothing from the socket — pipelined bytes
//!   wait in the kernel buffer (TCP backpressure) or in the parser's
//!   buffer, and are consumed only after the response flushes. This is
//!   what makes keep-alive compose with the per-user FIFO serialization:
//!   a connection can never have two requests racing in the pool.
//! * **Admission before work.** A parsed request is shed inline (never
//!   dispatched, bridge pipeline untouched) when in-flight dispatches
//!   sit at the shed watermark or the user's FIFO group is at its bound
//!   (`FifoQueue::push_bounded`) — both 429 `"reason":"admission"`;
//!   when the user's token bucket is empty — 429 `"reason":"rate"` with
//!   `Retry-After`; and when a POST body to the JSON API is unparseable
//!   — 400 (`server_reject_badjson`), which previously burned a
//!   dispatch slot and a worker round-trip before failing. The
//!   connection stays open: shedding is per-request, so a well-behaved
//!   keep-alive client can retry on the same socket. The shed
//!   watermark and rate limits come from the [`ServerState`]'s
//!   hot-reloadable ops snapshot, loaded once per request.
//! * **Workers are panic-isolated.** Route handling runs under
//!   `catch_unwind`: a panicking request yields a 500 for that
//!   connection (`server_worker_panics`), the FIFO slot is acked, and
//!   the worker keeps serving. The completions mutex is taken with
//!   [`super::lock_unpoisoned`] on both sides, so even a panic at the
//!   worst point (mid-push) cannot take the loop thread down with a
//!   poisoned-lock unwrap — one bad request used to kill the server.
//! * **The admin listener shares the loop.** With `--admin-port`, a
//!   second listener (token [`TOKEN_ADMIN`]) is multiplexed by the same
//!   epoll loop; its connections are marked `admin`, exempt from
//!   `max_conns`, and answered **inline** via [`super::route_admin`] —
//!   never dispatched — so cache inspection, breaker state, and config
//!   hot-reload stay responsive exactly when the data port is shedding.
//! * **The loop never blocks — and never recurses.** Accepts, reads, and
//!   writes all run nonblocking on readiness; bridge work happens
//!   exclusively on the dispatch pool; completions return via a
//!   lock-then-wake handoff ([`crate::util::epoll::WakePipe`]). Serving
//!   a run of pipelined requests (each possibly shed inline) is a loop in
//!   `process_parsed`, not mutual recursion, so a flood of tiny pipelined
//!   requests is O(1) stack.
//! * **Deadlines are swept, not armed.** A 100ms `epoll_wait` timeout
//!   doubles as the sweep tick for keep-alive idle closes and the
//!   per-request read deadline (anti-slowloris: the clock starts at the
//!   first byte of an incomplete request and survives dribbled bytes,
//!   unlike the idle clock, which any byte resets).
//!
//! Graceful drain: on stop the listener is deregistered, idle
//! connections close immediately, dispatched/writing connections get
//! until [`super::ServerConfig::drain_deadline`] to finish, then the
//! loop force-closes the rest and joins the pool.

use std::collections::HashMap;
use std::io::Write;
use std::net::TcpListener;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Bridge;
use crate::queuing::FifoQueue;
use crate::telemetry::Telemetry;
use crate::util::epoll::{Epoll, Event, WakePipe, INTEREST_READ, INTEREST_WRITE};
use crate::util::json::Json;

use super::conn::{Conn, ConnState, FillOutcome, HttpRequest, WriteOutcome};
use super::{
    admission_shed_body, lock_unpoisoned, rate_shed_reply, render_reply, render_response,
    route_server, Reply, ServerConfig, ServerState,
};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
/// The admin listener's token (`--admin-port`), when configured.
const TOKEN_ADMIN: u64 = 2;
const FIRST_CONN_TOKEN: u64 = 3;
/// epoll_wait timeout — the sweep tick for idle/deadline reaping.
const TICK_MS: i32 = 100;

/// A fully parsed request handed to the dispatch pool.
#[derive(Clone)]
struct Job {
    token: u64,
    req: HttpRequest,
}

/// A rendered response traveling back from a worker to the loop.
struct Completion {
    token: u64,
    bytes: Vec<u8>,
    close_after: bool,
}

pub(super) struct EventedHandle {
    stop: Arc<AtomicBool>,
    wake: Arc<WakePipe>,
    join: Vec<std::thread::JoinHandle<()>>,
}

impl EventedHandle {
    pub(super) fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        self.wake.wake();
        for h in self.join {
            let _ = h.join();
        }
    }
}

pub(super) fn start(
    bridge: Arc<Bridge>,
    listener: TcpListener,
    admin_listener: Option<TcpListener>,
    state: Arc<ServerState>,
    config: ServerConfig,
) -> Result<EventedHandle> {
    listener.set_nonblocking(true)?;
    let epoll = Epoll::new()?;
    epoll.add(listener.as_raw_fd(), INTEREST_READ, TOKEN_LISTENER)?;
    let wake = Arc::new(WakePipe::new()?);
    epoll.add(wake.read_fd(), INTEREST_READ, TOKEN_WAKE)?;
    if let Some(al) = &admin_listener {
        al.set_nonblocking(true)?;
        epoll.add(al.as_raw_fd(), INTEREST_READ, TOKEN_ADMIN)?;
    }

    let stop = Arc::new(AtomicBool::new(false));
    let queue: Arc<FifoQueue<Job>> = Arc::new(FifoQueue::new());
    let completions: Arc<Mutex<Vec<Completion>>> = Arc::new(Mutex::new(Vec::new()));
    let mut join = Vec::new();

    // Dispatch pool: fully-parsed requests in, rendered responses out.
    // `pop` honors the per-user exclusive-delivery guarantee; `ack`
    // after publishing the completion keeps a user's next request
    // blocked until their previous response is on its way back.
    //
    // Route handling is panic-isolated: an unwinding handler turns into
    // a 500 for that connection, and ack/completion/wake still run —
    // the panic can neither wedge the user's FIFO group nor skip the
    // loop's wakeup.
    for _ in 0..config.workers.max(1) {
        let queue = queue.clone();
        let completions = completions.clone();
        let wake = wake.clone();
        let bridge = bridge.clone();
        let state = state.clone();
        join.push(std::thread::spawn(move || {
            while let Some(msg) = queue.pop() {
                let job = msg.payload;
                let reply = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    route_server(&bridge, &state, &job.req)
                }))
                .unwrap_or_else(|_| {
                    bridge.telemetry().counters.incr("server_worker_panics");
                    Reply::new(
                        500,
                        r#"{"error":"internal error: request handler panicked"}"#,
                    )
                });
                let close_after = !job.req.keep_alive;
                let bytes = render_reply(&reply, !close_after);
                lock_unpoisoned(&completions).push(Completion {
                    token: job.token,
                    bytes,
                    close_after,
                });
                queue.ack(msg.id, &msg.group);
                wake.wake();
            }
        }));
    }

    // The readiness loop itself.
    {
        let stop = stop.clone();
        let wake = wake.clone();
        let tele = bridge.telemetry().clone();
        join.push(std::thread::spawn(move || {
            let mut lp = Loop {
                epoll,
                listener,
                admin_listener,
                bridge,
                wake,
                queue,
                completions,
                tele,
                state,
                config,
                conns: HashMap::new(),
                next_token: FIRST_CONN_TOKEN,
                draining: false,
            };
            lp.run(&stop);
        }));
    }

    Ok(EventedHandle { stop, wake, join })
}

/// What became of a connection after a response finished (or failed).
#[derive(PartialEq)]
enum AfterWrite {
    /// Back in `Reading` — the caller may keep pulling parsed requests.
    Recycled,
    /// Parked in `Writing` (socket buffer full), dispatched, or closed —
    /// stop driving this connection for now.
    Settled,
}

struct Loop {
    epoll: Epoll,
    listener: TcpListener,
    admin_listener: Option<TcpListener>,
    bridge: Arc<Bridge>,
    wake: Arc<WakePipe>,
    queue: Arc<FifoQueue<Job>>,
    completions: Arc<Mutex<Vec<Completion>>>,
    tele: Arc<Telemetry>,
    state: Arc<ServerState>,
    config: ServerConfig,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    draining: bool,
}

impl Loop {
    fn run(&mut self, stop: &AtomicBool) {
        let mut events: Vec<Event> = Vec::new();
        let mut drain_deadline: Option<Instant> = None;
        loop {
            if self.epoll.wait(&mut events, 256, TICK_MS).is_err() {
                break;
            }
            if stop.load(Ordering::Relaxed) && !self.draining {
                self.begin_drain();
                drain_deadline = Some(Instant::now() + self.config.drain_deadline);
            }
            for &ev in &events {
                match ev.token {
                    TOKEN_LISTENER => self.accept_burst(false),
                    TOKEN_ADMIN => self.accept_burst(true),
                    TOKEN_WAKE => self.wake.drain(),
                    token => self.conn_event(token, ev),
                }
            }
            self.drain_completions();
            self.sweep_deadlines();
            if self.draining {
                let drained = self.conns.is_empty() && self.state.inflight() == 0;
                let expired = drain_deadline.is_some_and(|d| Instant::now() >= d);
                if drained || expired {
                    break;
                }
            }
        }
        // Teardown: no more dispatches, force-close the stragglers.
        self.queue.close();
        self.conns.clear();
    }

    /// Stop accepting, reap idle connections, let the pool drain what is
    /// already queued (`close` only stops blocked pops once empty).
    fn begin_drain(&mut self) {
        self.draining = true;
        self.state.set_draining();
        let _ = self.epoll.delete(self.listener.as_raw_fd());
        if let Some(al) = &self.admin_listener {
            let _ = self.epoll.delete(al.as_raw_fd());
        }
        self.queue.close();
        let idle: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.state == ConnState::Reading)
            .map(|(t, _)| *t)
            .collect();
        for t in idle {
            self.close_conn(t);
        }
    }

    fn accept_burst(&mut self, admin: bool) {
        loop {
            let accepted = if admin {
                match &self.admin_listener {
                    Some(l) => l.accept(),
                    None => return,
                }
            } else {
                self.listener.accept()
            };
            match accepted {
                Ok((stream, _)) => {
                    self.tele.counters.incr("server_accepted");
                    // Admin connections bypass `max_conns`: the point of
                    // the separate port is staying reachable exactly when
                    // the data plane is at its connection ceiling.
                    if !admin && self.conns.len() >= self.config.max_conns {
                        // Best-effort 429 so the client learns why; the
                        // socket is young, so the first write virtually
                        // always fits the send buffer.
                        self.tele.counters.incr("server_shed_conns");
                        let mut s = stream;
                        let _ = s.set_nonblocking(true);
                        let _ = s.write(&render_response(
                            429,
                            r#"{"error":"connection limit reached","reason":"admission"}"#,
                            false,
                        ));
                        continue;
                    }
                    // accept(2) does not inherit O_NONBLOCK from the
                    // listener; a socket stuck in blocking mode would
                    // stall the whole loop — drop it instead.
                    if let Err(e) = stream.set_nonblocking(true) {
                        self.tele.counters.incr("server_sock_mode_errors");
                        eprintln!(
                            "server: dropping accepted connection — \
                             cannot set nonblocking mode: {e}"
                        );
                        continue;
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    if self
                        .epoll
                        .add(stream.as_raw_fd(), INTEREST_READ, token)
                        .is_err()
                    {
                        continue;
                    }
                    let mut conn = Conn::new(stream);
                    conn.admin = admin;
                    self.conns.insert(token, conn);
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => break,
            }
        }
    }

    fn conn_event(&mut self, token: u64, ev: Event) {
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        match conn.state {
            ConnState::Reading => match conn.fill() {
                FillOutcome::Error | FillOutcome::Eof => self.close_conn(token),
                FillOutcome::Progress | FillOutcome::Idle => {
                    self.process_parsed(token);
                    // Still `Reading` after parsing everything available:
                    // an EOF (or a hangup event `fill` could not observe,
                    // e.g. EPOLLERR) means no request can ever complete
                    // here.
                    let dead = self.conns.get(&token).is_some_and(|c| {
                        c.state == ConnState::Reading && (c.peer_closed || ev.hangup)
                    });
                    if dead {
                        self.close_conn(token);
                    }
                }
            },
            ConnState::Dispatched => {
                // Interest is empty while dispatched; only RDHUP/HUP can
                // land here. Remember the EOF — the response still gets
                // a delivery attempt, then the conn closes.
                if ev.hangup {
                    conn.peer_closed = true;
                }
            }
            ConnState::Writing => {
                if ev.writable || ev.hangup {
                    self.finish_write(token);
                }
            }
        }
    }

    /// Pull complete requests out of the parser until the connection
    /// dispatches, parks, closes, or runs out of bytes. Inline responses
    /// (sheds, parse rejects) are flushed here too — iteratively, so a
    /// pipelined burst never grows the stack.
    fn process_parsed(&mut self, token: u64) {
        loop {
            let Some(conn) = self.conns.get_mut(&token) else {
                return;
            };
            if conn.state != ConnState::Reading {
                return;
            }
            match conn.parser.next() {
                Ok(Some(req)) => {
                    conn.reading_since = None;
                    if conn.served > 0 {
                        self.tele.counters.incr("server_keepalive_reuse");
                    }
                    if self.dispatch(token, req) == AfterWrite::Settled {
                        return;
                    }
                }
                Ok(None) => return,
                Err(pe) => {
                    // The byte stream is unframeable from here on:
                    // answer and always close.
                    self.tele.counters.incr("server_parse_rejects");
                    let body = Json::obj(vec![("error", Json::str(pe.to_string()))]).to_string();
                    self.write_inline(token, Reply::new(pe.http_status(), body), true);
                    return;
                }
            }
        }
    }

    /// Admission-check one parsed request: queue it (entering
    /// `Dispatched`) or shed it with an inline 429/400. Returns
    /// `Recycled` only when the connection is back in `Reading` and the
    /// caller may continue with the next pipelined request.
    fn dispatch(&mut self, token: u64, req: HttpRequest) -> AfterWrite {
        let keep_alive = req.keep_alive;
        // Admin connections are answered inline — never dispatched, never
        // admission-checked — so the control surface stays responsive
        // exactly when the data plane is shedding. The handlers are cheap
        // reads and config swaps; the one heavy case (DELETE clearing a
        // large journaled cache) briefly occupies the loop, an accepted
        // cost for keeping the surface worker-independent.
        if self.conns.get(&token).is_some_and(|c| c.admin) {
            let reply = super::route_admin(&self.bridge, &self.state, &req);
            return self.write_inline(token, reply, !keep_alive);
        }
        // Probes are answered inline by the loop — never dispatched, so
        // they stay accurate exactly when it matters: under overload
        // (when the pool would shed them) and during drain.
        if req.method == "GET" && req.path == "/health" {
            return self.write_inline(token, Reply::new(200, r#"{"status":"ok"}"#), !keep_alive);
        }
        if req.method == "GET" && req.path == "/ready" {
            let reply = super::ready_response(&self.state);
            return self.write_inline(token, reply, !keep_alive);
        }
        // One coherent ops snapshot per request: the watermark, rate, and
        // burst below all come from the same hot-reload generation.
        let ops = self.state.ops_config();
        if self.draining || !self.state.admits_under(&ops) {
            self.tele.counters.incr("server_shed_admission");
            let close = self.draining || !keep_alive;
            return self.write_inline(token, Reply::new(429, admission_shed_body()), close);
        }
        // Parse the body once: FIFO grouping, rate limiting, and the
        // bad-JSON reject all read it. A POST to the JSON API whose body
        // does not parse is rejected here — it used to burn a dispatch
        // slot and a worker round-trip before failing with the same 400.
        let parsed = Json::parse(&req.body).ok();
        if parsed.is_none()
            && req.method == "POST"
            && matches!(req.path.as_str(), "/v1/request" | "/v1/regenerate")
        {
            self.tele.counters.incr("server_reject_badjson");
            return self.write_inline(
                token,
                Reply::new(400, r#"{"error":"request body is not valid JSON"}"#),
                !keep_alive,
            );
        }
        let user = parsed.as_ref().and_then(|j| j.str_of("user").ok());
        // The token bucket gates ahead of the quota stage: a flooding
        // user is turned away before consuming a dispatch slot.
        if let Some(u) = &user {
            if let Err(retry_secs) = self.state.rate_acquire(&ops, u) {
                self.tele.counters.incr("server_shed_rate");
                return self.write_inline(token, rate_shed_reply(u, retry_secs), !keep_alive);
            }
        }
        // FIFO group = user when the body names one (per-user
        // serialization), else connection-unique (no ordering need). The
        // "d:" prefix keeps client-chosen names out of the internal
        // namespace.
        let group = user
            .map(|user| format!("d:u:{user}"))
            .unwrap_or_else(|| format!("d:a:{token}"));
        match self
            .queue
            .push_bounded(&group, Job { token, req }, self.config.per_user_queue_cap)
        {
            Ok(_) => {
                self.state.begin_dispatch();
                let conn = self.conns.get_mut(&token).expect("checked in caller");
                conn.state = ConnState::Dispatched;
                // Pause reads: pipelined bytes wait in the kernel buffer.
                let fd = conn.stream.as_raw_fd();
                let _ = self.epoll.modify(fd, 0, token);
                AfterWrite::Settled
            }
            Err(_) => {
                // This user's queue is full — per-user backpressure.
                self.tele.counters.incr("server_shed_admission");
                self.write_inline(token, Reply::new(429, admission_shed_body()), !keep_alive)
            }
        }
    }

    /// Flush a loop-generated response on a connection currently in
    /// `Reading` (interest already EPOLLIN, so a recycled connection
    /// needs no re-registration; a parked one switches to EPOLLOUT).
    fn write_inline(&mut self, token: u64, reply: Reply, close_after: bool) -> AfterWrite {
        let Some(conn) = self.conns.get_mut(&token) else {
            return AfterWrite::Settled;
        };
        let keep = !close_after;
        conn.start_write(render_reply(&reply, keep), keep);
        match conn.flush_write() {
            WriteOutcome::Done => self.after_response(token),
            WriteOutcome::Blocked => {
                let fd = conn.stream.as_raw_fd();
                let _ = self.epoll.modify(fd, INTEREST_WRITE, token);
                AfterWrite::Settled
            }
            WriteOutcome::Error => {
                self.close_conn(token);
                AfterWrite::Settled
            }
        }
    }

    /// A response finished flushing: recycle for keep-alive or close.
    /// A peer that half-closed but left complete pipelined requests
    /// buffered still gets them served; the persistent RDHUP level event
    /// reaps the connection once the parser goes idle.
    fn after_response(&mut self, token: u64) -> AfterWrite {
        let Some(conn) = self.conns.get_mut(&token) else {
            return AfterWrite::Settled;
        };
        conn.served += 1;
        let recycle = conn.keep_alive_after_write
            && !self.draining
            && (!conn.peer_closed || !conn.parser.is_idle());
        if recycle {
            conn.state = ConnState::Reading;
            // Re-arm the anti-slowloris clock for a partially-buffered
            // next request; a clean boundary starts fresh on first byte.
            conn.reading_since = if conn.parser.is_idle() {
                None
            } else {
                Some(Instant::now())
            };
            AfterWrite::Recycled
        } else {
            self.close_conn(token);
            AfterWrite::Settled
        }
    }

    /// Drive a `Writing` connection (EPOLLOUT readiness or a completion
    /// handoff). On completion, re-enters the read cycle — including any
    /// pipelined requests already buffered.
    fn finish_write(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        match conn.flush_write() {
            WriteOutcome::Done => {
                if self.after_response(token) == AfterWrite::Recycled {
                    let Some(conn) = self.conns.get_mut(&token) else {
                        return;
                    };
                    let fd = conn.stream.as_raw_fd();
                    let _ = self.epoll.modify(fd, INTEREST_READ, token);
                    self.process_parsed(token);
                }
            }
            WriteOutcome::Blocked => {
                let fd = conn.stream.as_raw_fd();
                let _ = self.epoll.modify(fd, INTEREST_WRITE, token);
            }
            WriteOutcome::Error => self.close_conn(token),
        }
    }

    /// Hand worker completions to their connections.
    fn drain_completions(&mut self) {
        let batch: Vec<Completion> = {
            let mut guard = lock_unpoisoned(&self.completions);
            std::mem::take(&mut *guard)
        };
        for c in batch {
            self.state.end_dispatch();
            let Some(conn) = self.conns.get_mut(&c.token) else {
                // Connection died while its request was in flight; the
                // response has nowhere to go.
                continue;
            };
            conn.start_write(c.bytes, !c.close_after);
            self.finish_write(c.token);
        }
    }

    /// Reap idle keep-alive connections and enforce the per-request read
    /// deadline. The two clocks differ on purpose: any byte resets
    /// `last_activity` (idle), but only a *complete* request clears
    /// `reading_since` (deadline) — a dribbler cannot stay ahead of it.
    fn sweep_deadlines(&mut self) {
        let now = Instant::now();
        let ka = self.config.keepalive_timeout;
        let rd = self.config.request_deadline;
        let expired: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.state == ConnState::Reading)
            .filter(|(_, c)| {
                let idle = now.duration_since(c.last_activity) >= ka;
                let dribbling = c
                    .reading_since
                    .is_some_and(|t| now.duration_since(t) >= rd);
                idle || dribbling
            })
            .map(|(t, _)| *t)
            .collect();
        for t in expired {
            self.tele.counters.incr("server_idle_closed");
            self.close_conn(t);
        }
    }

    fn close_conn(&mut self, token: u64) {
        // Dropping the stream closes the fd, which de-registers it from
        // epoll implicitly.
        self.conns.remove(&token);
    }
}
