//! The portable blocking-socket worker pool — the pre-evented server
//! design, kept as the non-Linux fallback (and reachable anywhere via
//! [`super::ServerBackend::Threaded`]).
//!
//! The acceptor thread only accepts: request parsing happens on the
//! workers, so one slow-writing client can never stall accepts
//! (head-of-line blocking). Each connection flows through two queue hops
//! on the same FIFO substrate — a connection-unique "raw" group while
//! unparsed, then the per-user group once the body names a user — which
//! preserves the per-user serialization guarantee exactly like the
//! evented path. Every response closes the connection (no keep-alive on
//! this path); clients that want connection reuse get it from the
//! evented loop.
//!
//! Admission control here is coarser than the evented loop's (there is
//! no connection ceiling — the thread pool itself is the bound) but the
//! same gates apply, from the same hot-reloadable ops snapshot: a parsed
//! request sheds with an admission 429 when total queued work sits at or
//! above the shed watermark, with a rate 429 + `Retry-After` when the
//! user's token bucket is empty, and with an inline 400
//! (`server_reject_badjson`) when a POST body to the JSON API is
//! unparseable — all before the dispatch hop.
//!
//! Dispatch is panic-isolated just like the evented workers: a panicking
//! route handler yields a 500 on that connection
//! (`server_worker_panics`), the in-flight gauge is released, and the
//! worker thread survives to serve the next pop.
//!
//! With `--admin-port`, a dedicated acceptor thread serves the admin
//! surface ([`super::route_admin`]) one blocking request at a time —
//! deliberately outside the worker pool, so cache inspection and config
//! hot-reload stay responsive while the data plane sheds.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::Bridge;
use crate::queuing::FifoQueue;
use crate::util::json::Json;

use super::conn::HttpRequest;
use super::{
    admission_shed_body, lock_unpoisoned, rate_shed_reply, read_request_deadline, route_server,
    write_reply, write_response, Reply, ServerConfig, ServerState,
};

/// A connection's place in the two-hop worker flow.
enum Slot {
    /// Accepted, not yet parsed (queued under a connection-unique group).
    Raw(std::net::TcpStream),
    /// Parsed, awaiting dispatch (queued under the per-user group).
    Ready(std::net::TcpStream, HttpRequest),
}

pub(super) struct ThreadedHandle {
    stop: Arc<AtomicBool>,
    join: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadedHandle {
    /// Stop accepting and drain: the acceptor closes the queue, workers
    /// finish every queued connection (bounded per connection by the
    /// read deadline), then exit.
    pub(super) fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.join {
            let _ = h.join();
        }
    }
}

pub(super) fn start(
    bridge: Arc<Bridge>,
    listener: std::net::TcpListener,
    admin_listener: Option<std::net::TcpListener>,
    state: Arc<ServerState>,
    config: ServerConfig,
) -> Result<ThreadedHandle> {
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let queue: Arc<FifoQueue<u64>> = Arc::new(FifoQueue::new());
    // Connection registry: id -> state.
    let conns: Arc<std::sync::Mutex<std::collections::HashMap<u64, Slot>>> =
        Arc::new(std::sync::Mutex::new(std::collections::HashMap::new()));
    let mut join = Vec::new();

    // Acceptor: accept, register, enqueue — never reads the socket, so
    // a client that dribbles its request bytes can't block accepts.
    {
        let stop = stop.clone();
        let queue = queue.clone();
        let conns = conns.clone();
        let tele = bridge.telemetry().clone();
        join.push(std::thread::spawn(move || {
            let mut next_id = 0u64;
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        tele.counters.incr("server_accepted");
                        // The listener is nonblocking, so accepted
                        // sockets inherit nothing predictable — workers
                        // need blocking mode. A socket we cannot switch
                        // must be dropped, never handed to a blocking
                        // worker (it would spin on EWOULDBLOCK).
                        if let Err(e) = stream.set_nonblocking(false) {
                            tele.counters.incr("server_sock_mode_errors");
                            eprintln!(
                                "server: dropping accepted connection — \
                                 cannot restore blocking mode: {e}"
                            );
                            continue;
                        }
                        // Bound response writes to unresponsive clients.
                        stream
                            .set_write_timeout(Some(std::time::Duration::from_secs(10)))
                            .ok();
                        next_id += 1;
                        lock_unpoisoned(&conns).insert(next_id, Slot::Raw(stream));
                        // Group naming doubles as scheduling policy:
                        // FifoQueue::pop scans groups in key order, so
                        // dispatch groups ("d:...") always win over
                        // parse groups ("p:...") — a flood of new
                        // connections can't starve parsed requests —
                        // and prefixing keeps client-chosen user names
                        // out of the internal namespace.
                        queue.push(&format!("p:raw-{next_id}"), next_id);
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            queue.close();
        }));
    }

    // Admin acceptor: serves the control surface inline, one blocking
    // request per connection, entirely outside the worker pool and its
    // admission gates. Handlers are cheap; a slowloris here can stall
    // only the admin plane, never data-plane dispatch.
    if let Some(al) = admin_listener {
        al.set_nonblocking(true)?;
        let stop = stop.clone();
        let bridge = bridge.clone();
        let state = state.clone();
        let deadline = config.request_deadline;
        join.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match al.accept() {
                    Ok((mut stream, _)) => {
                        if stream.set_nonblocking(false).is_err() {
                            continue;
                        }
                        stream
                            .set_write_timeout(Some(std::time::Duration::from_secs(10)))
                            .ok();
                        match read_request_deadline(
                            &mut stream,
                            Some(std::time::Instant::now() + deadline),
                        ) {
                            Ok(req) => {
                                let reply = super::route_admin(&bridge, &state, &req);
                                let _ = write_reply(&mut stream, &reply);
                            }
                            Err(_) => {
                                let _ =
                                    write_response(&mut stream, 400, r#"{"error":"bad request"}"#);
                            }
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        }));
    }

    // Workers: a raw pop parses and re-enqueues under the user group;
    // a ready pop dispatches. Raw groups are connection-unique, so
    // parsing parallelizes; ready groups serialize per user (the SQS
    // per-user exclusive-delivery guarantee).
    for _ in 0..config.workers.max(1) {
        let queue = queue.clone();
        let conns = conns.clone();
        let bridge = bridge.clone();
        let state = state.clone();
        let deadline = config.request_deadline;
        join.push(std::thread::spawn(move || {
            let tele = bridge.telemetry().clone();
            while let Some(msg) = queue.pop() {
                let entry = lock_unpoisoned(&conns).remove(&msg.payload);
                match entry {
                    Some(Slot::Raw(mut stream)) => {
                        match read_request_deadline(
                            &mut stream,
                            Some(std::time::Instant::now() + deadline),
                        ) {
                            Ok(req) => {
                                // One coherent ops snapshot per request —
                                // watermark and rate limits from the same
                                // hot-reload generation.
                                let ops = state.ops_config();
                                // Admission control: shed before the
                                // dispatch queue grows past the
                                // watermark (the bridge never sees the
                                // request).
                                if queue.len() >= ops.shed_watermark {
                                    tele.counters.incr("server_shed_admission");
                                    let _ = write_response(
                                        &mut stream,
                                        429,
                                        &admission_shed_body(),
                                    );
                                    queue.ack(msg.id, &msg.group);
                                    continue;
                                }
                                // Parse once: grouping, rate limiting,
                                // and the bad-JSON reject all read it.
                                let parsed = Json::parse(&req.body).ok();
                                if parsed.is_none()
                                    && req.method == "POST"
                                    && matches!(
                                        req.path.as_str(),
                                        "/v1/request" | "/v1/regenerate"
                                    )
                                {
                                    tele.counters.incr("server_reject_badjson");
                                    let _ = write_response(
                                        &mut stream,
                                        400,
                                        r#"{"error":"request body is not valid JSON"}"#,
                                    );
                                    queue.ack(msg.id, &msg.group);
                                    continue;
                                }
                                let user =
                                    parsed.as_ref().and_then(|j| j.str_of("user").ok());
                                if let Some(u) = &user {
                                    if let Err(secs) = state.rate_acquire(&ops, u) {
                                        tele.counters.incr("server_shed_rate");
                                        let _ = write_reply(
                                            &mut stream,
                                            &rate_shed_reply(u, secs),
                                        );
                                        queue.ack(msg.id, &msg.group);
                                        continue;
                                    }
                                }
                                // FIFO group = user when parseable,
                                // else connection-unique (no
                                // ordering need).
                                let group = user
                                    .map(|user| format!("d:u:{user}"))
                                    .unwrap_or_else(|| format!("d:a:{}", msg.payload));
                                lock_unpoisoned(&conns)
                                    .insert(msg.payload, Slot::Ready(stream, req));
                                state.begin_dispatch();
                                queue.push(&group, msg.payload);
                            }
                            Err(_) => {
                                let _ = write_response(
                                    &mut stream,
                                    400,
                                    r#"{"error":"bad request"}"#,
                                );
                            }
                        }
                    }
                    Some(Slot::Ready(mut stream, req)) => {
                        // Panic isolation: a handler that unwinds costs
                        // this request a 500, not the worker thread —
                        // and the in-flight gauge is always released.
                        let reply = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || route_server(&bridge, &state, &req),
                        ))
                        .unwrap_or_else(|_| {
                            tele.counters.incr("server_worker_panics");
                            Reply::new(
                                500,
                                r#"{"error":"internal error: request handler panicked"}"#,
                            )
                        });
                        let _ = write_reply(&mut stream, &reply);
                        state.end_dispatch();
                    }
                    None => {}
                }
                queue.ack(msg.id, &msg.group);
            }
        }));
    }

    Ok(ThreadedHandle { stop, join })
}
