//! The portable blocking-socket worker pool — the pre-evented server
//! design, kept as the non-Linux fallback (and reachable anywhere via
//! [`super::ServerBackend::Threaded`]).
//!
//! The acceptor thread only accepts: request parsing happens on the
//! workers, so one slow-writing client can never stall accepts
//! (head-of-line blocking). Each connection flows through two queue hops
//! on the same FIFO substrate — a connection-unique "raw" group while
//! unparsed, then the per-user group once the body names a user — which
//! preserves the per-user serialization guarantee exactly like the
//! evented path. Every response closes the connection (no keep-alive on
//! this path); clients that want connection reuse get it from the
//! evented loop.
//!
//! Admission control here is coarser than the evented loop's (there is
//! no connection ceiling — the thread pool itself is the bound) but the
//! same watermark applies: a parsed request sheds with an admission 429
//! when total queued work sits at or above
//! [`super::ServerConfig::shed_watermark`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::Bridge;
use crate::queuing::FifoQueue;
use crate::util::json::Json;

use super::conn::HttpRequest;
use super::{
    admission_shed_body, read_request_deadline, route_server, write_response, ServerConfig,
    ServerState,
};

/// A connection's place in the two-hop worker flow.
enum Slot {
    /// Accepted, not yet parsed (queued under a connection-unique group).
    Raw(std::net::TcpStream),
    /// Parsed, awaiting dispatch (queued under the per-user group).
    Ready(std::net::TcpStream, HttpRequest),
}

pub(super) struct ThreadedHandle {
    stop: Arc<AtomicBool>,
    join: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadedHandle {
    /// Stop accepting and drain: the acceptor closes the queue, workers
    /// finish every queued connection (bounded per connection by the
    /// read deadline), then exit.
    pub(super) fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.join {
            let _ = h.join();
        }
    }
}

pub(super) fn start(
    bridge: Arc<Bridge>,
    listener: std::net::TcpListener,
    state: Arc<ServerState>,
    config: ServerConfig,
) -> Result<ThreadedHandle> {
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let queue: Arc<FifoQueue<u64>> = Arc::new(FifoQueue::new());
    // Connection registry: id -> state.
    let conns: Arc<std::sync::Mutex<std::collections::HashMap<u64, Slot>>> =
        Arc::new(std::sync::Mutex::new(std::collections::HashMap::new()));
    let mut join = Vec::new();

    // Acceptor: accept, register, enqueue — never reads the socket, so
    // a client that dribbles its request bytes can't block accepts.
    {
        let stop = stop.clone();
        let queue = queue.clone();
        let conns = conns.clone();
        let tele = bridge.telemetry().clone();
        join.push(std::thread::spawn(move || {
            let mut next_id = 0u64;
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        tele.counters.incr("server_accepted");
                        // The listener is nonblocking, so accepted
                        // sockets inherit nothing predictable — workers
                        // need blocking mode. A socket we cannot switch
                        // must be dropped, never handed to a blocking
                        // worker (it would spin on EWOULDBLOCK).
                        if let Err(e) = stream.set_nonblocking(false) {
                            tele.counters.incr("server_sock_mode_errors");
                            eprintln!(
                                "server: dropping accepted connection — \
                                 cannot restore blocking mode: {e}"
                            );
                            continue;
                        }
                        // Bound response writes to unresponsive clients.
                        stream
                            .set_write_timeout(Some(std::time::Duration::from_secs(10)))
                            .ok();
                        next_id += 1;
                        conns.lock().unwrap().insert(next_id, Slot::Raw(stream));
                        // Group naming doubles as scheduling policy:
                        // FifoQueue::pop scans groups in key order, so
                        // dispatch groups ("d:...") always win over
                        // parse groups ("p:...") — a flood of new
                        // connections can't starve parsed requests —
                        // and prefixing keeps client-chosen user names
                        // out of the internal namespace.
                        queue.push(&format!("p:raw-{next_id}"), next_id);
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            queue.close();
        }));
    }

    // Workers: a raw pop parses and re-enqueues under the user group;
    // a ready pop dispatches. Raw groups are connection-unique, so
    // parsing parallelizes; ready groups serialize per user (the SQS
    // per-user exclusive-delivery guarantee).
    for _ in 0..config.workers.max(1) {
        let queue = queue.clone();
        let conns = conns.clone();
        let bridge = bridge.clone();
        let state = state.clone();
        let deadline = config.request_deadline;
        let watermark = config.shed_watermark;
        join.push(std::thread::spawn(move || {
            let tele = bridge.telemetry().clone();
            while let Some(msg) = queue.pop() {
                let entry = conns.lock().unwrap().remove(&msg.payload);
                match entry {
                    Some(Slot::Raw(mut stream)) => {
                        match read_request_deadline(
                            &mut stream,
                            Some(std::time::Instant::now() + deadline),
                        ) {
                            Ok(req) => {
                                // Admission control: shed before the
                                // dispatch queue grows past the
                                // watermark (the bridge never sees the
                                // request).
                                if queue.len() >= watermark {
                                    tele.counters.incr("server_shed_admission");
                                    let _ = write_response(
                                        &mut stream,
                                        429,
                                        &admission_shed_body(),
                                    );
                                } else {
                                    // FIFO group = user when parseable,
                                    // else connection-unique (no
                                    // ordering need).
                                    let group = Json::parse(&req.body)
                                        .ok()
                                        .and_then(|j| j.str_of("user").ok())
                                        .map(|user| format!("d:u:{user}"))
                                        .unwrap_or_else(|| format!("d:a:{}", msg.payload));
                                    conns
                                        .lock()
                                        .unwrap()
                                        .insert(msg.payload, Slot::Ready(stream, req));
                                    state.begin_dispatch();
                                    queue.push(&group, msg.payload);
                                }
                            }
                            Err(_) => {
                                let _ = write_response(
                                    &mut stream,
                                    400,
                                    r#"{"error":"bad request"}"#,
                                );
                            }
                        }
                    }
                    Some(Slot::Ready(mut stream, req)) => {
                        let (status, body) = route_server(&bridge, &state, &req);
                        let _ = write_response(&mut stream, status, &body);
                        state.end_dispatch();
                    }
                    None => {}
                }
                queue.ack(msg.id, &msg.group);
            }
        }));
    }

    Ok(ThreadedHandle { stop, join })
}
