//! Workload generators — the substitution for the paper's production
//! traces (DESIGN.md §Substitutions). All seeded and deterministic:
//!
//! * [`corpus`] — synthetic encyclopedia articles (the Wikipedia stand-in
//!   that populates the cache in §5.3's smart_cache experiment).
//! * [`whatsapp`] — multi-turn Q&A conversations shaped like the WhatsApp
//!   deployment (§5.1): topical templates, 30% factual queries, anaphoric
//!   follow-ups that require context, follow-up-button and regenerate
//!   events.
//! * [`classroom`] — the §5.2 REST workload: request mix 73/13/13/1 across
//!   model classes, quota-constrained.

pub mod classroom;
pub mod corpus;
pub mod whatsapp;

pub use whatsapp::{Conversation, Query, WhatsAppWorkload};
