//! Synthetic encyclopedia — the Wikipedia stand-in (§5.3 cache experiment:
//! "The cache is populated with Wikipedia articles on topics gathered from
//! our WhatsApp service usage, using the delegated PUT").
//!
//! Topics and entities mirror the deployment's reported query themes
//! (health and well-being, cultural themes, politics, sports, ...). Every
//! article is deterministic in (topic, entity) and carries numbered facts
//! so the chunker's fact extraction has real material.

use crate::util::rng::Rng;
use crate::util::seed_of;

/// The query topics §5.1 reports.
pub const TOPICS: &[&str] = &[
    "health",
    "culture",
    "politics",
    "sports",
    "technology",
    "education",
    "food",
    "travel",
];

/// Entities per topic (shared vocabulary with the WhatsApp templates so
/// cache lookups have genuine lexical overlap).
pub fn entities(topic: &str) -> &'static [&'static str] {
    match topic {
        "health" => &[
            "malaria", "diabetes", "hypertension", "vaccination", "nutrition",
            "sleep hygiene", "dehydration", "anemia",
        ],
        "culture" => &[
            "eid traditions", "henna art", "sufi music", "nubian heritage",
            "wedding customs", "calligraphy", "ramadan", "storytelling",
        ],
        "politics" => &[
            "elections", "parliament", "constitution", "local government",
            "trade policy", "census", "diplomacy", "federalism",
        ],
        "sports" => &[
            "cricket", "football", "hockey", "athletics", "squash",
            "kabaddi", "wrestling", "badminton",
        ],
        "technology" => &[
            "mobile banking", "solar power", "internet access", "smartphones",
            "artificial intelligence", "satellite internet", "e commerce",
            "digital identity",
        ],
        "education" => &[
            "literacy programs", "scholarships", "exam systems",
            "vocational training", "universities", "online courses",
            "school meals", "teacher training",
        ],
        "food" => &[
            "biryani", "ful medames", "kisra bread", "chai", "mangoes",
            "dates", "lentils", "street food",
        ],
        "travel" => &[
            "khartoum", "karachi", "lahore", "port sudan", "dubai",
            "islamabad", "meroe pyramids", "nile river",
        ],
        _ => &["general knowledge"],
    }
}

/// One synthetic article.
#[derive(Clone, Debug)]
pub struct Article {
    pub topic: String,
    pub entity: String,
    pub title: String,
    pub text: String,
}

/// Deterministic article for (topic, entity).
pub fn article(topic: &str, entity: &str) -> Article {
    let mut rng = Rng::new(seed_of(&["article", topic, entity]));
    let adjectives = [
        "notable", "important", "widely discussed", "historic", "popular",
        "well documented", "significant", "growing",
    ];
    let mut s = Vec::new();
    s.push(format!(
        "{entity} is a {adj} subject within {topic}.",
        adj = rng.choice(&adjectives)
    ));
    s.push(format!(
        "Experts estimate that {entity} affects about {n} million people every year.",
        n = 1 + rng.below(90)
    ));
    s.push(format!(
        "The earliest records of {entity} date back to {year}.",
        year = 1850 + rng.below(160)
    ));
    s.push(format!(
        "Studies show {entity} is closely linked to {other} in {topic}.",
        other = rng.choice(entities(topic))
    ));
    s.push(format!(
        "In recent surveys {pct} percent of respondents said {entity} matters to their daily life.",
        pct = 20 + rng.below(75)
    ));
    s.push(format!(
        "Community programs about {entity} reached {n} districts last year.",
        n = 3 + rng.below(40)
    ));
    s.push(format!(
        "The main challenge around {entity} is access in rural regions."
    ));
    s.push(format!(
        "Local experts recommend learning about {entity} from trusted sources."
    ));
    Article {
        topic: topic.to_string(),
        entity: entity.to_string(),
        title: format!("{entity} ({topic})"),
        text: s.join(" "),
    }
}

/// The whole corpus: one article per (topic, entity).
pub fn full_corpus() -> Vec<Article> {
    TOPICS
        .iter()
        .flat_map(|t| entities(t).iter().map(move |e| article(t, e)))
        .collect()
}

/// An FAQ-style document (exercises the chunker's QA segmentation, §5.2).
pub fn faq_document(topic: &str) -> String {
    let ents = entities(topic);
    let mut rng = Rng::new(seed_of(&["faq", topic]));
    let mut out = String::new();
    for e in ents.iter().take(4) {
        out.push_str(&format!(
            "Q: What should I know about {e}?\nA: {e} is covered in our {topic} \
             guide; about {n} percent of questions we receive concern it.\n",
            n = 5 + rng.below(40)
        ));
    }
    out
}

/// A sectioned policy-style document (header segmentation, §5.2).
pub fn policy_document(topic: &str) -> String {
    let ents = entities(topic);
    format!(
        "## Scope\nThis policy covers {topic} services including {a} and {b}.\n\
         ## Eligibility\nResidents may enroll if they are over 18 years old.\n\
         ## Review\nThe policy is reviewed every 2 years by the committee.\n",
        a = ents[0],
        b = ents[1.min(ents.len() - 1)],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn articles_deterministic() {
        let a = article("health", "malaria");
        let b = article("health", "malaria");
        assert_eq!(a.text, b.text);
        assert!(a.text.contains("malaria"));
    }

    #[test]
    fn articles_differ_across_entities() {
        assert_ne!(
            article("health", "malaria").text,
            article("health", "diabetes").text
        );
    }

    #[test]
    fn corpus_covers_all_topics() {
        let corpus = full_corpus();
        assert_eq!(corpus.len(), 64);
        for t in TOPICS {
            assert!(corpus.iter().any(|a| a.topic == *t));
        }
    }

    #[test]
    fn articles_contain_facts() {
        // Fact extraction needs digits/copulas; every article has both.
        for a in full_corpus().iter().take(10) {
            let facts = crate::cache::chunker::facts(&a.text);
            assert!(facts.len() >= 3, "{}: {:?}", a.title, facts.len());
        }
    }

    #[test]
    fn structured_documents_detected() {
        use crate::cache::chunker::{detect_structure, DocStructure};
        assert_eq!(detect_structure(&faq_document("health")), DocStructure::Faq);
        assert_eq!(
            detect_structure(&policy_document("education")),
            DocStructure::Sectioned
        );
    }
}
