//! Classroom workload generator (§5.2): ~60 students across three courses,
//! 75K requests over 145 days (~500/day), model mix 73% GPT-4o-mini, 13%
//! Claude Haiku, 13% Llama-3, 1% Phi-3, with per-student token quotas.
//!
//! Also reproduces the §5.2 observation that prompts sent to Phi-3 are
//! structured/imperative while 4o-mini/Haiku prompts are conversational
//! (the chi-squared prompt-style association).

use crate::models::pricing::ModelId;
use crate::models::quality::QueryTraits;
use crate::util::rng::Rng;
use crate::util::seed_of;

#[derive(Clone, Debug)]
pub struct ClassroomRequest {
    pub student: String,
    pub course: &'static str,
    pub day: u32,
    pub model: ModelId,
    pub prompt: String,
    pub traits: QueryTraits,
    /// Style tag for the prompt-style association analysis.
    pub style: PromptStyle,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PromptStyle {
    /// Rule-based, imperative, command grammar (Phi-3-bound prompts).
    Imperative,
    /// Softer, collaborative phrasing (4o-mini / Haiku-bound prompts).
    Conversational,
}

pub const COURSES: &[&str] = &["web-accessibility", "multi-agent-systems", "social-good-chatbots"];

const IMPERATIVE_TEMPLATES: &[&str] = &[
    "extract all dates from the following text and return json",
    "classify this message as positive or negative only",
    "list exactly three bullet points about {t}",
    "output the parsed schema for the form fields",
    "return yes or no is this page accessible",
];

const CONVERSATIONAL_TEMPLATES: &[&str] = &[
    "could you help me make this paragraph about {t} friendlier",
    "i am building a chatbot for {t} what would you suggest",
    "can we brainstorm ideas to improve {t} together",
    "please review my plan for the {t} project when you can",
    "what do you think would make {t} more useful for users",
];

const PROJECT_TOPICS: &[&str] = &[
    "screen readers",
    "campus navigation",
    "food bank matching",
    "reasoning agents",
    "course faq bots",
    "volunteer scheduling",
];

/// Sample the §5.2 model mix: 73/13/13/1.
pub fn sample_model(rng: &mut Rng) -> ModelId {
    let x = rng.f64();
    if x < 0.73 {
        ModelId::Gpt4oMini
    } else if x < 0.86 {
        ModelId::Claude3Haiku
    } else if x < 0.99 {
        ModelId::Llama38b
    } else {
        ModelId::Phi3Mini
    }
}

/// Generate `n` classroom requests across `students` students and `days`
/// days. Deterministic in seed.
pub fn generate(seed: u64, students: usize, days: u32, n: usize) -> Vec<ClassroomRequest> {
    let mut rng = Rng::new(seed ^ seed_of(&["classroom"]));
    (0..n)
        .map(|i| {
            let s = rng.below(students);
            let course = *rng.choice(COURSES);
            let model = sample_model(&mut rng);
            // Prompt style correlates with the target model (§5.2): Phi-3
            // gets imperative prompts; larger models conversational ones,
            // with some mixing.
            let imperative = match model {
                ModelId::Phi3Mini => rng.chance(0.85),
                ModelId::Llama38b => rng.chance(0.45),
                _ => rng.chance(0.20),
            };
            let topic = *rng.choice(PROJECT_TOPICS);
            let (style, template) = if imperative {
                (PromptStyle::Imperative, *rng.choice(IMPERATIVE_TEMPLATES))
            } else {
                (
                    PromptStyle::Conversational,
                    *rng.choice(CONVERSATIONAL_TEMPLATES),
                )
            };
            let prompt = template.replace("{t}", topic);
            ClassroomRequest {
                student: format!("student-{s:02}"),
                course,
                day: rng.below(days as usize) as u32,
                model,
                traits: QueryTraits {
                    id: format!("class-{i:05}"),
                    difficulty: rng.normal_ms(0.4, 0.15).clamp(0.05, 0.9),
                    factual: rng.chance(0.2),
                    requires_context: false,
                },
                prompt,
                style,
            }
        })
        .collect()
}

/// Per-student quota (§5.2 usage-based service type).
#[derive(Clone, Copy, Debug)]
pub struct Quota {
    pub max_requests: u64,
    pub max_input_tokens: u64,
    pub max_output_tokens: u64,
}

impl Default for Quota {
    fn default() -> Self {
        Quota {
            max_requests: 2_000,
            max_input_tokens: 400_000,
            max_output_tokens: 100_000,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_mix_matches_paper() {
        let reqs = generate(1, 60, 145, 8000);
        let frac = |m: ModelId| {
            reqs.iter().filter(|r| r.model == m).count() as f64 / reqs.len() as f64
        };
        assert!((0.70..=0.76).contains(&frac(ModelId::Gpt4oMini)));
        assert!((0.10..=0.16).contains(&frac(ModelId::Claude3Haiku)));
        assert!((0.10..=0.16).contains(&frac(ModelId::Llama38b)));
        assert!(frac(ModelId::Phi3Mini) <= 0.03);
    }

    #[test]
    fn prompt_style_association() {
        // The §5.2 chi-squared association: Phi-3 prompts skew imperative.
        let reqs = generate(2, 60, 145, 20000);
        let imp_frac = |m: ModelId| {
            let of_model: Vec<_> = reqs.iter().filter(|r| r.model == m).collect();
            of_model
                .iter()
                .filter(|r| r.style == PromptStyle::Imperative)
                .count() as f64
                / of_model.len().max(1) as f64
        };
        assert!(imp_frac(ModelId::Phi3Mini) > 0.7);
        assert!(imp_frac(ModelId::Gpt4oMini) < 0.3);
    }

    #[test]
    fn deterministic() {
        let a = generate(3, 10, 30, 100);
        let b = generate(3, 10, 30, 100);
        assert_eq!(a[50].prompt, b[50].prompt);
        assert_eq!(a[50].model, b[50].model);
    }

    #[test]
    fn covers_courses_and_days() {
        let reqs = generate(4, 60, 145, 5000);
        for c in COURSES {
            assert!(reqs.iter().any(|r| r.course == *c));
        }
        let max_day = reqs.iter().map(|r| r.day).max().unwrap();
        assert!(max_day >= 140);
    }
}
