//! WhatsApp Q&A workload generator — the substitution for the paper's
//! production trace (§5.1: 100+ users, 14.7K requests; §5.3's dataset D:
//! "10 conversations ... with > 10 messages in each conversation. In total
//! there are 244 queries").
//!
//! Conversations mix standalone topical questions with anaphoric follow-ups
//! that genuinely require context; ~30% of queries are factual (the
//! fraction §5.3 reports). Every query carries its latent
//! [`QueryTraits`] so the quality model can score any strategy's responses.

use crate::models::quality::QueryTraits;
use crate::util::rng::Rng;
use crate::util::seed_of;

use super::corpus::{entities, TOPICS};

/// One user query within a conversation.
#[derive(Clone, Debug)]
pub struct Query {
    pub text: String,
    pub traits: QueryTraits,
    pub topic: String,
    pub entity: String,
    /// True when the surface form is an anaphoric follow-up.
    pub is_followup: bool,
}

/// A multi-turn conversation of one user.
#[derive(Clone, Debug)]
pub struct Conversation {
    pub user: String,
    pub id: String,
    pub queries: Vec<Query>,
}

const STANDALONE_TEMPLATES: &[&str] = &[
    "tell me about {e} and why people in my community talk about it so much",
    "what is {e} exactly and what should an ordinary person understand about it",
    "give me practical advice on {e} that i can actually use this week",
    "what are the main benefits of {e} for a family like mine back home",
    "how common is {e} these days and is it becoming more or less popular",
    "what should i know about {e} before discussing it with my relatives",
    "please explain {e} in simple words that someone without schooling can follow",
    "is {e} important for families with young children and elderly parents at home",
    "what do doctors and experts usually say about {e} in recent years",
    "can you share some useful tips about {e} for people on a budget",
];

const FACTUAL_TEMPLATES: &[&str] = &[
    "how many people are affected by {e} every year according to recent estimates",
    "when did {e} start and what year do the earliest records come from",
    "what percent of people say {e} matters to their daily life in surveys",
    "how many districts were reached by community programs about {e} last year",
    "what are the documented numbers and facts about {e} that i can trust",
];

const FOLLOWUP_TEMPLATES: &[&str] = &[
    "tell me more about that please it sounds interesting and important to me",
    "what about for children and older people does the same advice apply there",
    "why is that the case and who decided it should work that way",
    "and what about in rural areas far from the big cities and hospitals",
    "can you explain that part again more slowly with a simple example please",
    "what about the history behind it how did things get to this point",
    "how does that compare with other countries in the region or elsewhere abroad",
    "is that still true today or have things changed in the last years",
];

/// Generate one conversation with `n` queries, deterministic in
/// (seed, user index).
pub fn conversation(seed: u64, user_idx: usize, n: usize) -> Conversation {
    let mut rng = Rng::new(seed ^ seed_of(&["conv", &user_idx.to_string()]));
    let user = format!("user-{user_idx:03}");
    let conv_id = format!("conv-{user_idx:03}");
    let mut queries = Vec::with_capacity(n);
    let mut topic = rng.choice(TOPICS).to_string();
    let mut entity = rng.choice(entities(&topic)).to_string();
    for i in 0..n {
        // Topic drift: occasionally switch subject entirely.
        let follow_up = i > 0 && rng.chance(0.30);
        if !follow_up {
            if rng.chance(0.4) {
                topic = rng.choice(TOPICS).to_string();
            }
            entity = rng.choice(entities(&topic)).to_string();
        }
        let factual = rng.chance(0.30);
        let text = if follow_up {
            rng.choice(FOLLOWUP_TEMPLATES).to_string()
        } else if factual {
            rng.choice(FACTUAL_TEMPLATES).replace("{e}", &entity)
        } else {
            rng.choice(STANDALONE_TEMPLATES).replace("{e}", &entity)
        };
        let difficulty = rng.normal_ms(0.45, 0.18).clamp(0.05, 0.95);
        queries.push(Query {
            traits: QueryTraits {
                id: format!("{conv_id}-q{i:03}"),
                difficulty,
                factual,
                requires_context: follow_up,
            },
            text,
            topic: topic.clone(),
            entity: entity.clone(),
            is_followup: follow_up,
        });
    }
    Conversation {
        user,
        id: conv_id,
        queries,
    }
}

/// The §5.3 evaluation dataset D: 10 conversations, >10 messages each,
/// 244 queries total.
pub fn dataset_d(seed: u64) -> Vec<Conversation> {
    let sizes = [25, 25, 25, 25, 24, 24, 24, 24, 24, 24];
    sizes
        .iter()
        .enumerate()
        .map(|(i, &n)| conversation(seed, i, n))
        .collect()
}

/// The §5.3 cache-experiment set: "170 queries across 17 user
/// conversations ... the last 10 requests per user".
pub fn cache_dataset(seed: u64) -> Vec<Conversation> {
    (0..17).map(|i| conversation(seed ^ 0xCAFE, 100 + i, 10)).collect()
}

/// A long single conversation (Fig 1: "a 50 query conversation").
pub fn fig1_conversation(seed: u64) -> Conversation {
    conversation(seed ^ 0xF161, 500, 50)
}

/// Full-deployment event stream for the e2e example.
#[derive(Clone, Debug)]
pub enum Event {
    /// Free-form user query.
    Ask { conv: usize, query: Query },
    /// User pressed a prefetched follow-up button (13% of interactions).
    Button { conv: usize, prompt: String },
    /// User pressed "Get Better Answer" (regenerate).
    Regenerate { conv: usize },
}

pub struct WhatsAppWorkload {
    pub conversations: Vec<Conversation>,
    pub events: Vec<Event>,
}

impl WhatsAppWorkload {
    /// An event mix matching the §5.1 interaction shares: ~13% cached
    /// button presses, a few percent regenerations, rest free-form asks.
    pub fn generate(seed: u64, users: usize, events_per_user: usize) -> WhatsAppWorkload {
        let mut rng = Rng::new(seed);
        let conversations: Vec<Conversation> = (0..users)
            .map(|u| conversation(seed, u, events_per_user))
            .collect();
        let mut events = Vec::new();
        for (ci, conv) in conversations.iter().enumerate() {
            for q in conv.queries.iter() {
                events.push(Event::Ask {
                    conv: ci,
                    query: q.clone(),
                });
                if rng.chance(0.13) {
                    events.push(Event::Button {
                        conv: ci,
                        prompt: format!("more about {}", q.entity),
                    });
                }
                if rng.chance(0.05) {
                    events.push(Event::Regenerate { conv: ci });
                }
            }
        }
        WhatsAppWorkload {
            conversations,
            events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_d_has_244_queries() {
        let d = dataset_d(1);
        assert_eq!(d.len(), 10);
        let total: usize = d.iter().map(|c| c.queries.len()).sum();
        assert_eq!(total, 244);
        assert!(d.iter().all(|c| c.queries.len() > 10));
    }

    #[test]
    fn deterministic() {
        let a = dataset_d(7);
        let b = dataset_d(7);
        assert_eq!(a[3].queries[5].text, b[3].queries[5].text);
        assert_eq!(
            a[3].queries[5].traits.difficulty,
            b[3].queries[5].traits.difficulty
        );
        let c = dataset_d(8);
        assert_ne!(a[3].queries[5].traits.difficulty, c[3].queries[5].traits.difficulty);
    }

    #[test]
    fn factual_fraction_near_30pct() {
        let d = dataset_d(2);
        let all: Vec<&Query> = d.iter().flat_map(|c| c.queries.iter()).collect();
        let f = all.iter().filter(|q| q.traits.factual).count() as f64 / all.len() as f64;
        assert!((0.2..=0.4).contains(&f), "factual fraction {f}");
    }

    #[test]
    fn followups_require_context() {
        let d = dataset_d(3);
        for c in &d {
            assert!(!c.queries[0].is_followup, "first query can't follow up");
            for q in &c.queries {
                assert_eq!(q.is_followup, q.traits.requires_context);
                if q.is_followup {
                    assert!(!q.text.contains("{e}"));
                }
            }
        }
    }

    #[test]
    fn templates_fill_entity() {
        let d = dataset_d(4);
        for c in &d {
            for q in &c.queries {
                assert!(!q.text.contains("{e}"), "unfilled template: {}", q.text);
            }
        }
    }

    #[test]
    fn event_mix_shares() {
        let w = WhatsAppWorkload::generate(5, 20, 20);
        let total = w.events.len() as f64;
        let buttons = w
            .events
            .iter()
            .filter(|e| matches!(e, Event::Button { .. }))
            .count() as f64;
        assert!((0.06..=0.18).contains(&(buttons / total)), "button share");
    }

    #[test]
    fn fig1_conversation_is_50_queries() {
        assert_eq!(fig1_conversation(1).queries.len(), 50);
    }

    #[test]
    fn cache_dataset_shape() {
        let cd = cache_dataset(1);
        assert_eq!(cd.len(), 17);
        assert!(cd.iter().all(|c| c.queries.len() == 10));
        let total: usize = cd.iter().map(|c| c.queries.len()).sum();
        assert_eq!(total, 170);
    }
}
