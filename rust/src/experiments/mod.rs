//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§2.2 Fig 1, §5.3 Figs 4-7) by replaying the synthetic
//! production workloads through the real proxy pipeline.
//!
//! Used by the `figures` binary (prints the rows/series the paper reports),
//! the `table_*` benches, and `rust/tests/paper_shapes.rs` (asserts the
//! paper's qualitative claims: who wins, by roughly what factor, where the
//! crossovers fall).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::api::{CachePolicy, Request, ServiceType};
use crate::coordinator::{Bridge, BridgeConfig};
use crate::models::judge::Judge;
use crate::models::pricing::{Generation, ModelId};
use crate::util::rng::Rng;
use crate::util::seed_of;
use crate::workload::whatsapp::{self, Conversation};

pub const DEFAULT_SEED: u64 = 20240711;

/// Per-query record of one strategy replay.
#[derive(Clone, Debug)]
pub struct QueryRecord {
    pub query_id: String,
    pub text: String,
    pub response: String,
    pub latent: f64,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub cost_usd: f64,
    pub llm_ms: f64,
    pub context_llm_ms: f64,
    pub context_messages: usize,
    pub escalated: bool,
    pub grounded: bool,
    pub cache_hit: bool,
}

/// A full strategy replay over a set of conversations.
#[derive(Clone, Debug)]
pub struct StrategyRun {
    pub name: String,
    pub records: Vec<QueryRecord>,
}

impl StrategyRun {
    pub fn total_cost(&self) -> f64 {
        self.records.iter().map(|r| r.cost_usd).sum()
    }

    pub fn total_input_tokens(&self) -> u64 {
        self.records.iter().map(|r| r.input_tokens).sum()
    }

    pub fn total_llm_ms(&self) -> f64 {
        self.records.iter().map(|r| r.llm_ms).sum()
    }

    pub fn escalation_fraction(&self) -> f64 {
        self.records.iter().filter(|r| r.escalated).count() as f64
            / self.records.len().max(1) as f64
    }
}

/// Which model the replay should route a query to (replay-level strategy).
#[derive(Clone, Debug)]
pub enum Strategy {
    /// The §3.3 verification cascade.
    Verification {
        t: f64,
        m1: ModelId,
        m2: ModelId,
        verifier: ModelId,
    },
    /// Random M2 with probability p (the §5.3 baseline).
    Random { p: f64, m1: ModelId, m2: ModelId },
    /// A single model with last-k context.
    FixedModel { model: ModelId, k: usize },
    /// SmartContext service type with answer-model per generation.
    SmartContext { k: usize },
    /// SmartCache with the given local model.
    SmartCache { model: ModelId },
}

impl Strategy {
    pub fn label(&self) -> String {
        match self {
            Strategy::Verification { t, .. } => format!("verification(t={t})"),
            Strategy::Random { p, .. } => format!("random(p={p})"),
            Strategy::FixedModel { model, k } => format!("{model}(k={k})"),
            Strategy::SmartContext { k } => format!("smart_context(k={k})"),
            Strategy::SmartCache { model } => format!("smart_cache({model})"),
        }
    }

    fn service_type(&self, query_id: &str) -> ServiceType {
        match self {
            Strategy::Verification { t, m1, m2, verifier } => ServiceType::ModelSelector {
                threshold: *t,
                m1: Some(*m1),
                m2: Some(*m2),
                verifier: Some(*verifier),
            },
            Strategy::Random { p, m1, m2 } => {
                let mut rng = Rng::new(seed_of(&["random-route", query_id, &format!("{p:.3}")]));
                let model = if rng.chance(*p) { *m2 } else { *m1 };
                ServiceType::Fixed {
                    model,
                    cache: CachePolicy::Skip,
                    context_k: 5,
                }
            }
            Strategy::FixedModel { model, k } => ServiceType::Fixed {
                model: *model,
                cache: CachePolicy::Skip,
                context_k: *k,
            },
            Strategy::SmartContext { k } => ServiceType::SmartContext {
                k: *k,
                model: ModelId::Claude3Haiku,
            },
            Strategy::SmartCache { model } => ServiceType::SmartCache { model: *model },
        }
    }

    fn is_escalation(&self, models_used: &[(String, String)]) -> bool {
        match self {
            Strategy::Verification { .. } => models_used.iter().any(|(_, r)| r == "m2"),
            Strategy::Random { m2, .. } => {
                models_used.iter().any(|(m, _)| m == m2.as_str())
            }
            _ => false,
        }
    }
}

/// Replay `convs` through `bridge` under `strategy`. Conversation ids are
/// suffixed with the strategy label so histories don't cross-contaminate
/// when one bridge hosts several replays (sharing the completion memo).
pub fn replay(
    bridge: &Bridge,
    convs: &[Conversation],
    strategy: &Strategy,
    limit: Option<usize>,
) -> Result<StrategyRun> {
    let mut records = Vec::new();
    let suffix = crate::util::fnv1a(strategy.label().as_bytes());
    'outer: for conv in convs {
        let conv_id = format!("{}-{suffix:08x}", conv.id);
        bridge.clear_history(&conv.user, &conv_id);
        for q in &conv.queries {
            if let Some(l) = limit {
                if records.len() >= l {
                    break 'outer;
                }
            }
            let req = Request::new(&conv.user, &conv_id, &q.text)
                .service_type(strategy.service_type(&q.traits.id))
                .with_traits(q.traits.clone());
            let resp = bridge.handle(req)?;
            records.push(QueryRecord {
                query_id: q.traits.id.clone(),
                text: q.text.clone(),
                response: resp.text,
                latent: resp.metadata.latent_quality,
                input_tokens: resp.metadata.input_tokens,
                output_tokens: resp.metadata.output_tokens,
                cost_usd: resp.metadata.cost_usd,
                llm_ms: resp.metadata.llm_ms,
                context_llm_ms: resp.metadata.context_llm_ms,
                context_messages: resp.metadata.context_messages,
                escalated: strategy.is_escalation(&resp.metadata.models_used),
                grounded: resp.metadata.grounded,
                cache_hit: matches!(
                    resp.metadata.cache,
                    crate::api::CacheOutcome::SemanticHit { .. }
                        | crate::api::CacheOutcome::ExactHit
                ),
            });
        }
    }
    Ok(StrategyRun {
        name: strategy.label(),
        records,
    })
}

/// Judge every record of `run` against the aligned `reference` run.
/// Returns scores in query order (the paper's 0-10 scale, reference = 10).
pub fn judge_scores(judge: &Judge, run: &StrategyRun, reference: &StrategyRun) -> Result<Vec<f64>> {
    let by_id: BTreeMap<&str, &QueryRecord> = reference
        .records
        .iter()
        .map(|r| (r.query_id.as_str(), r))
        .collect();
    let mut out = Vec::with_capacity(run.records.len());
    for r in &run.records {
        let Some(reference) = by_id.get(r.query_id.as_str()) else {
            continue;
        };
        out.push(judge.score(
            &r.query_id,
            &r.response,
            r.latent,
            &reference.response,
            reference.latent,
        )?);
    }
    Ok(out)
}

/// CDF helper: sorted scores plus selected percentiles.
pub fn percentiles(mut xs: Vec<f64>, ps: &[f64]) -> Vec<(f64, f64)> {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ps.iter()
        .map(|&p| {
            if xs.is_empty() {
                return (p, f64::NAN);
            }
            let idx = ((xs.len() as f64 - 1.0) * p).round() as usize;
            (p, xs[idx])
        })
        .collect()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

// ===================================================================
// Fig 1: context growth (cost) and quality vs last-k
// ===================================================================

pub struct Fig1Row {
    pub k: usize,
    pub input_tokens: u64,
    pub cost_usd: f64,
    pub quality_scores: Vec<f64>,
}

/// Fig 1a/1b: a 50-query conversation replayed at k = 0,1,5,10,50.
/// Reference for quality is k=50 (paper: "judged against using full
/// context").
pub fn fig1(bridge: &Bridge, seed: u64, limit: Option<usize>) -> Result<Vec<Fig1Row>> {
    let conv = whatsapp::fig1_conversation(seed);
    let convs = vec![conv];
    let model = answer_model(bridge.generation());
    let ks = [0usize, 1, 5, 10, 50];
    let mut runs = Vec::new();
    for &k in &ks {
        runs.push(replay(
            bridge,
            &convs,
            &Strategy::FixedModel { model, k },
            limit,
        )?);
    }
    let judge = Judge::new(bridge.engine().clone());
    let reference = runs.last().unwrap().clone();
    let mut rows = Vec::new();
    for (i, &k) in ks.iter().enumerate() {
        let scores = judge_scores(&judge, &runs[i], &reference)?;
        rows.push(Fig1Row {
            k,
            input_tokens: runs[i].total_input_tokens(),
            cost_usd: runs[i].total_cost(),
            quality_scores: scores,
        });
    }
    Ok(rows)
}

// ===================================================================
// Figs 4 & 5: model selection (quality, cost, time)
// ===================================================================

pub struct Fig45Output {
    pub generation: Generation,
    /// (strategy label, judge-score CDF data).
    pub quality: Vec<(String, Vec<f64>)>,
    /// (strategy label, total cost normalized to M1-only = 1).
    pub cost: Vec<(String, f64)>,
    /// (strategy label, total LLM time normalized to M1-only = 1).
    pub time: Vec<(String, f64)>,
    /// Fraction of prompts the cascade routed to M2.
    pub escalation_fraction: f64,
}

/// Paper §5.3 model-selection setups.
pub fn fig45_models(generation: Generation) -> (ModelId, ModelId, ModelId) {
    match generation {
        // "M1 = GPT3.5, M2 = GPT4, Claude Opus as verifier".
        Generation::Old => (ModelId::Gpt35Turbo, ModelId::Gpt4, ModelId::Claude3Opus),
        // "GPT4o-mini as M1 and GPT4o as M2 and the verifier".
        Generation::New => (ModelId::Gpt4oMini, ModelId::Gpt4o, ModelId::Gpt4o),
    }
}

fn answer_model(generation: Generation) -> ModelId {
    match generation {
        Generation::Old => ModelId::Gpt4,
        Generation::New => ModelId::Gpt4o,
    }
}

/// Figs 4a/4b + 5a/5b. `p_random` follows the paper: the measured cascade
/// escalation fraction and 0.1 as the lower-cost alternative.
pub fn fig45(
    bridge: &Bridge,
    seed: u64,
    generation: Generation,
    limit: Option<usize>,
) -> Result<Fig45Output> {
    assert_eq!(bridge.generation(), generation, "bridge generation");
    let convs = whatsapp::dataset_d(seed);
    let (m1, m2, verifier) = fig45_models(generation);

    let verify = replay(
        bridge,
        &convs,
        &Strategy::Verification { t: 8.0, m1, m2, verifier },
        limit,
    )?;
    let esc = verify.escalation_fraction();
    let m1_only = replay(bridge, &convs, &Strategy::FixedModel { model: m1, k: 5 }, limit)?;
    let m2_only = replay(bridge, &convs, &Strategy::FixedModel { model: m2, k: 5 }, limit)?;
    // Random baselines: p = measured escalation fraction (rounded as the
    // paper does) and p = 0.1.
    let p_high = (esc * 100.0).round() / 100.0;
    let rand_high = replay(
        bridge,
        &convs,
        &Strategy::Random { p: p_high, m1, m2 },
        limit,
    )?;
    let rand_low = replay(bridge, &convs, &Strategy::Random { p: 0.1, m1, m2 }, limit)?;

    let judge = Judge::new(bridge.engine().clone());
    let mut quality = Vec::new();
    for run in [&verify, &rand_high, &rand_low, &m1_only] {
        quality.push((run.name.clone(), judge_scores(&judge, run, &m2_only)?));
    }

    let base_cost = m1_only.total_cost();
    let base_time = m1_only.total_llm_ms();
    let cost = vec![
        (m1_only.name.clone(), 1.0),
        (verify.name.clone(), verify.total_cost() / base_cost),
        (rand_high.name.clone(), rand_high.total_cost() / base_cost),
        (rand_low.name.clone(), rand_low.total_cost() / base_cost),
        (m2_only.name.clone(), m2_only.total_cost() / base_cost),
    ];
    let time = vec![
        (m1_only.name.clone(), 1.0),
        (verify.name.clone(), verify.total_llm_ms() / base_time),
        (rand_high.name.clone(), rand_high.total_llm_ms() / base_time),
        (rand_low.name.clone(), rand_low.total_llm_ms() / base_time),
        (m2_only.name.clone(), m2_only.total_llm_ms() / base_time),
    ];
    Ok(Fig45Output {
        generation,
        quality,
        cost,
        time,
        escalation_fraction: esc,
    })
}

// ===================================================================
// Fig 6: SmartContext (cost, quality, decision-time share)
// ===================================================================

pub struct Fig6Output {
    /// (strategy, total cost normalized so the cheapest = 1).
    pub cost: Vec<(String, f64)>,
    /// (strategy, judge scores vs LastK(5) reference).
    pub quality: Vec<(String, Vec<f64>)>,
    /// Per-message fraction of LLM time spent on the SmartContext call,
    /// for smart-k1 and smart-k5.
    pub decision_time_fraction: Vec<(String, Vec<f64>)>,
}

pub fn fig6(bridge: &Bridge, seed: u64, limit: Option<usize>) -> Result<Fig6Output> {
    let convs = whatsapp::dataset_d(seed);
    let model = answer_model(bridge.generation());
    let k0 = replay(bridge, &convs, &Strategy::FixedModel { model, k: 0 }, limit)?;
    let k1 = replay(bridge, &convs, &Strategy::FixedModel { model, k: 1 }, limit)?;
    let k5 = replay(bridge, &convs, &Strategy::FixedModel { model, k: 5 }, limit)?;
    let s1 = replay(bridge, &convs, &Strategy::SmartContext { k: 1 }, limit)?;
    let s5 = replay(bridge, &convs, &Strategy::SmartContext { k: 5 }, limit)?;

    let judge = Judge::new(bridge.engine().clone());
    let mut quality = Vec::new();
    for run in [&k0, &k1, &s1, &s5] {
        quality.push((run.name.clone(), judge_scores(&judge, run, &k5)?));
    }

    let runs = [&k0, &k1, &k5, &s1, &s5];
    let min_cost = runs
        .iter()
        .map(|r| r.total_cost())
        .fold(f64::INFINITY, f64::min);
    let cost = runs
        .iter()
        .map(|r| (r.name.clone(), r.total_cost() / min_cost))
        .collect();

    let decision_time_fraction = [&s1, &s5]
        .iter()
        .map(|r| {
            (
                r.name.clone(),
                r.records
                    .iter()
                    .filter(|q| q.llm_ms > 0.0)
                    .map(|q| q.context_llm_ms / q.llm_ms)
                    .collect(),
            )
        })
        .collect();

    Ok(Fig6Output {
        cost,
        quality,
        decision_time_fraction,
    })
}

// ===================================================================
// Fig 7: SmartCache (grounded quality on factual queries)
// ===================================================================

pub struct Fig7Output {
    /// (strategy, judge scores vs Sonar reference) over factual queries.
    pub quality: Vec<(String, Vec<f64>)>,
    /// Same, restricted to queries where smart_cache used the cache.
    pub cache_used_quality: Vec<(String, Vec<f64>)>,
    pub n_factual: usize,
    pub n_cache_used: usize,
}

pub fn fig7(bridge: &Bridge, seed: u64, limit: Option<usize>) -> Result<Fig7Output> {
    // Populate the cache with corpus articles via delegated PUT (§5.3).
    bridge.cache().clear();
    for article in crate::workload::corpus::full_corpus() {
        bridge.cache().put_delegated(
            bridge.generator(),
            ModelId::Phi3Mini,
            &article.title,
            &article.text,
        )?;
    }
    // 170 queries / 17 conversations; keep the factual ones (~30%).
    let mut convs = whatsapp::cache_dataset(seed);
    for c in &mut convs {
        c.queries.retain(|q| q.traits.factual && !q.traits.requires_context);
    }
    let smart = replay(
        bridge,
        &convs,
        &Strategy::SmartCache { model: ModelId::Phi3Mini },
        limit,
    )?;
    let gpt4o = replay(
        bridge,
        &convs,
        &Strategy::FixedModel { model: ModelId::Gpt4o, k: 0 },
        limit,
    )?;
    let phi = replay(
        bridge,
        &convs,
        &Strategy::FixedModel { model: ModelId::Phi3Mini, k: 0 },
        limit,
    )?;
    // Reference: Sonar-Huge-Online (internet-grounded).
    let sonar = replay(
        bridge,
        &convs,
        &Strategy::FixedModel { model: ModelId::SonarHugeOnline, k: 0 },
        limit,
    )?;

    let judge = Judge::new(bridge.engine().clone());
    let mut quality = Vec::new();
    for run in [&smart, &gpt4o, &phi] {
        quality.push((run.name.clone(), judge_scores(&judge, run, &sonar)?));
    }

    // Fig 7b: the subset where smart_cache actually used cached content.
    let used_ids: std::collections::HashSet<&str> = smart
        .records
        .iter()
        .filter(|r| r.cache_hit)
        .map(|r| r.query_id.as_str())
        .collect();
    let subset = |run: &StrategyRun| StrategyRun {
        name: run.name.clone(),
        records: run
            .records
            .iter()
            .filter(|r| used_ids.contains(r.query_id.as_str()))
            .cloned()
            .collect(),
    };
    let mut cache_used_quality = Vec::new();
    for run in [&smart, &phi] {
        let sub = subset(run);
        cache_used_quality.push((
            sub.name.clone(),
            judge_scores(&judge, &sub, &sonar)?,
        ));
    }

    Ok(Fig7Output {
        quality,
        cache_used_quality,
        n_factual: smart.records.len(),
        n_cache_used: used_ids.len(),
    })
}

// ===================================================================
// Ablations (DESIGN.md §Perf: design-choice sweeps)
// ===================================================================

pub struct AblationRow {
    pub threshold: f64,
    pub escalation: f64,
    pub mean_quality: f64,
    pub cost_vs_m2: f64,
}

/// Verifier-threshold sweep: how t trades escalation fraction, quality and
/// cost. (The paper fixes t=8; this quantifies the knob it exposes.)
pub fn ablation_threshold(
    bridge: &Bridge,
    seed: u64,
    thresholds: &[f64],
    limit: Option<usize>,
) -> Result<Vec<AblationRow>> {
    let generation = bridge.generation();
    let convs = whatsapp::dataset_d(seed);
    let (m1, m2, verifier) = fig45_models(generation);
    let m2_only = replay(bridge, &convs, &Strategy::FixedModel { model: m2, k: 5 }, limit)?;
    let judge = Judge::new(bridge.engine().clone());
    let mut rows = Vec::new();
    for &t in thresholds {
        let run = replay(
            bridge,
            &convs,
            &Strategy::Verification { t, m1, m2, verifier },
            limit,
        )?;
        let scores = judge_scores(&judge, &run, &m2_only)?;
        rows.push(AblationRow {
            threshold: t,
            escalation: run.escalation_fraction(),
            mean_quality: mean(&scores),
            cost_vs_m2: run.total_cost() / m2_only.total_cost(),
        });
    }
    Ok(rows)
}

/// SmartContext double-call ablation support: fraction of dependent queries
/// wrongly stripped of context (false positives) under 1 vs 2 classifier
/// votes — computed analytically from the calibrated classifier accuracy.
pub fn smart_context_false_positive_rates(capability: f64) -> (f64, f64) {
    let p = crate::models::quality::classifier_accuracy(capability);
    // One call: wrong with prob (1-p). Two calls, drop only if both say
    // standalone: wrong with prob (1-p)^2.
    (1.0 - p, (1.0 - p) * (1.0 - p))
}

/// Convenience: a fresh bridge on a shared engine with the right generation.
pub fn bridge_for(engine: &crate::runtime::EngineHandle, generation: Generation) -> Result<Bridge> {
    Bridge::from_engine(
        engine.clone(),
        BridgeConfig {
            generation,
            ..Default::default()
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_helper() {
        let ps = percentiles(vec![3.0, 1.0, 2.0, 4.0], &[0.0, 0.5, 1.0]);
        assert_eq!(ps[0].1, 1.0);
        assert_eq!(ps[2].1, 4.0);
    }

    #[test]
    fn strategy_labels_stable() {
        let s = Strategy::Verification {
            t: 8.0,
            m1: ModelId::Gpt35Turbo,
            m2: ModelId::Gpt4,
            verifier: ModelId::Claude3Opus,
        };
        assert_eq!(s.label(), "verification(t=8)");
        assert_eq!(
            Strategy::SmartContext { k: 5 }.label(),
            "smart_context(k=5)"
        );
    }

    #[test]
    fn random_strategy_service_type_deterministic() {
        let s = Strategy::Random {
            p: 0.5,
            m1: ModelId::Gpt35Turbo,
            m2: ModelId::Gpt4,
        };
        assert_eq!(s.service_type("q1"), s.service_type("q1"));
        // Across many queries, both models get picked.
        let mut m2_count = 0;
        for i in 0..100 {
            if let ServiceType::Fixed { model, .. } = s.service_type(&format!("q{i}")) {
                if model == ModelId::Gpt4 {
                    m2_count += 1;
                }
            }
        }
        assert!((25..=75).contains(&m2_count));
    }
}
