//! Engine thread + RPC handle, generic over the inference backend.
//!
//! [`EngineHandle`] is the cloneable, thread-safe face of inference: the
//! backend (an [`EmbedBackend`]) runs on a dedicated engine thread and the
//! rest of the proxy talks to it through mpsc RPC — the same shape as
//! handing requests to a GPU-serving process. Backends are constructed
//! *on* that thread, which is what lets the PJRT path work at all: PJRT
//! wrapper types hold raw pointers and are `!Send`.
//!
//! * Default build: [`EngineHandle::spawn_deterministic`] serves from the
//!   pure-Rust [`DeterministicBackend`] — no native deps, no artifacts.
//! * `--features pjrt`: `Engine` owns a `PjRtClient` plus one compiled
//!   executable per model-pool variant (weights pre-uploaded as device
//!   buffers, so the hot path transfers only the token window), loaded
//!   from the artifact [`Registry`](super::registry::Registry).
//!
//! [`EngineHandle::spawn_from_dir`] picks whichever of the two the build
//! enables, so `Bridge::open`, the CLI, benches, and tests are
//! backend-agnostic.
//!
//! ## Batching semantics
//!
//! The engine thread batches opportunistically: after each blocking
//! `recv` it drains the queue with `try_recv` (up to `MAX_DRAIN`
//! messages) and serves the whole wave in one wake-up. Within a wave,
//! embed requests are **coalesced single-flight**: identical token
//! windows — whether they arrive as separate [`EngineHandle::embed_text`]
//! calls from concurrent request threads or inside one
//! [`EngineHandle::embed_batch`] — execute the embedder exactly once and
//! fan the result out to every waiter. `embed_batch` additionally turns
//! N embeds into a single RPC round-trip (one channel send + recv), which
//! is what the semantic cache's multi-key PUT rides on. Within a wave,
//! arrival order is respected at batch granularity: LM steps ahead of the
//! first embed run first, the coalesced embed batch executes at the first
//! embed's position, then the remaining LM steps. No reply ever waits on
//! an LM step that arrived after it; embeds arriving later in the wave
//! ride the earlier batch (that is the coalescing win), and an LM step
//! waits on the batch only when an embed genuinely arrived ahead of it.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::backend::{DeterministicBackend, EmbedBackend};
#[cfg(feature = "pjrt")]
use super::registry::{load_weights, Registry};
use super::tokenizer;

/// A single compiled LM variant with resident weights.
#[cfg(feature = "pjrt")]
struct LoadedLm {
    exe: xla::PjRtLoadedExecutable,
    theta: xla::PjRtBuffer,
    seq_len: usize,
    vocab: usize,
}

/// The PJRT engine proper. Not `Send` — lives on the engine thread.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    lms: HashMap<String, LoadedLm>,
    embed_exe: xla::PjRtLoadedExecutable,
    embed_theta: xla::PjRtBuffer,
    embed_dim: usize,
    seq_len: usize,
}

#[cfg(feature = "pjrt")]
fn compile(client: &xla::PjRtClient, hlo: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        hlo.to_str().context("non-utf8 path")?,
    )
    .map_err(|e| anyhow!("parse {hlo:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {hlo:?}: {e:?}"))
}

#[cfg(feature = "pjrt")]
impl Engine {
    pub fn load(registry: &Registry) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        let mut lms = HashMap::new();
        for art in &registry.models {
            let exe = compile(&client, art.serving_hlo())?;
            let weights = load_weights(&art.weights_path, art.params)?;
            let theta = client
                .buffer_from_host_buffer::<f32>(&weights, &[weights.len()], None)
                .map_err(|e| anyhow!("upload weights {}: {e:?}", art.variant))?;
            lms.insert(
                art.variant.clone(),
                LoadedLm {
                    exe,
                    theta,
                    seq_len: art.seq_len,
                    vocab: art.vocab,
                },
            );
        }
        let embed_exe = compile(&client, &registry.embedder.hlo_path)?;
        let ew = load_weights(&registry.embedder.weights_path, registry.embedder.params)?;
        let embed_theta = client
            .buffer_from_host_buffer::<f32>(&ew, &[ew.len()], None)
            .map_err(|e| anyhow!("upload embedder weights: {e:?}"))?;
        Ok(Engine {
            client,
            lms,
            embed_exe,
            embed_theta,
            embed_dim: registry.embedder.dim,
            seq_len: registry.seq_len(),
        })
    }
}

#[cfg(feature = "pjrt")]
impl EmbedBackend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn embed_dim(&self) -> usize {
        self.embed_dim
    }

    /// Next-token logits for `tokens[..length]` under `variant`.
    fn lm_logits(&self, variant: &str, tokens: &[i32], length: i32) -> Result<Vec<f32>> {
        let lm = self
            .lms
            .get(variant)
            .with_context(|| format!("unknown variant '{variant}'"))?;
        anyhow::ensure!(
            tokens.len() == lm.seq_len,
            "token window is {} but artifact expects {}",
            tokens.len(),
            lm.seq_len
        );
        let t = self
            .client
            .buffer_from_host_buffer::<i32>(tokens, &[lm.seq_len], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))?;
        let l = self
            .client
            .buffer_from_host_buffer::<i32>(&[length], &[], None)
            .map_err(|e| anyhow!("upload length: {e:?}"))?;
        let out = lm
            .exe
            .execute_b(&[&t, &l, &lm.theta])
            .map_err(|e| anyhow!("execute lm_{variant}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch logits: {e:?}"))?;
        let tuple = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let logits = tuple
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))?;
        anyhow::ensure!(logits.len() == lm.vocab, "logit size {}", logits.len());
        Ok(logits)
    }

    /// Text embedding via the embedder artifact.
    fn embed_tokens(&self, tokens: &[i32], length: i32) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == self.seq_len, "embed window size");
        let t = self
            .client
            .buffer_from_host_buffer::<i32>(tokens, &[self.seq_len], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))?;
        let l = self
            .client
            .buffer_from_host_buffer::<i32>(&[length], &[], None)
            .map_err(|e| anyhow!("upload length: {e:?}"))?;
        let out = self
            .embed_exe
            .execute_b(&[&t, &l, &self.embed_theta])
            .map_err(|e| anyhow!("execute embedder: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch embedding: {e:?}"))?;
        let tuple = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let emb = tuple
            .to_vec::<f32>()
            .map_err(|e| anyhow!("embedding to_vec: {e:?}"))?;
        anyhow::ensure!(emb.len() == self.embed_dim, "embed dim {}", emb.len());
        Ok(emb)
    }
}

// ---------------------------------------------------------------- handle

enum Rpc {
    Lm {
        variant: String,
        tokens: Vec<i32>,
        length: i32,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Embed {
        tokens: Vec<i32>,
        length: i32,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    /// N token windows embedded in one round-trip; replies in order.
    EmbedBatch {
        items: Vec<(Vec<i32>, i32)>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Shutdown,
}

/// Cap on how many queued messages one wake-up drains: bounds the latency
/// a wave can add ahead of a newly arrived request.
const MAX_DRAIN: usize = 64;

/// Who is waiting for embed results from the current wave.
enum EmbedWaiter {
    One {
        slot: usize,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Many {
        slots: Vec<usize>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
}

/// Intern a token window into the wave's single-flight job list: identical
/// windows share one slot, so the embedder runs once per unique window.
fn intern_embed(
    jobs: &mut Vec<(Vec<i32>, i32)>,
    slot_of: &mut HashMap<(Vec<i32>, i32), usize>,
    tokens: Vec<i32>,
    length: i32,
) -> usize {
    let key = (tokens, length);
    if let Some(&s) = slot_of.get(&key) {
        return s;
    }
    let s = jobs.len();
    jobs.push(key.clone());
    slot_of.insert(key, s);
    s
}

/// Execute each unique embed job once (micro-batch loop) and fan the
/// results out to every waiter. Errors are carried as strings internally
/// because `anyhow::Error` is not `Clone`.
fn flush_embeds(backend: &dyn EmbedBackend, jobs: &[(Vec<i32>, i32)], waiters: Vec<EmbedWaiter>) {
    let results: Vec<std::result::Result<Vec<f32>, String>> = jobs
        .iter()
        .map(|(t, l)| backend.embed_tokens(t, *l).map_err(|e| format!("{e:#}")))
        .collect();
    let result_at = |slot: usize| -> Result<Vec<f32>> {
        match &results[slot] {
            Ok(v) => Ok(v.clone()),
            Err(e) => Err(anyhow!("{e}")),
        }
    };
    for w in waiters {
        match w {
            EmbedWaiter::One { slot, reply } => {
                let _ = reply.send(result_at(slot));
            }
            EmbedWaiter::Many { slots, reply } => {
                let mut out = Vec::with_capacity(slots.len());
                let mut err = None;
                for s in slots {
                    match result_at(s) {
                        Ok(v) => out.push(v),
                        Err(e) => {
                            err = Some(e);
                            break;
                        }
                    }
                }
                let _ = reply.send(match err {
                    None => Ok(out),
                    Some(e) => Err(e),
                });
            }
        }
    }
}

/// Serve one drained wave of messages. Returns true if a shutdown was seen.
///
/// Arrival order is respected at batch granularity: LM steps that arrived
/// before the wave's first embed run first, the coalesced embed batch
/// executes at the first embed's position, and LM steps that arrived after
/// it run last. No reply ever waits on an LM step that arrived later; an
/// LM step only waits on embeds when one arrived ahead of it.
fn serve_wave(backend: &dyn EmbedBackend, wave: Vec<Rpc>) -> bool {
    let mut shutdown = false;
    let mut jobs: Vec<(Vec<i32>, i32)> = Vec::new();
    let mut slot_of: HashMap<(Vec<i32>, i32), usize> = HashMap::new();
    let mut waiters: Vec<EmbedWaiter> = Vec::new();
    let mut first_embed_pos: Option<usize> = None;
    let mut lms: Vec<(usize, String, Vec<i32>, i32, mpsc::Sender<Result<Vec<f32>>>)> =
        Vec::new();
    for (pos, msg) in wave.into_iter().enumerate() {
        match msg {
            Rpc::Lm {
                variant,
                tokens,
                length,
                reply,
            } => lms.push((pos, variant, tokens, length, reply)),
            Rpc::Embed {
                tokens,
                length,
                reply,
            } => {
                first_embed_pos.get_or_insert(pos);
                let slot = intern_embed(&mut jobs, &mut slot_of, tokens, length);
                waiters.push(EmbedWaiter::One { slot, reply });
            }
            Rpc::EmbedBatch { items, reply } => {
                first_embed_pos.get_or_insert(pos);
                let slots = items
                    .into_iter()
                    .map(|(t, l)| intern_embed(&mut jobs, &mut slot_of, t, l))
                    .collect();
                waiters.push(EmbedWaiter::Many { slots, reply });
            }
            Rpc::Shutdown => shutdown = true,
        }
    }
    let mut pending = if waiters.is_empty() { None } else { Some(waiters) };
    for (pos, variant, tokens, length, reply) in lms {
        if first_embed_pos.is_some_and(|fp| pos > fp) {
            if let Some(w) = pending.take() {
                flush_embeds(backend, &jobs, w);
            }
        }
        let _ = reply.send(backend.lm_logits(&variant, &tokens, length));
    }
    if let Some(w) = pending.take() {
        flush_embeds(backend, &jobs, w);
    }
    shutdown
}

/// Default engine RPC deadline (`--engine-timeout-secs` overrides it).
const DEFAULT_RPC_TIMEOUT_MS: u64 = 120_000;

/// Cloneable, `Send + Sync` handle to the engine thread. (`mpsc::Sender`
/// is `!Sync`, so it sits behind a short-lived Mutex; the lock covers only
/// the enqueue, never the execution.)
pub struct EngineHandle {
    tx: std::sync::Mutex<mpsc::Sender<Rpc>>,
    seq_len: usize,
    embed_dim: usize,
    backend: &'static str,
    /// RPC deadline in milliseconds, shared across clones so a runtime
    /// reconfiguration applies to every caller at once.
    rpc_timeout_ms: std::sync::Arc<std::sync::atomic::AtomicU64>,
}

impl Clone for EngineHandle {
    fn clone(&self) -> Self {
        EngineHandle {
            tx: std::sync::Mutex::new(self.tx.lock().unwrap().clone()),
            seq_len: self.seq_len,
            embed_dim: self.embed_dim,
            backend: self.backend,
            rpc_timeout_ms: self.rpc_timeout_ms.clone(),
        }
    }
}

impl EngineHandle {
    /// Spawn the engine thread over an arbitrary backend. `make` runs *on*
    /// the engine thread (backends need not be `Send`); a constructor
    /// error is surfaced here, not swallowed by the thread.
    pub fn spawn_backend<F>(make: F) -> Result<EngineHandle>
    where
        F: FnOnce() -> Result<Box<dyn EmbedBackend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Rpc>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize, &'static str)>>();
        std::thread::Builder::new()
            .name("llmbridge-engine".into())
            .spawn(move || {
                let backend = match make() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok((b.seq_len(), b.embed_dim(), b.name())));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // Blocking recv, then opportunistically drain the queue so
                // a wave of concurrent requests is served in one wake-up
                // (with single-flight coalescing of identical embeds).
                while let Ok(first) = rx.recv() {
                    let mut wave = Vec::with_capacity(8);
                    wave.push(first);
                    while wave.len() < MAX_DRAIN {
                        match rx.try_recv() {
                            Ok(m) => wave.push(m),
                            Err(_) => break,
                        }
                    }
                    if serve_wave(backend.as_ref(), wave) {
                        break;
                    }
                }
            })
            .context("spawn engine thread")?;
        let (seq_len, embed_dim, backend) = ready_rx
            .recv()
            .context("engine thread died during load")??;
        Ok(EngineHandle {
            tx: std::sync::Mutex::new(tx),
            seq_len,
            embed_dim,
            backend,
            rpc_timeout_ms: std::sync::Arc::new(std::sync::atomic::AtomicU64::new(
                DEFAULT_RPC_TIMEOUT_MS,
            )),
        })
    }

    /// Spawn over the pure-Rust [`DeterministicBackend`] (standard pool
    /// geometry) — the default build's serving path; needs no artifacts.
    pub fn spawn_deterministic() -> Result<EngineHandle> {
        EngineHandle::spawn_backend(|| {
            Ok(Box::new(DeterministicBackend::builtin_pool()) as Box<dyn EmbedBackend>)
        })
    }

    /// Spawn the PJRT engine thread and load all artifacts from `registry`.
    #[cfg(feature = "pjrt")]
    pub fn spawn(registry: Registry) -> Result<EngineHandle> {
        EngineHandle::spawn_backend(move || {
            Ok(Box::new(Engine::load(&registry)?) as Box<dyn EmbedBackend>)
        })
    }

    /// Bring up the serving backend for an artifacts directory: the PJRT
    /// engine over `Registry::load(dir)` under `--features pjrt`, the
    /// [`DeterministicBackend`] otherwise (the directory is then not
    /// consulted — the default build runs on a clean checkout).
    pub fn spawn_from_dir(dir: impl AsRef<std::path::Path>) -> Result<EngineHandle> {
        #[cfg(feature = "pjrt")]
        return EngineHandle::spawn(Registry::load(dir)?);
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = dir.as_ref();
            EngineHandle::spawn_deterministic()
        }
    }

    /// Which backend implementation serves this handle.
    pub fn backend_name(&self) -> &'static str {
        self.backend
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn embed_dim(&self) -> usize {
        self.embed_dim
    }

    /// Current RPC deadline. A hung backend holds a worker (and its
    /// per-user FIFO slot) for at most this long before the call fails
    /// with a typed [`EngineTimeout`] → 503.
    pub fn rpc_timeout(&self) -> Duration {
        Duration::from_millis(
            self.rpc_timeout_ms
                .load(std::sync::atomic::Ordering::Relaxed),
        )
    }

    /// Set the RPC deadline (shared across all clones of this handle).
    pub fn set_rpc_timeout(&self, timeout: Duration) {
        let ms = timeout.as_millis().clamp(1, u64::MAX as u128) as u64;
        self.rpc_timeout_ms
            .store(ms, std::sync::atomic::Ordering::Relaxed);
    }

    /// Wait for an RPC reply under the configured deadline. Expiry maps
    /// to the typed [`EngineTimeout`] marker (the pipeline downcasts it
    /// to a 503 and feeds it to the circuit breaker); a disconnected
    /// channel means the engine thread itself is gone.
    fn wait_reply<T>(&self, rx: mpsc::Receiver<Result<T>>) -> Result<T> {
        let timeout = self.rpc_timeout();
        match rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(anyhow::Error::new(crate::error::EngineTimeout { timeout }))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(anyhow!("engine thread gone")),
        }
    }

    pub fn lm_logits(&self, variant: &str, tokens: Vec<i32>, length: i32) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Rpc::Lm {
                variant: variant.to_string(),
                tokens,
                length,
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        self.wait_reply(rx)
    }

    /// Embed arbitrary text (tokenize + window + execute).
    pub fn embed_text(&self, text: &str) -> Result<Vec<f32>> {
        let (tokens, length) = tokenizer::window(text, self.seq_len);
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Rpc::Embed {
                tokens,
                length,
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        self.wait_reply(rx)
    }

    /// Embed many texts in one RPC round-trip. Results are in input order;
    /// duplicate texts are computed once on the engine thread (single
    /// flight) and fanned back out.
    pub fn embed_batch(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        if texts.is_empty() {
            return Ok(Vec::new());
        }
        let items: Vec<(Vec<i32>, i32)> = texts
            .iter()
            .map(|t| tokenizer::window(t, self.seq_len))
            .collect();
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Rpc::EmbedBatch { items, reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        self.wait_reply(rx)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Rpc::Shutdown);
    }
}
