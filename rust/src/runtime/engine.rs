//! PJRT inference engine — the runtime bridge between the rust coordinator
//! and the AOT-compiled JAX/Pallas artifacts.
//!
//! [`Engine`] owns a `PjRtClient` plus one compiled executable per
//! model-pool variant (weights pre-uploaded as device buffers, so the hot
//! path transfers only the token window). PJRT wrapper types hold raw
//! pointers and are `!Send`, so the engine runs on a dedicated thread and
//! the rest of the proxy talks to it through the cloneable, thread-safe
//! [`EngineHandle`] (mpsc RPC) — the same shape as handing requests to a
//! GPU-serving process.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::registry::{load_weights, Registry};
use super::tokenizer;

/// A single compiled LM variant with resident weights.
struct LoadedLm {
    exe: xla::PjRtLoadedExecutable,
    theta: xla::PjRtBuffer,
    seq_len: usize,
    vocab: usize,
}

/// The engine proper. Not `Send` — lives on the engine thread.
pub struct Engine {
    client: xla::PjRtClient,
    lms: HashMap<String, LoadedLm>,
    embed_exe: xla::PjRtLoadedExecutable,
    embed_theta: xla::PjRtBuffer,
    embed_dim: usize,
    seq_len: usize,
}

fn compile(client: &xla::PjRtClient, hlo: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        hlo.to_str().context("non-utf8 path")?,
    )
    .map_err(|e| anyhow!("parse {hlo:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {hlo:?}: {e:?}"))
}

impl Engine {
    pub fn load(registry: &Registry) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        let mut lms = HashMap::new();
        for art in &registry.models {
            let exe = compile(&client, art.serving_hlo())?;
            let weights = load_weights(&art.weights_path, art.params)?;
            let theta = client
                .buffer_from_host_buffer::<f32>(&weights, &[weights.len()], None)
                .map_err(|e| anyhow!("upload weights {}: {e:?}", art.variant))?;
            lms.insert(
                art.variant.clone(),
                LoadedLm {
                    exe,
                    theta,
                    seq_len: art.seq_len,
                    vocab: art.vocab,
                },
            );
        }
        let embed_exe = compile(&client, &registry.embedder.hlo_path)?;
        let ew = load_weights(&registry.embedder.weights_path, registry.embedder.params)?;
        let embed_theta = client
            .buffer_from_host_buffer::<f32>(&ew, &[ew.len()], None)
            .map_err(|e| anyhow!("upload embedder weights: {e:?}"))?;
        Ok(Engine {
            client,
            lms,
            embed_exe,
            embed_theta,
            embed_dim: registry.embedder.dim,
            seq_len: registry.seq_len(),
        })
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Next-token logits for `tokens[..length]` under `variant`.
    pub fn lm_logits(&self, variant: &str, tokens: &[i32], length: i32) -> Result<Vec<f32>> {
        let lm = self
            .lms
            .get(variant)
            .with_context(|| format!("unknown variant '{variant}'"))?;
        anyhow::ensure!(
            tokens.len() == lm.seq_len,
            "token window is {} but artifact expects {}",
            tokens.len(),
            lm.seq_len
        );
        let t = self
            .client
            .buffer_from_host_buffer::<i32>(tokens, &[lm.seq_len], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))?;
        let l = self
            .client
            .buffer_from_host_buffer::<i32>(&[length], &[], None)
            .map_err(|e| anyhow!("upload length: {e:?}"))?;
        let out = lm
            .exe
            .execute_b(&[&t, &l, &lm.theta])
            .map_err(|e| anyhow!("execute lm_{variant}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch logits: {e:?}"))?;
        let tuple = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let logits = tuple
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))?;
        anyhow::ensure!(logits.len() == lm.vocab, "logit size {}", logits.len());
        Ok(logits)
    }

    /// Text embedding via the embedder artifact.
    pub fn embed_tokens(&self, tokens: &[i32], length: i32) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == self.seq_len, "embed window size");
        let t = self
            .client
            .buffer_from_host_buffer::<i32>(tokens, &[self.seq_len], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))?;
        let l = self
            .client
            .buffer_from_host_buffer::<i32>(&[length], &[], None)
            .map_err(|e| anyhow!("upload length: {e:?}"))?;
        let out = self
            .embed_exe
            .execute_b(&[&t, &l, &self.embed_theta])
            .map_err(|e| anyhow!("execute embedder: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch embedding: {e:?}"))?;
        let tuple = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let emb = tuple
            .to_vec::<f32>()
            .map_err(|e| anyhow!("embedding to_vec: {e:?}"))?;
        anyhow::ensure!(emb.len() == self.embed_dim, "embed dim {}", emb.len());
        Ok(emb)
    }
}

// ---------------------------------------------------------------- handle

enum Rpc {
    Lm {
        variant: String,
        tokens: Vec<i32>,
        length: i32,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Embed {
        tokens: Vec<i32>,
        length: i32,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Cloneable, `Send + Sync` handle to the engine thread. (`mpsc::Sender`
/// is `!Sync`, so it sits behind a short-lived Mutex; the lock covers only
/// the enqueue, never the execution.)
pub struct EngineHandle {
    tx: std::sync::Mutex<mpsc::Sender<Rpc>>,
    seq_len: usize,
    embed_dim: usize,
}

impl Clone for EngineHandle {
    fn clone(&self) -> Self {
        EngineHandle {
            tx: std::sync::Mutex::new(self.tx.lock().unwrap().clone()),
            seq_len: self.seq_len,
            embed_dim: self.embed_dim,
        }
    }
}

impl EngineHandle {
    /// Spawn the engine thread and load all artifacts from `registry`.
    pub fn spawn(registry: Registry) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Rpc>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();
        std::thread::Builder::new()
            .name("llmbridge-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&registry) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok((e.seq_len(), e.embed_dim)));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Rpc::Lm {
                            variant,
                            tokens,
                            length,
                            reply,
                        } => {
                            let _ = reply.send(engine.lm_logits(&variant, &tokens, length));
                        }
                        Rpc::Embed {
                            tokens,
                            length,
                            reply,
                        } => {
                            let _ = reply.send(engine.embed_tokens(&tokens, length));
                        }
                        Rpc::Shutdown => break,
                    }
                }
            })
            .context("spawn engine thread")?;
        let (seq_len, embed_dim) = ready_rx
            .recv()
            .context("engine thread died during load")??;
        Ok(EngineHandle {
            tx: std::sync::Mutex::new(tx),
            seq_len,
            embed_dim,
        })
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn embed_dim(&self) -> usize {
        self.embed_dim
    }

    pub fn lm_logits(&self, variant: &str, tokens: Vec<i32>, length: i32) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Rpc::Lm {
                variant: variant.to_string(),
                tokens,
                length,
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv_timeout(Duration::from_secs(120))
            .map_err(|_| anyhow!("engine rpc timeout"))?
    }

    /// Embed arbitrary text (tokenize + window + execute).
    pub fn embed_text(&self, text: &str) -> Result<Vec<f32>> {
        let (tokens, length) = tokenizer::window(text, self.seq_len);
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Rpc::Embed {
                tokens,
                length,
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv_timeout(Duration::from_secs(120))
            .map_err(|_| anyhow!("engine rpc timeout"))?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Rpc::Shutdown);
    }
}
