//! Pluggable inference backends — the seam that lets the default build run
//! with zero native dependencies.
//!
//! [`EmbedBackend`] is the capability the engine thread actually needs:
//! next-token logits per model-pool variant, plus text embeddings over the
//! shared word-hash [`tokenizer`] window. Two implementations exist:
//!
//! * [`DeterministicBackend`] (always compiled; what the default build
//!   serves from): a pure-Rust stand-in with the same geometry as the AOT
//!   artifacts — seq_len 128, embed dim 64, vocab 4096, and the
//!   `nano`/`mini`/`large` variant ladder of `python/compile/model.py`.
//!   Embeddings are a seeded ±1 projection summed over the window's word
//!   ids and unit-normalized (so lexically overlapping texts score high
//!   cosine, like the artifact embedder trained on the same tokenizer).
//!   Logits hash the *live* token prefix per variant and fold a resident
//!   synthetic weight buffer sized like the variant's parameter count, so
//!   bigger variants cost proportionally more wall-clock per step — the
//!   latency ordering the routing policies and benches rely on. Every
//!   value derives from fixed seeds over slices: no map iteration order,
//!   no addresses, no clock — outputs are bit-identical across calls,
//!   threads, and processes (`tests/backend_determinism.rs` pins this
//!   with a cross-process probe).
//! * `Engine` (`--features pjrt`): the PJRT/XLA path executing the real
//!   AOT-compiled artifacts from the registry manifest; see
//!   [`super::engine`].
//!
//! The handle/RPC layer ([`super::engine::EngineHandle`]) is
//! backend-agnostic: wave batching, single-flight embed coalescing, and
//! reply ordering are identical under either implementation.

use anyhow::{anyhow, ensure, Result};

use super::tokenizer;
use crate::util::rng::split_mix as mix;
use crate::util::seed_of;

/// What the engine thread requires of an inference backend. Implementors
/// are constructed *on* the engine thread (see
/// [`super::engine::EngineHandle::spawn_backend`]), so they need not be
/// `Send` — the PJRT types are not.
pub trait EmbedBackend {
    /// Short identifier for telemetry and diagnostics (`"deterministic"`,
    /// `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Token-window length every `lm_logits`/`embed_tokens` call must use.
    fn seq_len(&self) -> usize;

    /// Embedding dimensionality.
    fn embed_dim(&self) -> usize;

    /// Next-token logits (vocab-sized) for `tokens[..length]` under the
    /// named model-pool `variant`.
    fn lm_logits(&self, variant: &str, tokens: &[i32], length: i32) -> Result<Vec<f32>>;

    /// Text embedding for the window `tokens[..length]`.
    fn embed_tokens(&self, tokens: &[i32], length: i32) -> Result<Vec<f32>>;
}

/// Geometry of one deterministic LM variant.
#[derive(Clone, Copy, Debug)]
pub struct VariantSpec {
    pub name: &'static str,
    pub d_model: usize,
    pub layers: usize,
}

impl VariantSpec {
    /// Size of the synthetic resident weight buffer: a tied token
    /// embedding/unembedding (`vocab × d_model`) plus ~12·d² per block —
    /// the same scaling law as the real artifacts, so per-step cost
    /// ordering (`nano` < `mini` < `large`) matches the hardware path.
    pub fn param_count(&self) -> usize {
        let vocab = tokenizer::VOCAB as usize;
        vocab * self.d_model + 12 * self.layers * self.d_model * self.d_model
    }
}

/// The built-in pool ladder — mirrors `VARIANTS` in
/// `python/compile/model.py` (and the artifact manifest the PJRT path
/// loads), so routing tables that name artifacts work under both backends.
pub const BUILTIN_VARIANTS: &[VariantSpec] = &[
    VariantSpec {
        name: "nano",
        d_model: 64,
        layers: 2,
    },
    VariantSpec {
        name: "mini",
        d_model: 96,
        layers: 3,
    },
    VariantSpec {
        name: "large",
        d_model: 128,
        layers: 4,
    },
];

/// Window length of the built-in pool (mirrors the AOT artifacts).
pub const BUILTIN_SEQ_LEN: usize = 128;

/// Embedding dimensionality of the built-in pool (mirrors the artifacts'
/// embedder).
pub const BUILTIN_EMBED_DIM: usize = 64;

// All backend pseudo-randomness flows through `mix` — one stateless
// SplitMix64 step ([`crate::util::rng::split_mix`]) keyed on fixed seeds.

/// Map a hash to an f32 in [-0.5, 0.5) using 24 high bits (exact in f32).
fn unit_f32(h: u64) -> f32 {
    ((h >> 40) as f32) / (1u64 << 24) as f32 - 0.5
}

struct DeterministicLm {
    name: &'static str,
    d_model: usize,
    /// Seeded synthetic weights, materialized once at spawn (like the real
    /// engine's device-resident theta); every `lm_logits` call folds the
    /// whole buffer once, so call cost scales with parameter count.
    weights: Vec<f32>,
}

/// Pure-Rust deterministic backend — the default build's serving path.
pub struct DeterministicBackend {
    seq_len: usize,
    embed_dim: usize,
    variants: Vec<DeterministicLm>,
}

impl DeterministicBackend {
    pub fn new(seq_len: usize, embed_dim: usize, variants: &[VariantSpec]) -> DeterministicBackend {
        let variants = variants
            .iter()
            .map(|spec| {
                let mut h = seed_of(&["det-weights", spec.name]);
                let weights = (0..spec.param_count())
                    .map(|_| {
                        h = mix(h);
                        unit_f32(h)
                    })
                    .collect();
                DeterministicLm {
                    name: spec.name,
                    d_model: spec.d_model,
                    weights,
                }
            })
            .collect();
        DeterministicBackend {
            seq_len,
            embed_dim,
            variants,
        }
    }

    /// The standard pool: same variants, window, and embedding dim as the
    /// AOT artifact set.
    pub fn builtin_pool() -> DeterministicBackend {
        DeterministicBackend::new(BUILTIN_SEQ_LEN, BUILTIN_EMBED_DIM, BUILTIN_VARIANTS)
    }

    /// Validate and slice the live prefix of a window.
    fn live_prefix<'a>(&self, tokens: &'a [i32], length: i32) -> Result<&'a [i32]> {
        ensure!(
            tokens.len() == self.seq_len,
            "token window is {} but backend expects {}",
            tokens.len(),
            self.seq_len
        );
        ensure!(
            length >= 0 && (length as usize) <= tokens.len(),
            "live length {length} outside the {}-token window",
            tokens.len()
        );
        Ok(&tokens[..length as usize])
    }
}

impl EmbedBackend for DeterministicBackend {
    fn name(&self) -> &'static str {
        "deterministic"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn embed_dim(&self) -> usize {
        self.embed_dim
    }

    fn lm_logits(&self, variant: &str, tokens: &[i32], length: i32) -> Result<Vec<f32>> {
        let lm = self
            .variants
            .iter()
            .find(|v| v.name == variant)
            .ok_or_else(|| anyhow!("unknown variant '{variant}'"))?;
        let live = self.live_prefix(tokens, length)?;

        // Content signature over the live prefix only — tokens beyond
        // `length` can never influence logits (mask correctness), and the
        // signature is position-sensitive so "a b" and "b a" diverge.
        let mut sig = seed_of(&["det-lm", variant]);
        for (pos, &t) in live.iter().enumerate() {
            sig = mix(sig ^ (t as u32 as u64) ^ ((pos as u64) << 32));
        }

        // The "forward pass": one full fold of the resident weights into a
        // d_model-wide state with signature-dependent signs. This is where
        // the wall-clock goes — cost tracks parameter count, preserving
        // the artifact FLOP ordering (nano < mini < large) that
        // `larger_model_slower` and the routing benches rely on.
        let d = lm.d_model;
        let mut state = vec![0.0f32; d];
        let lane = mix(sig);
        for (i, &w) in lm.weights.iter().enumerate() {
            let flip = lane.rotate_right((i & 63) as u32) & 1;
            state[i % d] += if flip == 1 { -w } else { w };
        }

        // Unembedding: per-token-id hash of the signature, nudged by the
        // state so the weight pass is load-bearing (never optimized out).
        let vocab = tokenizer::VOCAB as usize;
        let mut logits = Vec::with_capacity(vocab);
        let mut h = mix(sig);
        for v in 0..vocab {
            h = mix(h ^ (v as u64));
            logits.push(unit_f32(h) * 8.0 + state[v % d] * 1e-3);
        }
        Ok(logits)
    }

    fn embed_tokens(&self, tokens: &[i32], length: i32) -> Result<Vec<f32>> {
        let live = self.live_prefix(tokens, length)?;
        // Bag of seeded ±1 word vectors: each word id contributes a fixed
        // pseudo-random sign pattern, so texts sharing words land close in
        // cosine and unrelated texts decorrelate (≈ N(0, 1/√dim) noise).
        let base = seed_of(&["det-embed"]);
        let mut acc = vec![0.0f32; self.embed_dim];
        for &t in live {
            if t < tokenizer::FIRST_WORD_ID as i32 {
                continue; // specials (BOS/EOS/PAD/UNK) carry no content
            }
            let mut h = mix(base ^ (t as u32 as u64));
            for (j, slot) in acc.iter_mut().enumerate() {
                let bit = j & 63;
                if bit == 0 && j > 0 {
                    h = mix(h);
                }
                *slot += if (h >> bit) & 1 == 1 { 1.0 } else { -1.0 };
            }
        }
        let norm = acc.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            for x in &mut acc {
                *x /= norm;
            }
        } else {
            // An all-special window (e.g. empty text) still embeds to a
            // fixed unit vector rather than zeros or NaNs.
            acc[0] = 1.0;
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdb::Metric;

    fn windows(text: &str) -> (Vec<i32>, i32) {
        tokenizer::window(text, BUILTIN_SEQ_LEN)
    }

    #[test]
    fn two_instances_agree_bit_for_bit() {
        let a = DeterministicBackend::builtin_pool();
        let b = DeterministicBackend::builtin_pool();
        let (tokens, live) = windows("what is the capital of sudan");
        for v in ["nano", "mini", "large"] {
            assert_eq!(
                a.lm_logits(v, &tokens, live).unwrap(),
                b.lm_logits(v, &tokens, live).unwrap()
            );
        }
        assert_eq!(
            a.embed_tokens(&tokens, live).unwrap(),
            b.embed_tokens(&tokens, live).unwrap()
        );
    }

    #[test]
    fn logits_padding_inert_and_vocab_sized() {
        let be = DeterministicBackend::builtin_pool();
        let (tokens, live) = windows("a short prompt");
        let clean = be.lm_logits("nano", &tokens, live).unwrap();
        assert_eq!(clean.len(), tokenizer::VOCAB as usize);
        let mut dirty = tokens.clone();
        for t in dirty.iter_mut().skip(live as usize) {
            *t = 1234;
        }
        assert_eq!(clean, be.lm_logits("nano", &dirty, live).unwrap());
    }

    #[test]
    fn variants_diverge_and_unknown_variant_errors() {
        let be = DeterministicBackend::builtin_pool();
        let (tokens, live) = windows("tell me about cricket");
        let nano = be.lm_logits("nano", &tokens, live).unwrap();
        let large = be.lm_logits("large", &tokens, live).unwrap();
        let diff: f32 = nano.iter().zip(&large).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1.0, "diff={diff}");
        assert!(be.lm_logits("gpt-7", &tokens, live).is_err());
    }

    #[test]
    fn embeddings_are_normalized_and_lexically_ordered() {
        let be = DeterministicBackend::builtin_pool();
        let embed = |text: &str| {
            let (tokens, live) = windows(text);
            be.embed_tokens(&tokens, live).unwrap()
        };
        let a = embed("tell me about the socc conference");
        let b = embed("talk to me about socc conference please");
        let c = embed("recipe for chicken biryani with rice");
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-3);
        let ab = Metric::Cosine.score(&a, &b);
        let ac = Metric::Cosine.score(&a, &c);
        assert!(ab > ac + 0.2, "ab={ab} ac={ac}");
        // Empty text: fixed unit fallback, no NaNs.
        let e = embed("");
        assert!(e.iter().all(|x| x.is_finite()));
        assert!((e.iter().map(|x| x * x).sum::<f32>().sqrt() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn bigger_variants_cost_more_per_step() {
        // The latency ladder the router's latency-class policy and
        // `tests/runtime_smoke.rs::larger_model_slower` rely on. The
        // deterministic part first: per-call work is the weight fold, so
        // the ladder is exactly the parameter-count ordering.
        let specs: Vec<usize> = BUILTIN_VARIANTS.iter().map(|v| v.param_count()).collect();
        assert!(specs.windows(2).all(|w| w[0] < w[1]), "{specs:?}");
        // Wall-clock corroboration, made preemption-tolerant for shared CI
        // runners: take the *minimum* of several timed batches per variant
        // (a scheduler spike inflates a sample, never deflates it), and
        // large has ~3.6x nano's work, so min-vs-min ordering is stable.
        let be = DeterministicBackend::builtin_pool();
        let (tokens, live) = windows("latency probe alpha");
        let min_time = |variant: &str| {
            (0..5)
                .map(|_| {
                    let t0 = std::time::Instant::now();
                    for _ in 0..4 {
                        std::hint::black_box(be.lm_logits(variant, &tokens, live).unwrap());
                    }
                    t0.elapsed()
                })
                .min()
                .unwrap()
        };
        // Warm up once so first-touch page faults don't skew nano.
        let _ = min_time("large");
        let nano = min_time("nano");
        let large = min_time("large");
        assert!(
            large > nano,
            "large {large:?} must exceed nano {nano:?} (params scale the fold)"
        );
    }
}
