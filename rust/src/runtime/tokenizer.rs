//! Word-hash tokenizer — bit-for-bit mirror of `python/compile/model.py`.
//!
//! Lowercased ASCII-alphanumeric words, FNV-1a 64 hashed into ids
//! `FIRST_WORD_ID..VOCAB`. Specials: PAD=0, BOS=1, EOS=2, UNK=3.
//! `python/tests/test_tokenizer.py` and `rust/tests/tokenizer_vectors.rs`
//! pin shared vectors so the two implementations cannot drift.

use crate::util::fnv1a;

pub const VOCAB: i64 = 4096;
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const UNK: i32 = 3;
pub const FIRST_WORD_ID: i64 = 16;

/// Split into lowercase ascii-alphanumeric words (mirror of model.words).
pub fn words(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        let lower = ch.to_ascii_lowercase();
        if lower.is_ascii_alphanumeric() {
            cur.push(lower);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Hash one (already lowercased) word to its vocabulary id.
pub fn word_id(word: &str) -> i32 {
    (FIRST_WORD_ID + (fnv1a(word.as_bytes()) % (VOCAB - FIRST_WORD_ID) as u64) as i64) as i32
}

/// Unbounded encoding: `[BOS] words.. [EOS]` — used for token *counting*
/// (billing) and as the source for window packing.
pub fn encode(text: &str) -> Vec<i32> {
    let mut ids = vec![BOS];
    ids.extend(words(text).iter().map(|w| word_id(w)));
    ids.push(EOS);
    ids
}

/// Billable token count for a text (matches the paper's per-token pricing;
/// the bridge bills pre-truncation counts — see DESIGN.md §Substitutions).
pub fn count_tokens(text: &str) -> u64 {
    // BOS/EOS excluded from billing: count words only.
    words(text).len() as u64
}

/// Pack into a fixed window of `seq_len`: keeps the *most recent* tokens
/// when the text overflows (left truncation — a sliding context window),
/// pads with PAD on the right. Returns (tokens, live_length).
pub fn window(text: &str, seq_len: usize) -> (Vec<i32>, i32) {
    let mut ids = vec![BOS];
    let ws = words(text);
    let budget = seq_len - 2;
    let start = ws.len().saturating_sub(budget);
    ids.extend(ws[start..].iter().map(|w| word_id(w)));
    ids.push(EOS);
    let live = ids.len();
    ids.resize(seq_len, PAD);
    (ids, live as i32)
}

/// Same as [`window`] but without the trailing EOS — the shape used as a
/// generation prefix (the model continues after the prompt).
pub fn gen_prefix(text: &str, seq_len: usize, reserve: usize) -> (Vec<i32>, i32) {
    let mut ids = vec![BOS];
    let ws = words(text);
    let budget = seq_len.saturating_sub(reserve + 1);
    let start = ws.len().saturating_sub(budget);
    ids.extend(ws[start..].iter().map(|w| word_id(w)));
    let live = ids.len();
    ids.resize(seq_len, PAD);
    (ids, live as i32)
}

/// Inverse mapping for generated ids. Word ids are one-way hashes, so the
/// surface form is the synthetic `t<id>`; specials render as empty.
pub fn detokenize(ids: &[i32]) -> String {
    let mut out = String::new();
    for &id in ids {
        if id >= FIRST_WORD_ID as i32 {
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(&format!("t{id}"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen_text};

    #[test]
    fn words_split_and_lowercase() {
        assert_eq!(words("Tell me about Sigcomm!"), vec!["tell", "me", "about", "sigcomm"]);
        assert_eq!(words(""), Vec::<String>::new());
        assert_eq!(words("a-b_c"), vec!["a", "b", "c"]);
    }

    #[test]
    fn encode_has_bos_eos() {
        let ids = encode("hello world");
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert_eq!(ids.len(), 4);
    }

    #[test]
    fn window_left_truncates() {
        let long: String = (0..500).map(|i| format!("w{i} ")).collect();
        let (ids, live) = window(&long, 160);
        assert_eq!(ids.len(), 160);
        assert_eq!(live, 160);
        // Most recent word must be present.
        assert_eq!(ids[158], word_id("w499"));
        assert_eq!(ids[159], EOS);
    }

    #[test]
    fn gen_prefix_reserves_room() {
        let (ids, live) = gen_prefix("hello world", 160, 40);
        assert_eq!(ids.len(), 160);
        assert_eq!(live, 3); // BOS + 2 words
        assert!(live as usize <= 160 - 40);
        let long: String = (0..500).map(|i| format!("w{i} ")).collect();
        let (_, live) = gen_prefix(&long, 160, 40);
        assert_eq!(live as usize, 160 - 40);
    }

    #[test]
    fn prop_window_invariants() {
        forall(
            23,
            100,
            |r| gen_text(r, 300),
            |text| {
                let (ids, live) = window(text, 160);
                ids.len() == 160
                    && (2..=160).contains(&(live as usize))
                    && ids[0] == BOS
                    && ids[live as usize - 1] == EOS
                    && ids[live as usize..].iter().all(|&t| t == PAD)
                    && ids.iter().all(|&t| (0..VOCAB as i32).contains(&t))
            },
        );
    }

    #[test]
    fn count_matches_words() {
        assert_eq!(count_tokens("one two three"), 3);
        assert_eq!(count_tokens(""), 0);
    }
}
