//! Runtime layer: the only place the proxy touches XLA/PJRT.
//!
//! * [`tokenizer`] — word-hash tokenizer shared bit-for-bit with the python
//!   build path.
//! * [`registry`] — locates AOT artifacts via `artifacts/manifest.json`.
//! * [`engine`] — PJRT CPU client; compiles each `*.hlo.txt` once at load
//!   and executes them on the request path via a dedicated engine thread.

pub mod engine;
pub mod registry;
pub mod tokenizer;

pub use engine::{Engine, EngineHandle};
pub use registry::Registry;
