//! Runtime layer: tokenization, artifact discovery, and the inference
//! backends behind the engine thread.
//!
//! * [`tokenizer`] — word-hash tokenizer shared bit-for-bit with the python
//!   build path.
//! * [`backend`] — the [`EmbedBackend`] seam: the pure-Rust
//!   [`DeterministicBackend`] (default build; no native deps) vs the
//!   PJRT/XLA engine (`--features pjrt`).
//! * [`registry`] — locates AOT artifacts via `artifacts/manifest.json`
//!   (consumed by the PJRT path; the deterministic backend needs none).
//! * [`engine`] — the engine thread + cloneable [`EngineHandle`] RPC
//!   facade, generic over the backend. Under `--features pjrt` it also
//!   holds the PJRT client that compiles each `*.hlo.txt` once at load.

pub mod backend;
pub mod engine;
pub mod registry;
pub mod tokenizer;

pub use backend::{DeterministicBackend, EmbedBackend};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use engine::EngineHandle;
pub use registry::Registry;
