//! Artifact registry: parses `artifacts/manifest.json` written by
//! `python/compile/aot.py` and locates the HLO-text + weight blobs for each
//! model-pool variant and the embedder.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct LmArtifact {
    pub variant: String,
    pub d_model: usize,
    pub layers: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub params: usize,
    /// The Pallas-kernel lowering (the TPU-shaped path).
    pub hlo_path: PathBuf,
    /// The fused pure-jnp lowering XLA:CPU prefers (2.3x faster on the CPU
    /// PJRT plugin; EXPERIMENTS.md §Perf). Absent in older artifact dirs.
    pub hlo_fused_path: Option<PathBuf>,
    pub weights_path: PathBuf,
}

impl LmArtifact {
    /// Which lowering the engine should compile for serving:
    /// the fused twin when present, unless `LLMBRIDGE_KERNEL_PATH=pallas`
    /// forces the kernel path (used by tests to pin numerics equality).
    pub fn serving_hlo(&self) -> &PathBuf {
        let force_pallas = std::env::var("LLMBRIDGE_KERNEL_PATH")
            .map(|v| v == "pallas")
            .unwrap_or(false);
        match (&self.hlo_fused_path, force_pallas) {
            (Some(fused), false) => fused,
            _ => &self.hlo_path,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EmbedArtifact {
    pub dim: usize,
    pub seq_len: usize,
    pub params: usize,
    pub hlo_path: PathBuf,
    pub weights_path: PathBuf,
}

#[derive(Clone, Debug)]
pub struct Registry {
    pub dir: PathBuf,
    pub models: Vec<LmArtifact>,
    pub embedder: EmbedArtifact,
}

impl Registry {
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {manifest_path:?} — run `make artifacts` first to AOT-compile the model pool"
            )
        })?;
        let manifest = Json::parse(&text)?;

        let mut models = Vec::new();
        for entry in manifest
            .req("models")?
            .as_arr()
            .context("manifest 'models' not an array")?
        {
            let hlo_fused_path = entry
                .get("hlo_fused")
                .and_then(|v| v.as_str())
                .map(|f| dir.join(f))
                .filter(|p| p.exists());
            let art = LmArtifact {
                variant: entry.str_of("variant")?,
                d_model: entry.usize_of("d_model")?,
                layers: entry.usize_of("layers")?,
                seq_len: entry.usize_of("seq_len")?,
                vocab: entry.usize_of("vocab")?,
                params: entry.usize_of("params")?,
                hlo_path: dir.join(entry.str_of("hlo")?),
                hlo_fused_path,
                weights_path: dir.join(entry.str_of("weights")?),
            };
            if !art.hlo_path.exists() {
                bail!("missing artifact {:?}", art.hlo_path);
            }
            if !art.weights_path.exists() {
                bail!("missing weights {:?}", art.weights_path);
            }
            models.push(art);
        }
        if models.is_empty() {
            bail!("manifest has no models");
        }

        let e = manifest.req("embedder")?;
        let embedder = EmbedArtifact {
            dim: e.usize_of("dim")?,
            seq_len: e.usize_of("seq_len")?,
            params: e.usize_of("params")?,
            hlo_path: dir.join(e.str_of("hlo")?),
            weights_path: dir.join(e.str_of("weights")?),
        };

        Ok(Registry {
            dir,
            models,
            embedder,
        })
    }

    pub fn lm(&self, variant: &str) -> Result<&LmArtifact> {
        self.models
            .iter()
            .find(|m| m.variant == variant)
            .with_context(|| format!("unknown model variant '{variant}'"))
    }

    pub fn seq_len(&self) -> usize {
        self.models[0].seq_len
    }
}

/// Load a little-endian f32 weight blob.
pub fn load_weights(path: &Path, expect: usize) -> Result<Vec<f32>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading weights {path:?}"))?;
    if bytes.len() != expect * 4 {
        bail!(
            "weight blob {path:?} has {} bytes, expected {}",
            bytes.len(),
            expect * 4
        );
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root; artifacts are built by `make`.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// These tests exercise the manifest loader against real AOT
    /// artifacts, which exist only after `make artifacts` (the default
    /// build serves from the deterministic backend and needs none). Skip
    /// quietly when absent so the default-feature suite passes on a clean
    /// checkout; a present-but-broken artifact dir still fails loudly.
    fn loaded() -> Option<Registry> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping registry test: no artifacts at {dir:?} (run `make artifacts`)");
            return None;
        }
        Some(Registry::load(dir).expect("artifacts present but manifest failed to load"))
    }

    #[test]
    fn loads_manifest() {
        let Some(reg) = loaded() else { return };
        assert_eq!(reg.models.len(), 3);
        assert!(reg.lm("nano").is_ok());
        assert!(reg.lm("large").is_ok());
        assert!(reg.lm("gpt-7").is_err());
        assert_eq!(reg.embedder.dim, 64);
        assert_eq!(reg.seq_len(), 128);
    }

    #[test]
    fn fused_twin_selected_for_serving() {
        let Some(reg) = loaded() else { return };
        let large = reg.lm("large").unwrap();
        assert!(large.hlo_fused_path.is_some(), "aot emits the fused twin");
        // Default: fused; the env override is exercised by integration
        // tests (env vars are process-global, avoid racing here).
        if std::env::var("LLMBRIDGE_KERNEL_PATH").is_err() {
            assert_eq!(large.serving_hlo(), large.hlo_fused_path.as_ref().unwrap());
        }
    }

    #[test]
    fn weights_size_checked() {
        let Some(reg) = loaded() else { return };
        let nano = reg.lm("nano").unwrap();
        assert!(load_weights(&nano.weights_path, nano.params).is_ok());
        assert!(load_weights(&nano.weights_path, nano.params + 1).is_err());
    }
}
