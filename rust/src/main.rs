//! LLMBridge CLI — the leader entrypoint.
//!
//! ```text
//! llmbridge serve   [--bind 127.0.0.1:8080] [--workers 4] [--artifacts DIR]
//!                   [--prefetch] [--generation old|new]
//!                   [--data-dir DIR] [--compact-wal-bytes N]
//!                   [--backend auto|evented|threaded] [--max-conns 4096]
//!                   [--shed-watermark 512] [--user-queue-cap 32]
//!                   [--keepalive-secs 30] [--request-deadline-secs 10]
//!                   [--drain-secs 5] [--admin-port N]
//!                   [--rate-per-sec R] [--rate-burst B]
//!                   [--engine-timeout-secs N]
//!                   [--breaker-threshold N] [--breaker-cooldown-secs N]
//!                   [--node-id ID] [--sync-port N] [--peer HOST:PORT]
//!                   [--sync-interval-ms N]
//! llmbridge sync    --node-id ID --peer HOST:PORT [--data-dir DIR]
//!                                             # one anti-entropy round, then exit
//! llmbridge ask     --prompt "..." [--service TYPE] [--user u] [--artifacts DIR]
//! llmbridge warm    [--artifacts DIR]        # load corpus into the cache
//! llmbridge models                            # print the model pool
//! llmbridge probe-backend [--text "..."]      # backend fingerprint (determinism probe)
//! llmbridge trace [--seed N]                  # workload/trace fingerprints (determinism probe)
//! ```
//!
//! The default build serves from the deterministic pure-Rust backend (no
//! artifacts needed); `--features pjrt` serves the AOT artifacts under
//! `--artifacts DIR` via PJRT. See README.md for the build matrix.

use std::sync::Arc;

use anyhow::{bail, Result};

use llmbridge::api::{Request, ServiceType};
use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::models::pricing::{Generation, ModelId, POOL};
use llmbridge::server::{Server, ServerBackend, ServerConfig};
use llmbridge::util::cli::Args;
use llmbridge::util::json::Json;
use llmbridge::workload::corpus;

/// SIGINT/SIGTERM → a latch the serve loop polls, so Ctrl-C runs the
/// graceful path ([`Server::stop`]: drain + WAL flush) instead of
/// killing the process mid-write. Raw `signal(2)` through the C runtime
/// (same no-new-deps policy as the epoll shim); the handler body is a
/// single relaxed store — async-signal-safe.
#[cfg(unix)]
mod shutdown {
    use std::os::raw::c_int;
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static REQUESTED: AtomicBool = AtomicBool::new(false);

    const SIGINT: c_int = 2;
    const SIGTERM: c_int = 15;

    extern "C" {
        fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
    }

    extern "C" fn on_signal(_sig: c_int) {
        REQUESTED.store(true, Ordering::Relaxed);
    }

    pub fn install() {
        // Safety: installing an async-signal-safe handler; the prior
        // disposition (default) needs no restoration.
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    pub fn requested() -> bool {
        REQUESTED.load(Ordering::Relaxed)
    }
}

fn server_config_from(args: &Args) -> Result<ServerConfig> {
    let d = ServerConfig::default();
    Ok(ServerConfig {
        workers: args.usize_or("workers", d.workers),
        max_conns: args.usize_or("max-conns", d.max_conns),
        shed_watermark: args.usize_or("shed-watermark", d.shed_watermark),
        per_user_queue_cap: args.usize_or("user-queue-cap", d.per_user_queue_cap),
        keepalive_timeout: std::time::Duration::from_secs(args.u64_or("keepalive-secs", 30)),
        request_deadline: std::time::Duration::from_secs(args.u64_or("request-deadline-secs", 10)),
        drain_deadline: std::time::Duration::from_secs(args.u64_or("drain-secs", 5)),
        backend: match args.get_or("backend", "auto") {
            "auto" => ServerBackend::Auto,
            "evented" => ServerBackend::Evented,
            "threaded" => ServerBackend::Threaded,
            other => bail!("unknown --backend '{other}' (auto|evented|threaded)"),
        },
        rate_per_sec: args.f64_or("rate-per-sec", d.rate_per_sec),
        rate_burst: args.f64_or("rate-burst", d.rate_burst),
        // The admin surface binds loopback-only: it can clear the cache
        // and rewrite live limits, so it never rides the data bind.
        admin_bind: args
            .get("admin-port")
            .map(|p| format!("127.0.0.1:{p}")),
        sync: sync_config_from(args)?,
    })
}

/// Replication wiring from `--node-id`/`--sync-port`/`--peer`
/// (`--sync-interval-ms` tunes the anti-entropy cadence). All of it is
/// opt-in: with none of these flags, no sync threads start and the cache
/// carries no replication state.
fn sync_config_from(args: &Args) -> Result<Option<llmbridge::sync::SyncConfig>> {
    let listen_port = match args.get("sync-port") {
        Some(p) => Some(
            p.parse::<u16>()
                .map_err(|_| anyhow::anyhow!("bad --sync-port '{p}'"))?,
        ),
        None => None,
    };
    let peer = args.get("peer").map(String::from);
    let Some(node_id) = args.get("node-id") else {
        if listen_port.is_some() || peer.is_some() {
            bail!("--sync-port/--peer require --node-id (a distinct id per node)");
        }
        return Ok(None);
    };
    if listen_port.is_none() && peer.is_none() {
        // A node id alone turns on stamping (config_from passes it to the
        // bridge) without any sync wiring — legal, e.g. to pre-stamp a
        // corpus before joining a fleet.
        return Ok(None);
    }
    Ok(Some(llmbridge::sync::SyncConfig {
        node_id: node_id.to_string(),
        listen_port,
        peer,
        interval: std::time::Duration::from_millis(args.u64_or("sync-interval-ms", 5_000)),
    }))
}

fn config_from(args: &Args) -> BridgeConfig {
    BridgeConfig {
        prefetch_followups: args.flag("prefetch"),
        generation: if args.get_or("generation", "new") == "old" {
            Generation::Old
        } else {
            Generation::New
        },
        memoize: !args.flag("no-memoize"),
        quota: Default::default(),
        // Durable cache/quota/exchange state (snapshot + WAL). Off by
        // default: without --data-dir the proxy is fully in-memory.
        data_dir: args.get("data-dir").map(std::path::PathBuf::from),
        compact_wal_bytes: args.u64_or("compact-wal-bytes", 8 * 1024 * 1024),
        breaker: llmbridge::ops::BreakerConfig {
            threshold: args.usize_or("breaker-threshold", 5) as u32,
            cooldown: std::time::Duration::from_secs(args.u64_or("breaker-cooldown-secs", 10)),
        },
        engine_timeout: args
            .get("engine-timeout-secs")
            .and_then(|s| s.parse::<u64>().ok())
            .map(std::time::Duration::from_secs),
        node_id: args.get("node-id").map(String::from),
    }
}

fn service_type_from(args: &Args) -> Result<ServiceType> {
    Ok(match args.get_or("service", "model_selector") {
        "quality" => ServiceType::Quality,
        "cost" => ServiceType::Cost,
        "budget" => ServiceType::Budget {
            max_usd_per_mtok_in: args.f64_or("max-usd-per-mtok", 1.0),
        },
        "model_selector" => ServiceType::default(),
        "smart_context" => ServiceType::SmartContext {
            k: args.usize_or("k", 5),
            model: ModelId::Claude3Haiku,
        },
        "smart_cache" => ServiceType::SmartCache {
            model: ModelId::Phi3Mini,
        },
        "latency_first" => ServiceType::LatencyFirst,
        "fixed" => ServiceType::Fixed {
            model: ModelId::parse(args.get_or("model", "gpt-4o-mini"))?,
            cache: llmbridge::api::CachePolicy::Auto,
            context_k: args.usize_or("k", 0),
        },
        other => bail!("unknown --service '{other}'"),
    })
}

fn warm_cache(bridge: &Bridge) -> Result<usize> {
    let mut chunks = 0;
    for article in corpus::full_corpus() {
        let (ids, _calls) = bridge.cache().put_delegated(
            bridge.generator(),
            ModelId::Phi3Mini,
            &article.title,
            &article.text,
        )?;
        chunks += ids.len();
    }
    Ok(chunks)
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => {
            let bridge = Arc::new(Bridge::open_with(
                args.get_or("artifacts", "artifacts"),
                config_from(&args),
            )?);
            if args.flag("warm") {
                let n = warm_cache(&bridge)?;
                eprintln!("warmed cache with {n} corpus chunks");
            }
            let bind = args.get_or("bind", "127.0.0.1:8080");
            let config = server_config_from(&args)?;
            let workers = config.workers;
            let server = Server::start_with(bridge, bind, config)?;
            eprintln!(
                "llmbridge serving on {} ({workers} workers); Ctrl-C drains and stops",
                server.addr
            );
            if let Some(admin) = server.admin_addr {
                eprintln!("llmbridge admin surface on {admin}");
            }
            if let Some(addr) = server.sync_addr() {
                eprintln!("llmbridge sync listener on {addr}");
            }
            #[cfg(unix)]
            {
                shutdown::install();
                while !shutdown::requested() {
                    std::thread::sleep(std::time::Duration::from_millis(100));
                }
                eprintln!("llmbridge: signal received — draining connections, flushing WAL");
                server.stop();
                eprintln!("llmbridge: stopped cleanly");
            }
            #[cfg(not(unix))]
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "sync" => {
            // One-shot anti-entropy round against a running peer: boot
            // the local state (restore + replay), dial, exchange deltas,
            // flush the WAL, exit. The offline half of a fleet can catch
            // up without serving traffic.
            let peer = args
                .get("peer")
                .ok_or_else(|| anyhow::anyhow!("--peer required"))?;
            let config = config_from(&args);
            if config.node_id.is_none() {
                bail!("--node-id required (a distinct id per node)");
            }
            let bridge = Bridge::open_with(
                args.get_or("artifacts", "artifacts"),
                config,
            )?;
            let report = llmbridge::sync::run_once(&bridge, peer)?;
            if let Some(p) = bridge.persistence() {
                p.sync_wal()?;
            }
            println!(
                "{}",
                Json::obj(vec![
                    ("shipped", Json::num(report.shipped as f64)),
                    ("applied", Json::num(report.applied as f64)),
                    ("stale", Json::num(report.stale as f64)),
                ])
                .to_string()
            );
        }
        "ask" => {
            let prompt = args
                .get("prompt")
                .ok_or_else(|| anyhow::anyhow!("--prompt required"))?;
            let bridge = Bridge::open_with(
                args.get_or("artifacts", "artifacts"),
                config_from(&args),
            )?;
            if args.flag("warm") {
                warm_cache(&bridge)?;
            }
            let req = Request::new(
                args.get_or("user", "cli"),
                args.get_or("conversation", "cli"),
                prompt,
            )
            .service_type(service_type_from(&args)?);
            let resp = bridge.handle(req)?;
            println!("{}", resp.to_json().to_string());
        }
        "warm" => {
            let bridge = Bridge::open(args.get_or("artifacts", "artifacts"))?;
            let n = warm_cache(&bridge)?;
            println!("cached {n} chunks from {} articles", corpus::full_corpus().len());
        }
        "probe-backend" => {
            // Print a bit-exact fingerprint of the serving backend's
            // outputs (f32 bit patterns, not decimal renderings).
            // `tests/backend_determinism.rs` runs this twice in separate
            // processes and diffs the output — the cross-process
            // determinism contract of the default backend.
            use llmbridge::runtime::{tokenizer, EngineHandle};
            use llmbridge::util::fnv1a;
            let engine = EngineHandle::spawn_from_dir(args.get_or("artifacts", "artifacts"))?;
            let text = args.get_or("text", "backend determinism probe");
            println!("backend {}", engine.backend_name());
            // Which dot-product kernel the vecdb hot path dispatched to
            // (avx2/neon/scalar; LLMBRIDGE_FORCE_SCALAR=1 pins scalar).
            println!("kernel {}", llmbridge::vecdb::kernel::active_variant().name());
            let bits: Vec<String> = engine
                .embed_text(text)?
                .iter()
                .map(|v| format!("{:08x}", v.to_bits()))
                .collect();
            println!("embed {}", bits.join(""));
            let (tokens, live) = tokenizer::window(text, engine.seq_len());
            for variant in ["nano", "mini", "large"] {
                let logits = engine.lm_logits(variant, tokens.clone(), live)?;
                let mut bytes = Vec::with_capacity(logits.len() * 4);
                for v in &logits {
                    bytes.extend_from_slice(&v.to_bits().to_le_bytes());
                }
                println!("logits {variant} {:016x}", fnv1a(&bytes));
            }
            engine.shutdown();
        }
        "trace" => {
            // Print deterministic fingerprints of every synthetic
            // workload: the two seed workloads, the static corpus, and
            // each scenario trace in the standing matrix.
            // `tests/workload_determinism.rs` runs this twice in separate
            // processes and diffs the output byte for byte — same seed
            // must mean the same traffic, or every scenario number
            // becomes incomparable across machines and runs.
            use llmbridge::scenario::{default_matrix, tenants_fingerprint, ArrivalProcess, Trace};
            use llmbridge::util::fnv1a;
            use llmbridge::workload::{classroom, whatsapp};
            let seed = args.u64_or("seed", 42);

            let mut buf = String::new();
            for conv in whatsapp::dataset_d(seed) {
                for q in &conv.queries {
                    buf.push_str(&conv.user);
                    buf.push('|');
                    buf.push_str(&conv.id);
                    buf.push('|');
                    buf.push_str(&q.text);
                    buf.push('\n');
                }
            }
            println!("whatsapp {seed} {:016x}", fnv1a(buf.as_bytes()));

            buf.clear();
            for r in classroom::generate(seed, 30, 7, 500) {
                buf.push_str(&format!(
                    "{}|{}|{}|{}|{}\n",
                    r.student,
                    r.course,
                    r.day,
                    r.model.as_str(),
                    r.prompt
                ));
            }
            println!("classroom {seed} {:016x}", fnv1a(buf.as_bytes()));

            buf.clear();
            for article in corpus::full_corpus() {
                buf.push_str(&article.title);
                buf.push('|');
                buf.push_str(&article.text);
                buf.push('\n');
            }
            println!("corpus {:016x}", fnv1a(buf.as_bytes()));

            for sc in default_matrix() {
                let trace = Trace::generate(
                    seed ^ fnv1a(sc.name.as_bytes()),
                    &sc.tenants,
                    &ArrivalProcess::Poisson { rps: 80.0 },
                    std::time::Duration::from_secs(1),
                );
                println!(
                    "scenario {} {:016x} {} {:016x}",
                    sc.name,
                    trace.fingerprint,
                    trace.events.len(),
                    tenants_fingerprint(&sc.tenants)
                );
            }
        }
        "models" => {
            let rows: Vec<Json> = POOL
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("id", Json::str(m.id.as_str())),
                        ("family", Json::str(m.family)),
                        ("artifact", Json::str(m.artifact)),
                        ("capability", Json::Num(m.capability)),
                        ("usd_per_mtok_in", Json::Num(m.usd_per_mtok_in)),
                        ("usd_per_mtok_out", Json::Num(m.usd_per_mtok_out)),
                    ])
                })
                .collect();
            println!("{}", Json::Arr(rows).to_string());
        }
        _ => {
            eprintln!(
                "usage: llmbridge <serve|sync|ask|warm|models|probe-backend|trace> [--artifacts DIR] \
                 [--service TYPE] [--prompt TEXT] [--bind ADDR] [--workers N] \
                 [--generation old|new] [--prefetch] [--warm] \
                 [--data-dir DIR] [--compact-wal-bytes N] \
                 [--backend auto|evented|threaded] [--max-conns N] [--shed-watermark N] \
                 [--user-queue-cap N] [--keepalive-secs N] [--drain-secs N] \
                 [--admin-port N] [--rate-per-sec R] [--rate-burst B] \
                 [--engine-timeout-secs N] [--breaker-threshold N] \
                 [--breaker-cooldown-secs N] [--node-id ID] [--sync-port N] \
                 [--peer HOST:PORT] [--sync-interval-ms N]"
            );
        }
    }
    Ok(())
}
