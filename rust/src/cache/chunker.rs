//! Document chunking + key generation for the delegated PUT path (§3.5 and
//! the §5.2 RAG workflows).
//!
//! Handles the "structural variability" the classroom deployment hit:
//! FAQ-style documents are segmented around Q/A pairs, sectioned documents
//! around headers, and plain prose into sentence groups. For each chunk the
//! cache-LLM derives extra keys: keywords, hypothetical questions, a
//! summary, and the list of facts present in the chunk.

/// A document chunk with its derived keys.
#[derive(Clone, Debug)]
pub struct Chunk {
    pub text: String,
    pub keywords: Vec<String>,
    pub hypothetical_questions: Vec<String>,
    pub summary: String,
    pub facts: Vec<String>,
}

const STOPWORDS: &[&str] = &[
    "the", "a", "an", "of", "in", "on", "to", "is", "are", "was", "were",
    "and", "or", "for", "with", "it", "its", "as", "by", "at", "from",
    "that", "this", "be", "has", "have", "had", "about", "which", "their",
    "known", "also", "most", "more", "one", "two", "can", "will",
    // Question-pattern words: keyword extraction must surface the *topic*
    // of a prompt, not its interrogative scaffolding (prefetch keys, §5.1).
    "tell", "me", "please", "give", "share", "know", "say", "explain",
    "what", "should", "how", "why", "when", "did", "does", "do", "i", "my",
    "you", "your", "we", "they", "them", "who", "where", "would", "could",
    "these", "those", "there", "some", "any", "much", "many", "every",
    "people", "person", "day", "days", "year", "years", "week", "today",
];

fn is_stopword(w: &str) -> bool {
    STOPWORDS.contains(&w) || w.len() <= 2 || w.chars().all(|c| c.is_ascii_digit())
}

/// Split text into sentences (., !, ? and newline boundaries). A period
/// followed by a digit is treated as a decimal point ("5.2 million"), not a
/// boundary.
pub fn sentences(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let chars: Vec<char> = text.chars().collect();
    for (i, &ch) in chars.iter().enumerate() {
        cur.push(ch);
        let decimal_point =
            ch == '.' && chars.get(i + 1).map(|c| c.is_ascii_digit()).unwrap_or(false);
        if matches!(ch, '.' | '!' | '?' | '\n') && !decimal_point {
            let trimmed = cur.trim();
            if !trimmed.is_empty() {
                out.push(trimmed.to_string());
            }
            cur.clear();
        }
    }
    let trimmed = cur.trim();
    if !trimmed.is_empty() {
        out.push(trimmed.to_string());
    }
    out
}

/// Document structure detected for chunking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DocStructure {
    /// `Q:`/`A:` pairs.
    Faq,
    /// `## ` section headers.
    Sectioned,
    /// Plain prose.
    Prose,
}

pub fn detect_structure(text: &str) -> DocStructure {
    let lines: Vec<&str> = text.lines().collect();
    let q_lines = lines.iter().filter(|l| l.trim_start().starts_with("Q:")).count();
    if q_lines >= 2 {
        return DocStructure::Faq;
    }
    let headers = lines.iter().filter(|l| l.trim_start().starts_with("## ")).count();
    if headers >= 2 {
        return DocStructure::Sectioned;
    }
    DocStructure::Prose
}

/// Split a document into chunk texts per its structure. `max_words` bounds
/// prose chunks.
pub fn split_document(text: &str, max_words: usize) -> Vec<String> {
    match detect_structure(text) {
        DocStructure::Faq => {
            let mut chunks = Vec::new();
            let mut cur = String::new();
            for line in text.lines() {
                if line.trim_start().starts_with("Q:") && !cur.trim().is_empty() {
                    chunks.push(cur.trim().to_string());
                    cur.clear();
                }
                cur.push_str(line);
                cur.push('\n');
            }
            if !cur.trim().is_empty() {
                chunks.push(cur.trim().to_string());
            }
            chunks
        }
        DocStructure::Sectioned => {
            let mut chunks = Vec::new();
            let mut cur = String::new();
            for line in text.lines() {
                if line.trim_start().starts_with("## ") && !cur.trim().is_empty() {
                    chunks.push(cur.trim().to_string());
                    cur.clear();
                }
                cur.push_str(line);
                cur.push('\n');
            }
            if !cur.trim().is_empty() {
                chunks.push(cur.trim().to_string());
            }
            chunks
        }
        DocStructure::Prose => {
            let mut chunks = Vec::new();
            let mut cur = String::new();
            let mut words_in_cur = 0;
            for s in sentences(text) {
                let wc = crate::runtime::tokenizer::words(&s).len();
                if words_in_cur > 0 && words_in_cur + wc > max_words {
                    chunks.push(cur.trim().to_string());
                    cur.clear();
                    words_in_cur = 0;
                }
                cur.push_str(&s);
                cur.push(' ');
                words_in_cur += wc;
            }
            if !cur.trim().is_empty() {
                chunks.push(cur.trim().to_string());
            }
            chunks
        }
    }
}

/// Top-n keywords by term frequency, stopwords removed, first-seen order
/// for ties (deterministic).
pub fn keywords(text: &str, n: usize) -> Vec<String> {
    let ws = crate::runtime::tokenizer::words(text);
    let mut counts: Vec<(String, usize)> = Vec::new();
    for w in ws {
        if is_stopword(&w) {
            continue;
        }
        if let Some(e) = counts.iter_mut().find(|(k, _)| *k == w) {
            e.1 += 1;
        } else {
            counts.push((w, 1));
        }
    }
    counts.sort_by(|a, b| b.1.cmp(&a.1));
    counts.truncate(n);
    counts.into_iter().map(|(k, _)| k).collect()
}

/// Sentences likely to carry factual content (contain digits or copulas) —
/// the "list of facts" keys §3.5 generates for factual workloads.
pub fn facts(text: &str) -> Vec<String> {
    sentences(text)
        .into_iter()
        .filter(|s| {
            let lower = s.to_lowercase();
            s.chars().any(|c| c.is_ascii_digit())
                || lower.contains(" is ")
                || lower.contains(" are ")
                || lower.contains(" was ")
        })
        .collect()
}

/// Template-generated hypothetical questions a chunk could answer.
pub fn hypothetical_questions(chunk: &str, kws: &[String]) -> Vec<String> {
    let mut qs = Vec::new();
    if let Some(k) = kws.first() {
        qs.push(format!("what is {k}"));
        qs.push(format!("tell me about {k}"));
    }
    if kws.len() >= 2 {
        qs.push(format!("how does {} relate to {}", kws[0], kws[1]));
    }
    if facts(chunk).iter().any(|f| f.chars().any(|c| c.is_ascii_digit())) {
        if let Some(k) = kws.first() {
            qs.push(format!("how many {k}"));
        }
    }
    qs
}

/// Full delegated-PUT chunking: structure-aware split + per-chunk keys.
/// The `summary_of` callback lets the caller route summary generation
/// through the cache-LLM (a real pool call); tests pass a pure closure.
pub fn chunk_document(
    text: &str,
    max_words: usize,
    mut summary_of: impl FnMut(&str) -> String,
) -> Vec<Chunk> {
    split_document(text, max_words)
        .into_iter()
        .map(|chunk_text| {
            let kws = keywords(&chunk_text, 6);
            let hq = hypothetical_questions(&chunk_text, &kws);
            let fs = facts(&chunk_text);
            let summary = summary_of(&chunk_text);
            Chunk {
                text: chunk_text,
                keywords: kws,
                hypothetical_questions: hq,
                summary,
                facts: fs,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const PROSE: &str = "Khartoum is the capital of Sudan. It lies at the \
        confluence of the White Nile and Blue Nile. The city has a population \
        of about 5.2 million people. Khartoum is known for its markets. The \
        city hosts the national museum. Traders come from across the region.";

    const FAQ: &str = "Q: How do I reset my password?\nA: Use the settings \
        page.\nQ: How do I contact support?\nA: Email support@example.com.\n\
        Q: What are the office hours?\nA: 9am to 5pm weekdays.";

    const SECTIONED: &str = "## History\nThe university was founded in 1902. \
        It grew quickly.\n## Campus\nThe campus covers 140 acres. It has \
        12 libraries.\n## Athletics\nThe teams are called the Jumbos.";

    #[test]
    fn structure_detection() {
        assert_eq!(detect_structure(PROSE), DocStructure::Prose);
        assert_eq!(detect_structure(FAQ), DocStructure::Faq);
        assert_eq!(detect_structure(SECTIONED), DocStructure::Sectioned);
    }

    #[test]
    fn faq_chunks_are_qa_pairs() {
        let chunks = split_document(FAQ, 40);
        assert_eq!(chunks.len(), 3);
        assert!(chunks[0].starts_with("Q: How do I reset"));
        assert!(chunks[0].contains("A:"));
    }

    #[test]
    fn sectioned_chunks_follow_headers() {
        let chunks = split_document(SECTIONED, 40);
        assert_eq!(chunks.len(), 3);
        assert!(chunks[1].starts_with("## Campus"));
    }

    #[test]
    fn prose_chunks_bounded() {
        let chunks = split_document(PROSE, 20);
        assert!(chunks.len() >= 2);
        for c in &chunks {
            // A single sentence may exceed the budget, but grouped chunks
            // stay near it.
            assert!(crate::runtime::tokenizer::words(c).len() <= 30);
        }
    }

    #[test]
    fn keywords_skip_stopwords() {
        let kws = keywords(PROSE, 5);
        assert!(kws.contains(&"khartoum".to_string()));
        assert!(!kws.iter().any(|k| k == "the" || k == "is"));
    }

    #[test]
    fn facts_catch_numbers_and_copulas() {
        let fs = facts(PROSE);
        assert!(fs.iter().any(|f| f.contains("5.2 million")));
        assert!(fs.iter().any(|f| f.contains("capital of Sudan")));
    }

    #[test]
    fn hypothetical_questions_generated() {
        let kws = keywords(PROSE, 4);
        let qs = hypothetical_questions(PROSE, &kws);
        assert!(qs.iter().any(|q| q.starts_with("what is ")));
        assert!(qs.len() >= 2);
    }

    #[test]
    fn chunk_document_end_to_end() {
        let chunks = chunk_document(PROSE, 25, |c| {
            format!("summary: {}", &c[..c.len().min(20)])
        });
        assert!(!chunks.is_empty());
        for c in &chunks {
            assert!(!c.keywords.is_empty());
            assert!(c.summary.starts_with("summary:"));
        }
    }

    #[test]
    fn empty_document() {
        assert!(split_document("", 40).is_empty());
        assert!(keywords("", 5).is_empty());
    }
}
