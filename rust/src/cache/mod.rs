//! Semantic cache (paper §3.5): a typed-key cache over the vector database.
//!
//! Unlike an HTTP cache keyed by a URL hash, one cached *object* (an LLM
//! interaction or an external document chunk) can be indexed under many
//! *keys* of different [`CachedType`]s — the prompt, the response, chunk
//! text, hypothetical questions, keywords, summaries, extracted facts.
//!
//! * **PUT** — explicit keys, or *delegated*: the cache-LLM chunks complex
//!   objects and derives keys per chunk (see [`chunker`]).
//! * **GET** — low-level filtered similarity lookup, or *delegated*
//!   ("SmartCache"): retrieve top-k across types, let a small model decide
//!   relevance, and ground its reply in the cached content.
//! * **Exact path** — the WhatsApp deployment's prefetch buttons (§5.1) use
//!   exact-match entries to mask latency.
//!
//! ## Concurrency model
//!
//! The cache is read-mostly and designed so concurrent GETs never
//! serialize on each other:
//!
//! * The vector index sits behind one `RwLock`; `search` takes a read
//!   lock, only key insertion takes the write lock (briefly, for the whole
//!   key batch of a PUT).
//! * The `keys`, `objects`, and `exact` maps are split into
//!   [`SHARD_COUNT`] hash shards, each behind its own `RwLock`. Lookups
//!   take the touched shard's read lock; PUTs write-lock only the shard
//!   the id/key hashes to.
//! * Lock order is always index → keys → objects, one guard held at a
//!   time (no nesting), so there is no deadlock shape.
//! * PUT embeds all typed keys with one [`EngineHandle::embed_batch`]
//!   round-trip instead of a serial `embed_text` per key.
//!
//! [`EngineHandle::embed_batch`]: crate::runtime::EngineHandle::embed_batch

pub mod chunker;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use anyhow::Result;

use crate::models::generator::{Completion, Generator};
use crate::models::pricing::ModelId;
use crate::models::quality::{classify, QueryTraits};
use crate::vecdb::flat::FlatIndex;
use crate::vecdb::{Metric, VectorIndex};

/// Number of hash shards for the key/object/exact maps. Power of two so
/// shard selection is a mask; 16 is comfortably above the core counts the
/// proxy targets, keeping write collisions rare.
const SHARD_COUNT: usize = 16;

/// GET over-fetches the index beyond `filter.k`, because type filtering
/// and per-object dedup both shrink the raw hit list.
const OVERFETCH_PER_K: usize = 8;
/// Constant floor added on top of the per-k over-fetch.
const OVERFETCH_BASE: usize = 16;

/// What a key embedding was derived from (§3.5's "cached types").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CachedType {
    Prompt,
    Response,
    Chunk,
    HypotheticalQuestion,
    Keyword,
    Summary,
    Fact,
}

impl CachedType {
    pub fn as_str(&self) -> &'static str {
        match self {
            CachedType::Prompt => "prompt",
            CachedType::Response => "response",
            CachedType::Chunk => "chunk",
            CachedType::HypotheticalQuestion => "hypothetical_question",
            CachedType::Keyword => "keyword",
            CachedType::Summary => "summary",
            CachedType::Fact => "fact",
        }
    }
}

/// A cached object: either a past LLM interaction or external content.
#[derive(Clone, Debug)]
pub struct CacheObject {
    pub id: u64,
    /// The content served on a hit (response text / chunk text).
    pub text: String,
    /// Source prompt for interactions; title for documents.
    pub origin: String,
    pub is_document: bool,
}

/// One retrieval hit.
#[derive(Clone, Debug)]
pub struct CacheHit {
    pub object: CacheObject,
    pub matched_type: CachedType,
    pub score: f64,
}

/// GET-path filter (§3.5): restrict by cached type, similarity threshold,
/// and result count.
#[derive(Clone, Debug)]
pub struct GetFilter {
    pub types: Option<Vec<CachedType>>,
    pub min_score: f64,
    pub k: usize,
}

impl Default for GetFilter {
    fn default() -> Self {
        GetFilter {
            types: None,
            min_score: 0.0,
            k: 4,
        }
    }
}

struct KeyEntry {
    object_id: u64,
    ctype: CachedType,
}

/// Outcome of the delegated GET (SmartCache).
#[derive(Debug)]
pub struct SmartCacheOutcome {
    /// Whether cached content was deemed relevant and used.
    pub used: bool,
    /// The grounded response (present when `used`).
    pub response: Option<String>,
    /// The winning hit, if any retrieval happened.
    pub hit: Option<CacheHit>,
    /// Real cache-LLM calls made (billed to the request).
    pub llm_calls: Vec<Completion>,
}

pub struct SemanticCache {
    index: RwLock<FlatIndex>,
    keys: Vec<RwLock<HashMap<u64, KeyEntry>>>,
    objects: Vec<RwLock<HashMap<u64, CacheObject>>>,
    exact: Vec<RwLock<HashMap<String, String>>>,
    next_id: AtomicU64,
    /// Relevance threshold the SmartCache ground truth uses.
    pub relevance_threshold: f64,
}

impl SemanticCache {
    pub fn new(embed_dim: usize) -> SemanticCache {
        SemanticCache {
            index: RwLock::new(FlatIndex::new(embed_dim, Metric::Cosine)),
            keys: (0..SHARD_COUNT).map(|_| RwLock::new(HashMap::new())).collect(),
            objects: (0..SHARD_COUNT).map(|_| RwLock::new(HashMap::new())).collect(),
            exact: (0..SHARD_COUNT).map(|_| RwLock::new(HashMap::new())).collect(),
            next_id: AtomicU64::new(1),
            relevance_threshold: 0.40,
        }
    }

    fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    #[inline]
    fn shard_of(id: u64) -> usize {
        // Ids are sequential, so the low bits alone stripe evenly.
        (id as usize) & (SHARD_COUNT - 1)
    }

    #[inline]
    fn shard_of_str(s: &str) -> usize {
        (crate::util::fnv1a(s.as_bytes()) as usize) & (SHARD_COUNT - 1)
    }

    pub fn len_objects(&self) -> usize {
        self.objects.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn len_keys(&self) -> usize {
        self.keys.iter().map(|s| s.read().unwrap().len()).sum()
    }

    // ------------------------------------------------------------- exact

    /// Normalized exact-match key (prefetch buttons).
    fn exact_key(prompt: &str) -> String {
        crate::runtime::tokenizer::words(prompt).join(" ")
    }

    pub fn put_exact(&self, prompt: &str, response: &str) {
        let key = Self::exact_key(prompt);
        self.exact[Self::shard_of_str(&key)]
            .write()
            .unwrap()
            .insert(key, response.to_string());
    }

    pub fn get_exact(&self, prompt: &str) -> Option<String> {
        let key = Self::exact_key(prompt);
        self.exact[Self::shard_of_str(&key)]
            .read()
            .unwrap()
            .get(&key)
            .cloned()
    }

    // --------------------------------------------------------------- PUT

    /// Explicit PUT (§3.5): store `text` under the supplied typed keys.
    /// All keys are embedded via one batched engine round-trip.
    pub fn put(
        &self,
        generator: &Generator,
        text: &str,
        origin: &str,
        is_document: bool,
        keys: &[(CachedType, String)],
    ) -> Result<u64> {
        let object_id = self.fresh_id();
        self.objects[Self::shard_of(object_id)].write().unwrap().insert(
            object_id,
            CacheObject {
                id: object_id,
                text: text.to_string(),
                origin: origin.to_string(),
                is_document,
            },
        );
        let live: Vec<&(CachedType, String)> = keys
            .iter()
            .filter(|(_, key_text)| !key_text.trim().is_empty())
            .collect();
        let texts: Vec<&str> = live.iter().map(|pair| pair.1.as_str()).collect();
        let embs = generator.engine().embed_batch(&texts)?;
        let mut entries: Vec<(u64, CachedType)> = Vec::with_capacity(live.len());
        {
            // One write-lock acquisition for the whole key batch.
            let mut index = self.index.write().unwrap();
            for (pair, emb) in live.iter().zip(embs.iter()) {
                let key_id = self.fresh_id();
                index.insert(key_id, emb)?;
                entries.push((key_id, pair.0));
            }
        }
        for (key_id, ctype) in entries {
            self.keys[Self::shard_of(key_id)]
                .write()
                .unwrap()
                .insert(key_id, KeyEntry { object_id, ctype });
        }
        Ok(object_id)
    }

    /// Cache a full interaction under prompt + response keys (the §3.5
    /// B-tree example: future prompts may match the *response*).
    pub fn put_interaction(
        &self,
        generator: &Generator,
        prompt: &str,
        response: &str,
    ) -> Result<u64> {
        self.put(
            generator,
            response,
            prompt,
            false,
            &[
                (CachedType::Prompt, prompt.to_string()),
                (CachedType::Response, response.to_string()),
            ],
        )
    }

    /// Delegated PUT (§3.5): the cache-LLM chunks the document and derives
    /// keys (chunk text, keywords, hypothetical questions, summary, facts).
    /// Returns (object ids, cache-LLM calls made).
    pub fn put_delegated(
        &self,
        generator: &Generator,
        cache_llm: ModelId,
        title: &str,
        document: &str,
    ) -> Result<(Vec<u64>, Vec<Completion>)> {
        let mut calls = Vec::new();
        // One real cache-LLM call to "drive" chunk summarization; the
        // lexical summary itself is head-words (deterministic).
        let chunks = chunker::chunk_document(document, 48, |chunk| {
            let head: Vec<String> = crate::runtime::tokenizer::words(chunk)
                .into_iter()
                .take(10)
                .collect();
            head.join(" ")
        });
        if !chunks.is_empty() {
            calls.push(generator.generate(
                cache_llm,
                &format!("derive cache keys for document titled {title}"),
                Some(8),
            )?);
        }
        let mut ids = Vec::new();
        for chunk in &chunks {
            let mut keys: Vec<(CachedType, String)> =
                vec![(CachedType::Chunk, chunk.text.clone())];
            for q in &chunk.hypothetical_questions {
                keys.push((CachedType::HypotheticalQuestion, q.clone()));
            }
            if !chunk.keywords.is_empty() {
                keys.push((CachedType::Keyword, chunk.keywords.join(" ")));
            }
            keys.push((CachedType::Summary, chunk.summary.clone()));
            for f in &chunk.facts {
                keys.push((CachedType::Fact, f.clone()));
            }
            ids.push(self.put(generator, &chunk.text, title, true, &keys)?);
        }
        Ok((ids, calls))
    }

    // --------------------------------------------------------------- GET

    /// Low-level GET: top-k typed-key similarity search.
    ///
    /// Over-fetches `k * OVERFETCH_PER_K + OVERFETCH_BASE` raw keys, then
    /// widens (doubling) if type filtering and per-object dedup starved the
    /// result set below `k` while unseen keys remain.
    pub fn get(
        &self,
        generator: &Generator,
        query: &str,
        filter: &GetFilter,
    ) -> Result<Vec<CacheHit>> {
        let emb = generator.engine().embed_text(query)?;
        let mut fetch = filter.k * OVERFETCH_PER_K + OVERFETCH_BASE;
        loop {
            let (raw, total) = {
                let index = self.index.read().unwrap();
                (
                    index.search(&emb, fetch, filter.min_score as f32),
                    index.len(),
                )
            };
            // Fewer raw hits than asked for means everything above
            // min_score has been seen; fetch >= total means the whole
            // index was scanned.
            let exhausted = raw.len() < fetch || fetch >= total;
            let hits = self.resolve_hits(raw, filter);
            if hits.len() >= filter.k || exhausted {
                return Ok(hits);
            }
            fetch *= 2;
        }
    }

    /// Post-filter raw index hits: map key → object, apply the type
    /// filter, keep the best score per object, sort, truncate to `k`.
    fn resolve_hits(&self, raw: Vec<crate::vecdb::Hit>, filter: &GetFilter) -> Vec<CacheHit> {
        let mut best: HashMap<u64, CacheHit> = HashMap::new();
        for hit in raw {
            let entry = {
                let shard = self.keys[Self::shard_of(hit.id)].read().unwrap();
                shard.get(&hit.id).map(|e| (e.object_id, e.ctype))
            };
            let Some((object_id, ctype)) = entry else {
                continue;
            };
            if let Some(types) = &filter.types {
                if !types.contains(&ctype) {
                    continue;
                }
            }
            let obj = {
                let shard = self.objects[Self::shard_of(object_id)].read().unwrap();
                shard.get(&object_id).cloned()
            };
            let Some(obj) = obj else {
                continue;
            };
            let candidate = CacheHit {
                object: obj,
                matched_type: ctype,
                score: hit.score as f64,
            };
            match best.get(&object_id) {
                Some(prev) if prev.score >= candidate.score => {}
                _ => {
                    best.insert(object_id, candidate);
                }
            }
        }
        let mut hits: Vec<CacheHit> = best.into_values().collect();
        hits.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        hits.truncate(filter.k);
        hits
    }

    /// Delegated GET — "SmartCache" (§3.5): retrieve top-k across all
    /// cached types, let the cache-LLM judge relevance, and if relevant,
    /// generate a reply grounded in the cached content.
    pub fn smart_get(
        &self,
        generator: &Generator,
        cache_llm: ModelId,
        query: &str,
        traits: &QueryTraits,
    ) -> Result<SmartCacheOutcome> {
        let hits = self.get(generator, query, &GetFilter::default())?;
        let mut calls = Vec::new();
        let Some(top) = hits.first().cloned() else {
            return Ok(SmartCacheOutcome {
                used: false,
                response: None,
                hit: None,
                llm_calls: calls,
            });
        };
        // Real relevance-check call (label-style output).
        calls.push(generator.classify_call(
            cache_llm,
            &format!(
                "is this cached content relevant to the query? query: {query} \
                 content: {}",
                top.object.text
            ),
        )?);
        // Delegated decision: ground truth is "similarity clears the bar";
        // the small model gets it right per its calibrated accuracy.
        let truth_relevant = top.score >= self.relevance_threshold;
        let says_relevant =
            classify(truth_relevant, cache_llm.spec().capability, &traits.id, 7);
        if !says_relevant {
            return Ok(SmartCacheOutcome {
                used: false,
                response: None,
                hit: Some(top),
                llm_calls: calls,
            });
        }
        // Grounded generation: cache-LLM rewrites cached content for the
        // query (§3.5 response modes 2/3).
        let gen = generator.generate(
            cache_llm,
            &format!(
                "answer using this cached information. query: {query} \
                 information: {}",
                top.object.text
            ),
            Some(20),
        )?;
        let response = format!("{} {}", top.object.text, gen.text);
        calls.push(gen);
        Ok(SmartCacheOutcome {
            used: true,
            response: Some(response),
            hit: Some(top),
            llm_calls: calls,
        })
    }

    /// Drop everything (tests / benchmarks).
    pub fn clear(&self) {
        {
            // Single guarded scope: read dim and swap in the fresh index
            // under one write lock (the seed locked the index twice in one
            // statement — a latent deadlock shape).
            let mut index = self.index.write().unwrap();
            let dim = index.dim();
            *index = FlatIndex::new(dim, Metric::Cosine);
        }
        for shard in &self.keys {
            shard.write().unwrap().clear();
        }
        for shard in &self.objects {
            shard.write().unwrap().clear();
        }
        for shard in &self.exact {
            shard.write().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn exact_path_normalizes() {
        let c = SemanticCache::new(8);
        c.put_exact("What is the  Capital of Sudan?", "Khartoum");
        assert_eq!(
            c.get_exact("what is the capital of sudan"),
            Some("Khartoum".to_string())
        );
        assert_eq!(c.get_exact("unrelated"), None);
    }

    #[test]
    fn cached_type_names_unique() {
        let all = [
            CachedType::Prompt,
            CachedType::Response,
            CachedType::Chunk,
            CachedType::HypotheticalQuestion,
            CachedType::Keyword,
            CachedType::Summary,
            CachedType::Fact,
        ];
        let names: std::collections::HashSet<&str> =
            all.iter().map(|t| t.as_str()).collect();
        assert_eq!(names.len(), all.len());
    }

    #[test]
    fn get_filter_default() {
        let f = GetFilter::default();
        assert_eq!(f.k, 4);
        assert!(f.types.is_none());
    }

    /// Engine-free concurrency smoke over the sharded exact path: mixed
    /// readers/writers across every shard, no deadlock, consistent counts.
    #[test]
    fn exact_path_concurrent_smoke() {
        let c = Arc::new(SemanticCache::new(8));
        let threads = 8;
        let per_thread = 200;
        std::thread::scope(|s| {
            for t in 0..threads {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for i in 0..per_thread {
                        let prompt = format!("thread {t} prompt number {i}");
                        c.put_exact(&prompt, "resp");
                        assert_eq!(c.get_exact(&prompt).as_deref(), Some("resp"));
                        // Cross-shard reads of other threads' keys.
                        let _ = c.get_exact(&format!("thread {} prompt number {i}", (t + 1) % threads));
                    }
                });
            }
        });
        let total: usize = c.exact.iter().map(|s| s.read().unwrap().len()).sum();
        assert_eq!(total, threads * per_thread);
        // Clear under the new guarded scopes empties every shard.
        c.clear();
        assert_eq!(c.get_exact("thread 0 prompt number 0"), None);
        assert_eq!(c.len_keys(), 0);
        assert_eq!(c.len_objects(), 0);
    }

    #[test]
    fn exact_shards_stripe() {
        // Distinct normalized prompts should not all land in one shard.
        let c = SemanticCache::new(8);
        for i in 0..64 {
            c.put_exact(&format!("prompt variant {i}"), "r");
        }
        let populated = c.exact.iter().filter(|s| !s.read().unwrap().is_empty()).count();
        assert!(populated > SHARD_COUNT / 2, "populated={populated}");
    }
}
