//! Semantic cache (paper §3.5): a typed-key cache over the vector database.
//!
//! Unlike an HTTP cache keyed by a URL hash, one cached *object* (an LLM
//! interaction or an external document chunk) can be indexed under many
//! *keys* of different [`CachedType`]s — the prompt, the response, chunk
//! text, hypothetical questions, keywords, summaries, extracted facts.
//!
//! * **PUT** — explicit keys, or *delegated*: the cache-LLM chunks complex
//!   objects and derives keys per chunk (see [`chunker`]).
//! * **GET** — low-level filtered similarity lookup, or *delegated*
//!   ("SmartCache"): retrieve top-k across types, let a small model decide
//!   relevance, and ground its reply in the cached content.
//! * **Exact path** — the WhatsApp deployment's prefetch buttons (§5.1) use
//!   exact-match entries to mask latency.

pub mod chunker;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::models::generator::{Completion, Generator};
use crate::models::pricing::ModelId;
use crate::models::quality::{classify, QueryTraits};
use crate::vecdb::flat::FlatIndex;
use crate::vecdb::{Metric, VectorIndex};

/// What a key embedding was derived from (§3.5's "cached types").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CachedType {
    Prompt,
    Response,
    Chunk,
    HypotheticalQuestion,
    Keyword,
    Summary,
    Fact,
}

impl CachedType {
    pub fn as_str(&self) -> &'static str {
        match self {
            CachedType::Prompt => "prompt",
            CachedType::Response => "response",
            CachedType::Chunk => "chunk",
            CachedType::HypotheticalQuestion => "hypothetical_question",
            CachedType::Keyword => "keyword",
            CachedType::Summary => "summary",
            CachedType::Fact => "fact",
        }
    }
}

/// A cached object: either a past LLM interaction or external content.
#[derive(Clone, Debug)]
pub struct CacheObject {
    pub id: u64,
    /// The content served on a hit (response text / chunk text).
    pub text: String,
    /// Source prompt for interactions; title for documents.
    pub origin: String,
    pub is_document: bool,
}

/// One retrieval hit.
#[derive(Clone, Debug)]
pub struct CacheHit {
    pub object: CacheObject,
    pub matched_type: CachedType,
    pub score: f64,
}

/// GET-path filter (§3.5): restrict by cached type, similarity threshold,
/// and result count.
#[derive(Clone, Debug)]
pub struct GetFilter {
    pub types: Option<Vec<CachedType>>,
    pub min_score: f64,
    pub k: usize,
}

impl Default for GetFilter {
    fn default() -> Self {
        GetFilter {
            types: None,
            min_score: 0.0,
            k: 4,
        }
    }
}

struct KeyEntry {
    object_id: u64,
    ctype: CachedType,
}

/// Outcome of the delegated GET (SmartCache).
#[derive(Debug)]
pub struct SmartCacheOutcome {
    /// Whether cached content was deemed relevant and used.
    pub used: bool,
    /// The grounded response (present when `used`).
    pub response: Option<String>,
    /// The winning hit, if any retrieval happened.
    pub hit: Option<CacheHit>,
    /// Real cache-LLM calls made (billed to the request).
    pub llm_calls: Vec<Completion>,
}

pub struct SemanticCache {
    index: Mutex<FlatIndex>,
    keys: Mutex<HashMap<u64, KeyEntry>>,
    objects: Mutex<HashMap<u64, CacheObject>>,
    exact: Mutex<HashMap<String, String>>,
    next_id: AtomicU64,
    /// Relevance threshold the SmartCache ground truth uses.
    pub relevance_threshold: f64,
}

impl SemanticCache {
    pub fn new(embed_dim: usize) -> SemanticCache {
        SemanticCache {
            index: Mutex::new(FlatIndex::new(embed_dim, Metric::Cosine)),
            keys: Mutex::new(HashMap::new()),
            objects: Mutex::new(HashMap::new()),
            exact: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            relevance_threshold: 0.40,
        }
    }

    fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn len_objects(&self) -> usize {
        self.objects.lock().unwrap().len()
    }

    pub fn len_keys(&self) -> usize {
        self.keys.lock().unwrap().len()
    }

    // ------------------------------------------------------------- exact

    /// Normalized exact-match key (prefetch buttons).
    fn exact_key(prompt: &str) -> String {
        crate::runtime::tokenizer::words(prompt).join(" ")
    }

    pub fn put_exact(&self, prompt: &str, response: &str) {
        self.exact
            .lock()
            .unwrap()
            .insert(Self::exact_key(prompt), response.to_string());
    }

    pub fn get_exact(&self, prompt: &str) -> Option<String> {
        self.exact.lock().unwrap().get(&Self::exact_key(prompt)).cloned()
    }

    // --------------------------------------------------------------- PUT

    /// Explicit PUT (§3.5): store `text` under the supplied typed keys.
    /// Keys are embedded via the engine behind `generator`.
    pub fn put(
        &self,
        generator: &Generator,
        text: &str,
        origin: &str,
        is_document: bool,
        keys: &[(CachedType, String)],
    ) -> Result<u64> {
        let object_id = self.fresh_id();
        self.objects.lock().unwrap().insert(
            object_id,
            CacheObject {
                id: object_id,
                text: text.to_string(),
                origin: origin.to_string(),
                is_document,
            },
        );
        for (ctype, key_text) in keys {
            if key_text.trim().is_empty() {
                continue;
            }
            let emb = generator.engine().embed_text(key_text)?;
            let key_id = self.fresh_id();
            self.index.lock().unwrap().insert(key_id, &emb)?;
            self.keys.lock().unwrap().insert(
                key_id,
                KeyEntry {
                    object_id,
                    ctype: *ctype,
                },
            );
        }
        Ok(object_id)
    }

    /// Cache a full interaction under prompt + response keys (the §3.5
    /// B-tree example: future prompts may match the *response*).
    pub fn put_interaction(
        &self,
        generator: &Generator,
        prompt: &str,
        response: &str,
    ) -> Result<u64> {
        self.put(
            generator,
            response,
            prompt,
            false,
            &[
                (CachedType::Prompt, prompt.to_string()),
                (CachedType::Response, response.to_string()),
            ],
        )
    }

    /// Delegated PUT (§3.5): the cache-LLM chunks the document and derives
    /// keys (chunk text, keywords, hypothetical questions, summary, facts).
    /// Returns (object ids, cache-LLM calls made).
    pub fn put_delegated(
        &self,
        generator: &Generator,
        cache_llm: ModelId,
        title: &str,
        document: &str,
    ) -> Result<(Vec<u64>, Vec<Completion>)> {
        let mut calls = Vec::new();
        // One real cache-LLM call to "drive" chunk summarization; the
        // lexical summary itself is head-words (deterministic).
        let chunks = chunker::chunk_document(document, 48, |chunk| {
            let head: Vec<String> = crate::runtime::tokenizer::words(chunk)
                .into_iter()
                .take(10)
                .collect();
            head.join(" ")
        });
        if !chunks.is_empty() {
            calls.push(generator.generate(
                cache_llm,
                &format!("derive cache keys for document titled {title}"),
                Some(8),
            )?);
        }
        let mut ids = Vec::new();
        for chunk in &chunks {
            let mut keys: Vec<(CachedType, String)> =
                vec![(CachedType::Chunk, chunk.text.clone())];
            for q in &chunk.hypothetical_questions {
                keys.push((CachedType::HypotheticalQuestion, q.clone()));
            }
            if !chunk.keywords.is_empty() {
                keys.push((CachedType::Keyword, chunk.keywords.join(" ")));
            }
            keys.push((CachedType::Summary, chunk.summary.clone()));
            for f in &chunk.facts {
                keys.push((CachedType::Fact, f.clone()));
            }
            ids.push(self.put(generator, &chunk.text, title, true, &keys)?);
        }
        Ok((ids, calls))
    }

    // --------------------------------------------------------------- GET

    /// Low-level GET: top-k typed-key similarity search.
    pub fn get(
        &self,
        generator: &Generator,
        query: &str,
        filter: &GetFilter,
    ) -> Result<Vec<CacheHit>> {
        let emb = generator.engine().embed_text(query)?;
        // Over-fetch then post-filter by type, keeping best score per object.
        let raw = self
            .index
            .lock()
            .unwrap()
            .search(&emb, filter.k * 8 + 16, filter.min_score as f32);
        let keys = self.keys.lock().unwrap();
        let objects = self.objects.lock().unwrap();
        let mut best: HashMap<u64, CacheHit> = HashMap::new();
        for hit in raw {
            let Some(entry) = keys.get(&hit.id) else {
                continue;
            };
            if let Some(types) = &filter.types {
                if !types.contains(&entry.ctype) {
                    continue;
                }
            }
            let Some(obj) = objects.get(&entry.object_id) else {
                continue;
            };
            let candidate = CacheHit {
                object: obj.clone(),
                matched_type: entry.ctype,
                score: hit.score as f64,
            };
            match best.get(&entry.object_id) {
                Some(prev) if prev.score >= candidate.score => {}
                _ => {
                    best.insert(entry.object_id, candidate);
                }
            }
        }
        let mut hits: Vec<CacheHit> = best.into_values().collect();
        hits.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        hits.truncate(filter.k);
        Ok(hits)
    }

    /// Delegated GET — "SmartCache" (§3.5): retrieve top-k across all
    /// cached types, let the cache-LLM judge relevance, and if relevant,
    /// generate a reply grounded in the cached content.
    pub fn smart_get(
        &self,
        generator: &Generator,
        cache_llm: ModelId,
        query: &str,
        traits: &QueryTraits,
    ) -> Result<SmartCacheOutcome> {
        let hits = self.get(generator, query, &GetFilter::default())?;
        let mut calls = Vec::new();
        let Some(top) = hits.first().cloned() else {
            return Ok(SmartCacheOutcome {
                used: false,
                response: None,
                hit: None,
                llm_calls: calls,
            });
        };
        // Real relevance-check call (label-style output).
        calls.push(generator.classify_call(
            cache_llm,
            &format!(
                "is this cached content relevant to the query? query: {query} \
                 content: {}",
                top.object.text
            ),
        )?);
        // Delegated decision: ground truth is "similarity clears the bar";
        // the small model gets it right per its calibrated accuracy.
        let truth_relevant = top.score >= self.relevance_threshold;
        let says_relevant =
            classify(truth_relevant, cache_llm.spec().capability, &traits.id, 7);
        if !says_relevant {
            return Ok(SmartCacheOutcome {
                used: false,
                response: None,
                hit: Some(top),
                llm_calls: calls,
            });
        }
        // Grounded generation: cache-LLM rewrites cached content for the
        // query (§3.5 response modes 2/3).
        let gen = generator.generate(
            cache_llm,
            &format!(
                "answer using this cached information. query: {query} \
                 information: {}",
                top.object.text
            ),
            Some(20),
        )?;
        let response = format!("{} {}", top.object.text, gen.text);
        calls.push(gen);
        Ok(SmartCacheOutcome {
            used: true,
            response: Some(response),
            hit: Some(top),
            llm_calls: calls,
        })
    }

    /// Drop everything (tests / benchmarks).
    pub fn clear(&self) {
        let dim = self.index.lock().unwrap().dim();
        *self.index.lock().unwrap() = FlatIndex::new(dim, Metric::Cosine);
        self.keys.lock().unwrap().clear();
        self.objects.lock().unwrap().clear();
        self.exact.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_path_normalizes() {
        let c = SemanticCache::new(8);
        c.put_exact("What is the  Capital of Sudan?", "Khartoum");
        assert_eq!(
            c.get_exact("what is the capital of sudan"),
            Some("Khartoum".to_string())
        );
        assert_eq!(c.get_exact("unrelated"), None);
    }

    #[test]
    fn cached_type_names_unique() {
        let all = [
            CachedType::Prompt,
            CachedType::Response,
            CachedType::Chunk,
            CachedType::HypotheticalQuestion,
            CachedType::Keyword,
            CachedType::Summary,
            CachedType::Fact,
        ];
        let names: std::collections::HashSet<&str> =
            all.iter().map(|t| t.as_str()).collect();
        assert_eq!(names.len(), all.len());
    }

    #[test]
    fn get_filter_default() {
        let f = GetFilter::default();
        assert_eq!(f.k, 4);
        assert!(f.types.is_none());
    }
}
