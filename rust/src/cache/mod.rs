//! Semantic cache (paper §3.5): a typed-key cache over the vector database.
//!
//! Unlike an HTTP cache keyed by a URL hash, one cached *object* (an LLM
//! interaction or an external document chunk) can be indexed under many
//! *keys* of different [`CachedType`]s — the prompt, the response, chunk
//! text, hypothetical questions, keywords, summaries, extracted facts.
//!
//! * **PUT** — explicit keys, or *delegated*: the cache-LLM chunks complex
//!   objects and derives keys per chunk (see [`chunker`]).
//! * **GET** — low-level filtered similarity lookup, or *delegated*
//!   ("SmartCache"): retrieve top-k across types, let a small model decide
//!   relevance, and ground its reply in the cached content.
//! * **Exact path** — the WhatsApp deployment's prefetch buttons (§5.1) use
//!   exact-match entries to mask latency.
//!
//! ## Concurrency model
//!
//! The cache is read-mostly and designed so concurrent GETs never
//! serialize on each other:
//!
//! * The vector index — an [`AdaptiveIndex`]: bit-exact flat scans below
//!   the migration threshold, a trained IVF tier above it — sits behind
//!   one `RwLock`; `search` takes a read lock, only key insertion takes
//!   the write lock (briefly, for the whole key batch of a PUT).
//! * Index migration/retraining runs **off the read path**:
//!   [`SemanticCache::maybe_rebuild_index`] exports rows under the read
//!   lock, trains k-means with no lock held, and installs the trained
//!   tier under a brief write lock (reconciling any interim churn). It
//!   never touches the journal gate — a retrain changes the physical
//!   layout, not the journaled content, so it can run concurrently with
//!   WAL appends and needs no WAL record of its own.
//! * The `keys`, `objects`, and `exact` maps are split into
//!   `SHARD_COUNT` hash shards, each behind its own `RwLock`. Lookups
//!   take the touched shard's read lock; PUTs write-lock only the shard
//!   the id/key hashes to.
//! * Lock order is always **journal gate → index → keys → objects →
//!   WAL file mutex**, acquired strictly in that direction, so there is
//!   no deadlock shape. When a [`Journal`] is wired (durable
//!   deployments): embeds run *before* the gate (never hold it across an
//!   engine round-trip); `put`/`put_exact` take the gate in *shared*
//!   mode, apply, then append (`put_exact` appends while still holding
//!   its shard lock so same-key races land in the WAL in apply order);
//!   `clear` takes the gate *exclusively* (it spans every shard);
//!   snapshot compaction also takes the gate exclusively, then the state
//!   locks read-side. The 16-way shard locks are never held across a
//!   gate acquisition, and the WAL mutex is always the last lock anyone
//!   takes, so WAL appends cannot deadlock with the shard locks.
//! * PUT embeds all typed keys with one [`EngineHandle::embed_batch`]
//!   round-trip instead of a serial `embed_text` per key.
//! * Replication state (per-entry [`Stamp`]s, exact-path tombstones, the
//!   stamp→object dedup map) lives in dedicated shard-striped maps that
//!   are always present but stay empty until
//!   [`SemanticCache::enable_replication`] runs, so the unreplicated hot
//!   path pays one `OnceLock` load and nothing else. A stamp-map lock is
//!   always acquired *after* the data-shard lock it shadows and released
//!   with it, extending the lock order above without new deadlock shapes.
//!
//! ## Replication model
//!
//! When a node id is set, every mutation carries a [`Stamp`] —
//! `(origin, version)` under a per-node Lamport clock — and peers
//! exchange deltas by per-origin high-water mark (see `crate::sync`).
//! Conflicts resolve by [`Stamp::beats`]: higher version wins, ties break
//! on lexicographic origin, so any two replicas that have seen the same
//! stamps hold the same winners regardless of arrival order. Exact
//! entries are last-writer-wins with tombstoned removals; semantic
//! objects are add-only and deduplicated by stamp (ids are node-local —
//! a remote object is re-keyed under fresh local ids on apply). Vectors
//! travel with the delta in *stored* (pre-normalized) form and are
//! inserted verbatim, so replicas are bit-identical and the receiver
//! never re-embeds.
//!
//! [`EngineHandle::embed_batch`]: crate::runtime::EngineHandle::embed_batch

pub mod chunker;

use std::collections::{HashMap, HashSet};
use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, RwLock};

use anyhow::{anyhow, bail, Result};

use crate::models::generator::{Completion, Generator};
use crate::models::pricing::ModelId;
use crate::models::quality::{classify, QueryTraits};
use crate::vecdb::adaptive::{AdaptiveConfig, AdaptiveIndex, IndexStats};
use crate::vecdb::{Hit, Metric, VectorIndex};

/// Number of hash shards for the key/object/exact maps. Power of two so
/// shard selection is a mask; 16 is comfortably above the core counts the
/// proxy targets, keeping write collisions rare.
const SHARD_COUNT: usize = 16;

/// GET over-fetches the index beyond `filter.k`, because type filtering
/// and per-object dedup both shrink the raw hit list.
const OVERFETCH_PER_K: usize = 8;
/// Constant floor added on top of the per-k over-fetch.
const OVERFETCH_BASE: usize = 16;

/// What a key embedding was derived from (§3.5's "cached types").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CachedType {
    Prompt,
    Response,
    Chunk,
    HypotheticalQuestion,
    Keyword,
    Summary,
    Fact,
}

impl CachedType {
    pub fn as_str(&self) -> &'static str {
        match self {
            CachedType::Prompt => "prompt",
            CachedType::Response => "response",
            CachedType::Chunk => "chunk",
            CachedType::HypotheticalQuestion => "hypothetical_question",
            CachedType::Keyword => "keyword",
            CachedType::Summary => "summary",
            CachedType::Fact => "fact",
        }
    }

    /// Stable one-byte tag for binary WAL records.
    pub fn tag(&self) -> u8 {
        match self {
            CachedType::Prompt => 0,
            CachedType::Response => 1,
            CachedType::Chunk => 2,
            CachedType::HypotheticalQuestion => 3,
            CachedType::Keyword => 4,
            CachedType::Summary => 5,
            CachedType::Fact => 6,
        }
    }

    pub fn from_tag(tag: u8) -> Option<CachedType> {
        Some(match tag {
            0 => CachedType::Prompt,
            1 => CachedType::Response,
            2 => CachedType::Chunk,
            3 => CachedType::HypotheticalQuestion,
            4 => CachedType::Keyword,
            5 => CachedType::Summary,
            6 => CachedType::Fact,
            _ => return None,
        })
    }

    /// Inverse of [`CachedType::as_str`] (snapshot rows).
    pub fn parse(s: &str) -> Option<CachedType> {
        Some(match s {
            "prompt" => CachedType::Prompt,
            "response" => CachedType::Response,
            "chunk" => CachedType::Chunk,
            "hypothetical_question" => CachedType::HypotheticalQuestion,
            "keyword" => CachedType::Keyword,
            "summary" => CachedType::Summary,
            "fact" => CachedType::Fact,
            _ => return None,
        })
    }
}

/// A cached object: either a past LLM interaction or external content.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheObject {
    pub id: u64,
    /// The content served on a hit (response text / chunk text).
    pub text: String,
    /// Source prompt for interactions; title for documents.
    pub origin: String,
    pub is_document: bool,
}

/// One retrieval hit.
#[derive(Clone, Debug)]
pub struct CacheHit {
    pub object: CacheObject,
    pub matched_type: CachedType,
    pub score: f64,
}

/// GET-path filter (§3.5): restrict by cached type, similarity threshold,
/// and result count.
#[derive(Clone, Debug)]
pub struct GetFilter {
    pub types: Option<Vec<CachedType>>,
    pub min_score: f64,
    pub k: usize,
}

impl Default for GetFilter {
    fn default() -> Self {
        GetFilter {
            types: None,
            min_score: 0.0,
            k: 4,
        }
    }
}

struct KeyEntry {
    object_id: u64,
    ctype: CachedType,
}

/// Outcome of the delegated GET (SmartCache).
#[derive(Debug)]
pub struct SmartCacheOutcome {
    /// Whether cached content was deemed relevant and used.
    pub used: bool,
    /// The grounded response (present when `used`).
    pub response: Option<String>,
    /// The winning hit, if any retrieval happened.
    pub hit: Option<CacheHit>,
    /// Real cache-LLM calls made (billed to the request).
    pub llm_calls: Vec<Completion>,
}

/// Replication identity of one cache entry: which node wrote it
/// (`origin`) at which tick of that node's write clock (`version`).
/// [`Stamp::beats`] totally orders stamps identically on every node,
/// which is what makes anti-entropy apply-order-independent.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Stamp {
    pub origin: String,
    pub version: u64,
}

impl Stamp {
    /// The deterministic symmetric tiebreaker: higher version wins, equal
    /// versions break on lexicographic origin id. Equal stamps denote the
    /// *same* write (idempotent re-delivery), so neither beats the other.
    pub fn beats(&self, other: &Stamp) -> bool {
        (self.version, self.origin.as_str()) > (other.version, other.origin.as_str())
    }

    /// The stamp legacy (pre-replication) entries carry: version 0, empty
    /// origin. Any stamped write beats it.
    pub fn zero() -> Stamp {
        Stamp {
            origin: String::new(),
            version: 0,
        }
    }
}

/// What a `WalOp::Adopt` record retro-stamps: one pre-replication entry,
/// named without re-journaling its payload.
#[derive(Clone, Debug, PartialEq)]
pub enum AdoptTarget {
    /// A normalized exact-cache key.
    Exact(String),
    /// A semantic object id (node-local).
    Object(u64),
}

/// One unit of the anti-entropy delta stream, self-contained: everything
/// a peer needs to apply the entry without an engine round-trip (object
/// vectors travel in stored form) and without trusting the sender's
/// node-local ids (identity is the stamp).
#[derive(Clone, Debug, PartialEq)]
pub enum SyncEntry {
    /// An exact-cache entry (last-writer-wins by stamp).
    Exact {
        key: String,
        response: String,
        stamp: Stamp,
    },
    /// An exact-cache tombstone: the removal of `key` at `stamp`.
    Tomb { key: String, stamp: Stamp },
    /// A semantic object plus all its typed keys' stored-form vectors.
    /// Objects are add-only; the receiver re-keys under fresh local ids
    /// and dedups by stamp.
    Object {
        text: String,
        origin: String,
        is_document: bool,
        stamp: Stamp,
        keys: Vec<(CachedType, Vec<f32>)>,
    },
}

impl SyncEntry {
    pub fn stamp(&self) -> &Stamp {
        match self {
            SyncEntry::Exact { stamp, .. }
            | SyncEntry::Tomb { stamp, .. }
            | SyncEntry::Object { stamp, .. } => stamp,
        }
    }
}

/// Outcome of [`SemanticCache::apply_sync_entry`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncApplied {
    /// The entry won (or was new) and is now part of local state.
    Applied,
    /// The entry lost the tiebreaker or was already present — a no-op.
    Stale,
}

/// Node identity + Lamport write clock, set once by
/// [`SemanticCache::enable_replication`]. The clock holds the last
/// version issued *or observed*: local writes stamp
/// `max(clock, overwritten.version) + 1` and remote applies advance it,
/// so a local overwrite always beats the entry it replaced on every
/// replica, not just here.
struct ReplState {
    node_id: String,
    clock: AtomicU64,
}

impl ReplState {
    /// Issue a fresh stamp strictly beyond both the clock and `beyond`
    /// (the version of whatever this write supersedes).
    fn next_stamp(&self, beyond: u64) -> Stamp {
        let prev = self
            .clock
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |c| {
                Some(c.max(beyond) + 1)
            })
            .unwrap();
        Stamp {
            origin: self.node_id.clone(),
            version: prev.max(beyond) + 1,
        }
    }

    fn observe(&self, version: u64) {
        self.clock.fetch_max(version, Ordering::SeqCst);
    }
}

/// Compaction-gate guard handed out by [`Journal::enter`] /
/// [`Journal::enter_exclusive`]; held across one mutation's apply+append.
pub enum JournalGuard<'a> {
    /// Normal mutations: many may proceed concurrently, none while a
    /// compaction (or an exclusive mutation) holds the gate.
    Shared(std::sync::RwLockReadGuard<'a, ()>),
    /// Whole-cache mutations (`clear`): serialized against *everything*,
    /// so the WAL records a clean happens-before edge around them.
    Exclusive(std::sync::RwLockWriteGuard<'a, ()>),
}

/// Sink for durable cache mutations, implemented by the persist layer's
/// WAL (`crate::persist::Persistence`). Mutation paths call `enter` (or
/// `enter_exclusive`) first, apply in memory, then log — see the
/// module-level lock-order notes. `log_put` records the embedding vectors
/// alongside the typed keys so restore never re-embeds.
pub trait Journal: Send + Sync {
    fn enter(&self) -> JournalGuard<'_>;
    fn enter_exclusive(&self) -> JournalGuard<'_>;
    fn log_put_exact(&self, prompt: &str, response: &str);
    fn log_put(&self, object: CacheObject, keys: Vec<(u64, CachedType, Vec<f32>)>)
        -> Result<()>;
    fn log_clear(&self);
    fn log_remove_exact(&self, prompt: &str);
    /// Stamped twin of [`Journal::log_put_exact`] (replicated writes and
    /// applied remote entries).
    fn log_put_exact_v(&self, prompt: &str, response: &str, stamp: &Stamp);
    /// Stamped twin of [`Journal::log_put`]. On this path `keys` carries
    /// the index's *stored* rows (pre-normalized), replayed verbatim.
    fn log_put_v(
        &self,
        object: CacheObject,
        keys: Vec<(u64, CachedType, Vec<f32>)>,
        stamp: &Stamp,
    ) -> Result<()>;
    /// Stamped twin of [`Journal::log_remove_exact`]: a tombstone.
    fn log_remove_exact_v(&self, prompt: &str, stamp: &Stamp);
    /// Retro-stamp one pre-replication entry (payload-free record).
    fn log_adopt(&self, target: AdoptTarget, stamp: &Stamp);
}

pub struct SemanticCache {
    index: RwLock<AdaptiveIndex>,
    keys: Vec<RwLock<HashMap<u64, KeyEntry>>>,
    objects: Vec<RwLock<HashMap<u64, CacheObject>>>,
    exact: Vec<RwLock<HashMap<String, String>>>,
    next_id: AtomicU64,
    /// Serializes off-path index rebuilds (train is expensive; two
    /// concurrent maintenance callers must not both run k-means).
    rebuilding: AtomicBool,
    /// Durable-mutation sink; unset (zero-cost) for in-memory deployments.
    journal: OnceLock<std::sync::Arc<dyn Journal>>,
    /// Per-entry replication stamps for the exact map, sharded like it.
    /// Entries present in `exact` but absent here are version-0 (legacy).
    exact_stamps: Vec<RwLock<HashMap<String, Stamp>>>,
    /// Exact-path tombstones: the stamp at which a key was removed. Kept
    /// so a removal beats concurrent remote puts of the losing entry.
    exact_tombs: Vec<RwLock<HashMap<String, Stamp>>>,
    /// Per-object replication stamps, sharded like `objects`.
    object_stamps: Vec<RwLock<HashMap<u64, Stamp>>>,
    /// Stamp → local object id: dedups re-delivered remote objects (ids
    /// are node-local, so identity on the wire is the stamp alone).
    object_by_stamp: RwLock<HashMap<Stamp, u64>>,
    /// Max stamp version ever seen per origin — survives `clear` and is
    /// persisted in snapshot meta, so a node that clears and restarts
    /// still resumes its own write clock past every stamp it ever issued
    /// (re-issuing a version would permanently diverge the fleet).
    version_floors: Mutex<HashMap<String, u64>>,
    /// Node identity + write clock; unset until `enable_replication`.
    repl: OnceLock<ReplState>,
    /// Relevance threshold the SmartCache ground truth uses.
    pub relevance_threshold: f64,
}

impl SemanticCache {
    pub fn new(embed_dim: usize) -> SemanticCache {
        Self::with_index_config(embed_dim, AdaptiveConfig::default())
    }

    /// Build with explicit index-tier policy (tests and benches shrink the
    /// migration threshold; production uses the defaults).
    pub fn with_index_config(embed_dim: usize, cfg: AdaptiveConfig) -> SemanticCache {
        SemanticCache {
            index: RwLock::new(AdaptiveIndex::new(embed_dim, Metric::Cosine, cfg)),
            keys: (0..SHARD_COUNT).map(|_| RwLock::new(HashMap::new())).collect(),
            objects: (0..SHARD_COUNT).map(|_| RwLock::new(HashMap::new())).collect(),
            exact: (0..SHARD_COUNT).map(|_| RwLock::new(HashMap::new())).collect(),
            next_id: AtomicU64::new(1),
            rebuilding: AtomicBool::new(false),
            journal: OnceLock::new(),
            exact_stamps: (0..SHARD_COUNT).map(|_| RwLock::new(HashMap::new())).collect(),
            exact_tombs: (0..SHARD_COUNT).map(|_| RwLock::new(HashMap::new())).collect(),
            object_stamps: (0..SHARD_COUNT).map(|_| RwLock::new(HashMap::new())).collect(),
            object_by_stamp: RwLock::new(HashMap::new()),
            version_floors: Mutex::new(HashMap::new()),
            repl: OnceLock::new(),
            relevance_threshold: 0.40,
        }
    }

    /// Wire the durable-mutation sink (once, at boot, *after* any
    /// snapshot restore / WAL replay so recovery is not re-journaled).
    pub fn set_journal(&self, journal: std::sync::Arc<dyn Journal>) {
        let _ = self.journal.set(journal);
    }

    fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    #[inline]
    fn shard_of(id: u64) -> usize {
        // Ids are sequential, so the low bits alone stripe evenly.
        (id as usize) & (SHARD_COUNT - 1)
    }

    #[inline]
    fn shard_of_str(s: &str) -> usize {
        (crate::util::fnv1a(s.as_bytes()) as usize) & (SHARD_COUNT - 1)
    }

    pub fn len_objects(&self) -> usize {
        self.objects.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn len_keys(&self) -> usize {
        self.keys.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn len_exact(&self) -> usize {
        self.exact.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// The next id the allocator would hand out (snapshot metadata).
    pub fn next_id_hint(&self) -> u64 {
        self.next_id.load(Ordering::Relaxed)
    }

    // ------------------------------------------------------------- exact

    /// Normalized exact-match key (prefetch buttons).
    fn exact_key(prompt: &str) -> String {
        crate::runtime::tokenizer::words(prompt).join(" ")
    }

    pub fn put_exact(&self, prompt: &str, response: &str) {
        let journal = self.journal.get();
        let _gate = journal.map(|j| j.enter());
        let key = Self::exact_key(prompt);
        let si = Self::shard_of_str(&key);
        let mut shard = self.exact[si].write().unwrap();
        if let Some(r) = self.repl.get() {
            let mut stamps = self.exact_stamps[si].write().unwrap();
            let mut tombs = self.exact_tombs[si].write().unwrap();
            // Stamp past whatever this write supersedes (entry or
            // tombstone), so it beats the loser on every replica, not
            // just locally.
            let beyond = stamps
                .get(&key)
                .map(|s| s.version)
                .unwrap_or(0)
                .max(tombs.get(&key).map(|s| s.version).unwrap_or(0));
            let stamp = r.next_stamp(beyond);
            shard.insert(key.clone(), response.to_string());
            stamps.insert(key.clone(), stamp.clone());
            tombs.remove(&key);
            if let Some(j) = journal {
                j.log_put_exact_v(prompt, response, &stamp);
            }
        } else {
            shard.insert(key, response.to_string());
            if let Some(j) = journal {
                // Append while still holding the shard lock: same-key
                // races then land in the WAL in apply order, so
                // last-record-wins replay reconstructs exactly the
                // pre-crash winner.
                j.log_put_exact(prompt, response);
            }
        }
    }

    pub fn get_exact(&self, prompt: &str) -> Option<String> {
        let key = Self::exact_key(prompt);
        self.exact[Self::shard_of_str(&key)]
            .read()
            .unwrap()
            .get(&key)
            .cloned()
    }

    /// Admin invalidation of one exact entry (`DELETE /admin/cache?key=`).
    /// Returns whether an entry was actually removed. Journaled under the
    /// shard lock like `put_exact`, so replay preserves the same
    /// put/remove ordering the live cache saw.
    pub fn remove_exact(&self, prompt: &str) -> bool {
        let journal = self.journal.get();
        let _gate = journal.map(|j| j.enter());
        let key = Self::exact_key(prompt);
        let si = Self::shard_of_str(&key);
        let mut shard = self.exact[si].write().unwrap();
        let removed = shard.remove(&key).is_some();
        if removed {
            if let Some(r) = self.repl.get() {
                let mut stamps = self.exact_stamps[si].write().unwrap();
                let mut tombs = self.exact_tombs[si].write().unwrap();
                let beyond = stamps
                    .remove(&key)
                    .map(|s| s.version)
                    .unwrap_or(0)
                    .max(tombs.get(&key).map(|s| s.version).unwrap_or(0));
                let stamp = r.next_stamp(beyond);
                tombs.insert(key.clone(), stamp.clone());
                if let Some(j) = journal {
                    j.log_remove_exact_v(prompt, &stamp);
                }
            } else if let Some(j) = journal {
                j.log_remove_exact(prompt);
            }
        }
        removed
    }

    // --------------------------------------------------------------- PUT

    /// Explicit PUT (§3.5): store `text` under the supplied typed keys.
    /// All keys are embedded via one batched engine round-trip.
    pub fn put(
        &self,
        generator: &Generator,
        text: &str,
        origin: &str,
        is_document: bool,
        keys: &[(CachedType, String)],
    ) -> Result<u64> {
        // Embed before touching any cache state (and before the journal
        // gate): the engine round-trip is the slow part, and holding the
        // compaction gate across it would stall every other journaled
        // mutation whenever a compaction queues for exclusive access.
        // Bonus: a failed embed no longer leaves a keyless orphan object.
        let live: Vec<&(CachedType, String)> = keys
            .iter()
            .filter(|(_, key_text)| !key_text.trim().is_empty())
            .collect();
        let texts: Vec<&str> = live.iter().map(|pair| pair.1.as_str()).collect();
        let mut embs = generator.engine().embed_batch(&texts)?;
        let repl = self.repl.get();
        if repl.is_some() {
            // Replicated puts normalize up front and insert the stored
            // form verbatim (the index would normalize on insert anyway —
            // same bits). The WAL and the sync wire then carry the stored
            // rows themselves, so a replica applying this object lands
            // bit-identical without re-normalizing (normalizing an
            // already-unit f32 row is not a no-op).
            for e in &mut embs {
                crate::vecdb::normalize_in_place(e);
            }
        }

        let journal = self.journal.get();
        let _gate = journal.map(|j| j.enter());
        let object_id = self.fresh_id();
        self.objects[Self::shard_of(object_id)].write().unwrap().insert(
            object_id,
            CacheObject {
                id: object_id,
                text: text.to_string(),
                origin: origin.to_string(),
                is_document,
            },
        );
        let mut entries: Vec<(u64, CachedType)> = Vec::with_capacity(live.len());
        {
            // One write-lock acquisition for the whole key batch.
            let mut index = self.index.write().unwrap();
            for (pair, emb) in live.iter().zip(embs.iter()) {
                let key_id = self.fresh_id();
                if repl.is_some() {
                    index.insert_stored(key_id, emb)?;
                } else {
                    index.insert(key_id, emb)?;
                }
                entries.push((key_id, pair.0));
            }
        }
        for (key_id, ctype) in &entries {
            self.keys[Self::shard_of(*key_id)]
                .write()
                .unwrap()
                .insert(*key_id, KeyEntry { object_id, ctype: *ctype });
        }
        // Stamp *after* object + keys are all in place: a concurrent sync
        // round collects its delta by scanning stamps, so an unstamped
        // object is invisible to it and a stamped one is never
        // half-assembled.
        let stamp = repl.map(|r| {
            let stamp = r.next_stamp(0);
            self.object_stamps[Self::shard_of(object_id)]
                .write()
                .unwrap()
                .insert(object_id, stamp.clone());
            self.object_by_stamp
                .write()
                .unwrap()
                .insert(stamp.clone(), object_id);
            stamp
        });
        if let Some(j) = journal {
            // Log the embeddings alongside the assigned ids: replay
            // re-inserts them without an engine round-trip (raw rows on
            // the legacy path, stored rows on the replicated path).
            let logged: Vec<(u64, CachedType, Vec<f32>)> = entries
                .iter()
                .zip(embs.iter())
                .map(|(&(key_id, ctype), emb)| (key_id, ctype, emb.clone()))
                .collect();
            let object = CacheObject {
                id: object_id,
                text: text.to_string(),
                origin: origin.to_string(),
                is_document,
            };
            let log_result = match &stamp {
                Some(s) => j.log_put_v(object, logged, s),
                None => j.log_put(object, logged),
            };
            if let Err(e) = log_result {
                // Roll back the in-memory apply so an Err means "this PUT
                // did not happen" — memory and WAL stay in agreement, and
                // a caller's retry can't strand duplicate objects.
                if let Some(s) = &stamp {
                    self.object_stamps[Self::shard_of(object_id)]
                        .write()
                        .unwrap()
                        .remove(&object_id);
                    self.object_by_stamp.write().unwrap().remove(s);
                }
                {
                    let mut index = self.index.write().unwrap();
                    for (key_id, _) in &entries {
                        index.remove(*key_id);
                    }
                }
                for (key_id, _) in &entries {
                    self.keys[Self::shard_of(*key_id)].write().unwrap().remove(key_id);
                }
                self.objects[Self::shard_of(object_id)]
                    .write()
                    .unwrap()
                    .remove(&object_id);
                return Err(e);
            }
        }
        Ok(object_id)
    }

    /// Re-apply a WAL-logged PUT: the object plus its typed keys with
    /// their original ids and snapshotted embeddings (no engine call).
    /// Idempotent per key id, so an op captured by both a snapshot and a
    /// trailing WAL replays cleanly.
    pub fn apply_logged_put(
        &self,
        object: CacheObject,
        keys: &[(u64, CachedType, Vec<f32>)],
    ) -> Result<()> {
        let object_id = object.id;
        let mut max_id = object_id;
        {
            let mut index = self.index.write().unwrap();
            for (key_id, _, vector) in keys {
                max_id = max_id.max(*key_id);
                if !index.contains(*key_id) {
                    index.insert(*key_id, vector)?;
                }
            }
        }
        for (key_id, ctype, _) in keys {
            self.keys[Self::shard_of(*key_id)]
                .write()
                .unwrap()
                .insert(*key_id, KeyEntry { object_id, ctype: *ctype });
        }
        self.objects[Self::shard_of(object_id)]
            .write()
            .unwrap()
            .insert(object_id, object);
        self.next_id.fetch_max(max_id + 1, Ordering::Relaxed);
        Ok(())
    }

    /// Cache a full interaction under prompt + response keys (the §3.5
    /// B-tree example: future prompts may match the *response*).
    pub fn put_interaction(
        &self,
        generator: &Generator,
        prompt: &str,
        response: &str,
    ) -> Result<u64> {
        self.put(
            generator,
            response,
            prompt,
            false,
            &[
                (CachedType::Prompt, prompt.to_string()),
                (CachedType::Response, response.to_string()),
            ],
        )
    }

    /// Delegated PUT (§3.5): the cache-LLM chunks the document and derives
    /// keys (chunk text, keywords, hypothetical questions, summary, facts).
    /// Returns (object ids, cache-LLM calls made).
    pub fn put_delegated(
        &self,
        generator: &Generator,
        cache_llm: ModelId,
        title: &str,
        document: &str,
    ) -> Result<(Vec<u64>, Vec<Completion>)> {
        let mut calls = Vec::new();
        // One real cache-LLM call to "drive" chunk summarization; the
        // lexical summary itself is head-words (deterministic).
        let chunks = chunker::chunk_document(document, 48, |chunk| {
            let head: Vec<String> = crate::runtime::tokenizer::words(chunk)
                .into_iter()
                .take(10)
                .collect();
            head.join(" ")
        });
        if !chunks.is_empty() {
            calls.push(generator.generate(
                cache_llm,
                &format!("derive cache keys for document titled {title}"),
                Some(8),
            )?);
        }
        let mut ids = Vec::new();
        for chunk in &chunks {
            let mut keys: Vec<(CachedType, String)> =
                vec![(CachedType::Chunk, chunk.text.clone())];
            for q in &chunk.hypothetical_questions {
                keys.push((CachedType::HypotheticalQuestion, q.clone()));
            }
            if !chunk.keywords.is_empty() {
                keys.push((CachedType::Keyword, chunk.keywords.join(" ")));
            }
            keys.push((CachedType::Summary, chunk.summary.clone()));
            for f in &chunk.facts {
                keys.push((CachedType::Fact, f.clone()));
            }
            ids.push(self.put(generator, &chunk.text, title, true, &keys)?);
        }
        Ok((ids, calls))
    }

    // --------------------------------------------------------------- GET

    /// Low-level GET: top-k typed-key similarity search.
    ///
    /// Over-fetches `k * OVERFETCH_PER_K + OVERFETCH_BASE` raw keys, then
    /// widens (doubling) if type filtering and per-object dedup starved the
    /// result set below `k` while unseen keys remain. On the IVF tier each
    /// widening step also doubles the probed cells (the index's `effort`
    /// knob), so a starved result set recruits more of the corpus — up to
    /// an exhaustive all-cells probe — before the GET settles for fewer
    /// than `k` hits.
    pub fn get(
        &self,
        generator: &Generator,
        query: &str,
        filter: &GetFilter,
    ) -> Result<Vec<CacheHit>> {
        let emb = generator.engine().embed_text(query)?;
        // Effort level at which search_effort is exhaustive for any nlist
        // (probes = nprobe << 20 dwarfs the 1024-cell cap).
        const MAX_EFFORT: u32 = 20;
        let mut fetch = filter.k * OVERFETCH_PER_K + OVERFETCH_BASE;
        let mut effort = 0u32;
        loop {
            let (raw, total, exhaustive) = {
                let index = self.index.read().unwrap();
                let (raw, exhaustive) =
                    index.search_effort(&emb, fetch, filter.min_score as f32, effort);
                (raw, index.len(), exhaustive)
            };
            // Only an exhaustive scan can prove there is nothing left:
            // fewer raw hits than asked for means everything above
            // min_score has been seen; fetch >= total means the whole
            // index was scanned.
            let exhausted = exhaustive && (raw.len() < fetch || fetch >= total);
            let starved_probe = !exhaustive && raw.len() < fetch;
            let hits = self.resolve_hits(raw, filter);
            if hits.len() >= filter.k || exhausted {
                return Ok(hits);
            }
            if starved_probe {
                // The probed cells hold nothing more above min_score, so a
                // bigger fetch cannot help — only more cells can. Jump
                // straight to the exhaustive probe instead of climbing the
                // geometric ladder (which would re-scan every
                // already-probed cell per step — a likely cache *miss*
                // must not cost multiples of the flat scan it replaced).
                effort = MAX_EFFORT;
            } else {
                fetch *= 2;
                effort = (effort + 1).min(MAX_EFFORT);
            }
        }
    }

    /// Raw index probe (no engine, no key/object resolution) — the
    /// persistence suite compares restored indexes with this.
    pub fn search_raw(&self, embedding: &[f32], k: usize, min_score: f32) -> Vec<Hit> {
        self.index.read().unwrap().search(embedding, k, min_score)
    }

    /// Index tier diagnostics (which tier, rows, trained, cells).
    pub fn index_stats(&self) -> IndexStats {
        self.index.read().unwrap().stats()
    }

    /// Run one index maintenance step if due: migrate the flat tier to a
    /// trained IVF once the corpus outgrows the configured threshold, or
    /// retrain a drifted IVF tier. Training runs **without any lock held**
    /// (reads take the index read lock concurrently throughout); only the
    /// final swap takes the write lock, where interim churn is reconciled.
    /// Returns whether a rebuild ran. Polled by the server's janitor
    /// thread; library users call it from their own maintenance cadence.
    pub fn maybe_rebuild_index(&self) -> bool {
        if self.rebuilding.swap(true, Ordering::Acquire) {
            return false;
        }
        let ran = (|| {
            let plan = {
                let index = self.index.read().unwrap();
                index.rebuild_plan()
            };
            let Some(plan) = plan else {
                return false;
            };
            let trained = plan.train();
            // install() refuses the trained tier (returning false) if the
            // index value was replaced mid-train — e.g. clear() swapped in
            // a fresh flat index; the stale centroids are discarded.
            self.index.write().unwrap().install(trained)
        })();
        self.rebuilding.store(false, Ordering::Release);
        ran
    }

    /// Post-filter raw index hits: map key → object, apply the type
    /// filter, keep the best score per object, sort, truncate to `k`.
    fn resolve_hits(&self, raw: Vec<crate::vecdb::Hit>, filter: &GetFilter) -> Vec<CacheHit> {
        let mut best: HashMap<u64, CacheHit> = HashMap::new();
        for hit in raw {
            let entry = {
                let shard = self.keys[Self::shard_of(hit.id)].read().unwrap();
                shard.get(&hit.id).map(|e| (e.object_id, e.ctype))
            };
            let Some((object_id, ctype)) = entry else {
                continue;
            };
            if let Some(types) = &filter.types {
                if !types.contains(&ctype) {
                    continue;
                }
            }
            let obj = {
                let shard = self.objects[Self::shard_of(object_id)].read().unwrap();
                shard.get(&object_id).cloned()
            };
            let Some(obj) = obj else {
                continue;
            };
            let candidate = CacheHit {
                object: obj,
                matched_type: ctype,
                score: hit.score as f64,
            };
            match best.get(&object_id) {
                Some(prev) if prev.score >= candidate.score => {}
                _ => {
                    best.insert(object_id, candidate);
                }
            }
        }
        let mut hits: Vec<CacheHit> = best.into_values().collect();
        hits.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        hits.truncate(filter.k);
        hits
    }

    /// Delegated GET — "SmartCache" (§3.5): retrieve top-k across all
    /// cached types, let the cache-LLM judge relevance, and if relevant,
    /// generate a reply grounded in the cached content.
    pub fn smart_get(
        &self,
        generator: &Generator,
        cache_llm: ModelId,
        query: &str,
        traits: &QueryTraits,
    ) -> Result<SmartCacheOutcome> {
        let hits = self.get(generator, query, &GetFilter::default())?;
        let mut calls = Vec::new();
        let Some(top) = hits.first().cloned() else {
            return Ok(SmartCacheOutcome {
                used: false,
                response: None,
                hit: None,
                llm_calls: calls,
            });
        };
        // Real relevance-check call (label-style output).
        calls.push(generator.classify_call(
            cache_llm,
            &format!(
                "is this cached content relevant to the query? query: {query} \
                 content: {}",
                top.object.text
            ),
        )?);
        // Delegated decision: ground truth is "similarity clears the bar";
        // the small model gets it right per its calibrated accuracy.
        let truth_relevant = top.score >= self.relevance_threshold;
        let says_relevant =
            classify(truth_relevant, cache_llm.spec().capability, &traits.id, 7);
        if !says_relevant {
            return Ok(SmartCacheOutcome {
                used: false,
                response: None,
                hit: Some(top),
                llm_calls: calls,
            });
        }
        // Grounded generation: cache-LLM rewrites cached content for the
        // query (§3.5 response modes 2/3).
        let gen = generator.generate(
            cache_llm,
            &format!(
                "answer using this cached information. query: {query} \
                 information: {}",
                top.object.text
            ),
            Some(20),
        )?;
        let response = format!("{} {}", top.object.text, gen.text);
        calls.push(gen);
        Ok(SmartCacheOutcome {
            used: true,
            response: Some(response),
            hit: Some(top),
            llm_calls: calls,
        })
    }

    /// Drop everything (tests / benchmarks).
    pub fn clear(&self) {
        let journal = self.journal.get();
        // Exclusive gate: a clear spans every shard, so it must not
        // interleave with concurrent puts — in memory or in the WAL.
        // Exclusivity gives its record a clean happens-before position.
        let _gate = journal.map(|j| j.enter_exclusive());
        {
            // Single guarded scope: read dim and swap in the fresh index
            // under one write lock (the seed locked the index twice in one
            // statement — a latent deadlock shape). A clear resets to the
            // flat tier (an empty IVF has nothing to probe).
            let mut index = self.index.write().unwrap();
            let dim = index.dim();
            let cfg = index.config().clone();
            *index = AdaptiveIndex::new(dim, Metric::Cosine, cfg);
        }
        for shard in &self.keys {
            shard.write().unwrap().clear();
        }
        for shard in &self.objects {
            shard.write().unwrap().clear();
        }
        for shard in &self.exact {
            shard.write().unwrap().clear();
        }
        for shard in &self.exact_stamps {
            shard.write().unwrap().clear();
        }
        for shard in &self.exact_tombs {
            shard.write().unwrap().clear();
        }
        for shard in &self.object_stamps {
            shard.write().unwrap().clear();
        }
        self.object_by_stamp.write().unwrap().clear();
        // version_floors survives deliberately: the write clock must never
        // re-issue a version this node already used, even across a clear
        // (a peer that saw the old stamp would treat the re-issue as
        // already-applied and the fleet would silently diverge).
        if let Some(j) = journal {
            j.log_clear();
        }
    }

    // ------------------------------------------------------- replication

    /// Turn on replication: give this cache a node identity and seed its
    /// write clock past every version this node has ever issued (the
    /// persisted floor), so versions are never reused across restarts or
    /// clears. Call once at boot, *after* snapshot restore and WAL replay
    /// (which populate the floor). Idempotent; later calls are ignored.
    pub fn enable_replication(&self, node_id: &str) {
        let floor = self
            .version_floors
            .lock()
            .unwrap()
            .get(node_id)
            .copied()
            .unwrap_or(0);
        let _ = self.repl.set(ReplState {
            node_id: node_id.to_string(),
            clock: AtomicU64::new(floor),
        });
    }

    /// This node's replication identity, if enabled.
    pub fn replication_node(&self) -> Option<&str> {
        self.repl.get().map(|r| r.node_id.as_str())
    }

    /// Current value of the write clock (diagnostics; 0 when disabled).
    pub fn replication_clock(&self) -> u64 {
        self.repl
            .get()
            .map(|r| r.clock.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    fn note_floor(&self, stamp: &Stamp) {
        let mut floors = self.version_floors.lock().unwrap();
        let e = floors.entry(stamp.origin.clone()).or_insert(0);
        *e = (*e).max(stamp.version);
    }

    /// Retro-stamp every version-0 (pre-replication) entry with a fresh
    /// own stamp, journaling payload-free `Adopt` records — the one-time
    /// upgrade path when a legacy corpus first boots with a node id.
    /// Without this, legacy entries would have no stamp, never clear any
    /// peer's high-water mark, and never replicate. Returns the number of
    /// entries adopted (0 when replication is off or nothing is legacy).
    pub fn adopt_unstamped(&self) -> usize {
        let Some(r) = self.repl.get() else {
            return 0;
        };
        let journal = self.journal.get();
        let _gate = journal.map(|j| j.enter());
        let mut adopted = 0usize;
        for si in 0..SHARD_COUNT {
            let unstamped: Vec<String> = {
                let shard = self.exact[si].read().unwrap();
                let stamps = self.exact_stamps[si].read().unwrap();
                shard
                    .keys()
                    .filter(|k| !stamps.contains_key(*k))
                    .cloned()
                    .collect()
            };
            for key in unstamped {
                let stamp = r.next_stamp(0);
                self.exact_stamps[si]
                    .write()
                    .unwrap()
                    .insert(key.clone(), stamp.clone());
                self.note_floor(&stamp);
                if let Some(j) = journal {
                    j.log_adopt(AdoptTarget::Exact(key), &stamp);
                }
                adopted += 1;
            }
            let unstamped: Vec<u64> = {
                let shard = self.objects[si].read().unwrap();
                let stamps = self.object_stamps[si].read().unwrap();
                shard
                    .keys()
                    .filter(|id| !stamps.contains_key(*id))
                    .copied()
                    .collect()
            };
            for id in unstamped {
                let stamp = r.next_stamp(0);
                self.object_stamps[si]
                    .write()
                    .unwrap()
                    .insert(id, stamp.clone());
                self.object_by_stamp
                    .write()
                    .unwrap()
                    .insert(stamp.clone(), id);
                self.note_floor(&stamp);
                if let Some(j) = journal {
                    j.log_adopt(AdoptTarget::Object(id), &stamp);
                }
                adopted += 1;
            }
        }
        adopted
    }

    /// Replay a WAL `PutExactV`: unconditional (the losing side of any
    /// conflict was resolved *before* journaling, so WAL order is final
    /// state), tracking the version floor.
    pub fn replay_put_exact_v(&self, prompt: &str, response: &str, stamp: &Stamp) {
        let key = Self::exact_key(prompt);
        let si = Self::shard_of_str(&key);
        let mut shard = self.exact[si].write().unwrap();
        let mut stamps = self.exact_stamps[si].write().unwrap();
        let mut tombs = self.exact_tombs[si].write().unwrap();
        shard.insert(key.clone(), response.to_string());
        stamps.insert(key.clone(), stamp.clone());
        tombs.remove(&key);
        drop((shard, stamps, tombs));
        self.note_floor(stamp);
    }

    /// Replay a WAL `RemoveExactV`: re-establish the tombstone.
    pub fn replay_remove_exact_v(&self, prompt: &str, stamp: &Stamp) {
        let key = Self::exact_key(prompt);
        let si = Self::shard_of_str(&key);
        let mut shard = self.exact[si].write().unwrap();
        let mut stamps = self.exact_stamps[si].write().unwrap();
        let mut tombs = self.exact_tombs[si].write().unwrap();
        shard.remove(&key);
        stamps.remove(&key);
        tombs.insert(key, stamp.clone());
        drop((shard, stamps, tombs));
        self.note_floor(stamp);
    }

    /// Replay a WAL `PutObjectV`: like [`SemanticCache::apply_logged_put`]
    /// but the journaled rows are stored-form and land verbatim
    /// (`insert_stored`), and the object's stamp is restored. Idempotent
    /// per key id.
    pub fn replay_put_object_v(
        &self,
        object: CacheObject,
        keys: &[(u64, CachedType, Vec<f32>)],
        stamp: &Stamp,
    ) -> Result<()> {
        let object_id = object.id;
        let mut max_id = object_id;
        {
            let mut index = self.index.write().unwrap();
            for (key_id, _, vector) in keys {
                max_id = max_id.max(*key_id);
                if !index.contains(*key_id) {
                    index.insert_stored(*key_id, vector)?;
                }
            }
        }
        for (key_id, ctype, _) in keys {
            self.keys[Self::shard_of(*key_id)]
                .write()
                .unwrap()
                .insert(*key_id, KeyEntry { object_id, ctype: *ctype });
        }
        self.objects[Self::shard_of(object_id)]
            .write()
            .unwrap()
            .insert(object_id, object);
        self.object_stamps[Self::shard_of(object_id)]
            .write()
            .unwrap()
            .insert(object_id, stamp.clone());
        self.object_by_stamp
            .write()
            .unwrap()
            .insert(stamp.clone(), object_id);
        self.next_id.fetch_max(max_id + 1, Ordering::Relaxed);
        self.note_floor(stamp);
        Ok(())
    }

    /// Replay a WAL `Adopt`: stamp the named entry if it still exists (a
    /// later WAL record may have removed it — adoption is best-effort by
    /// construction).
    pub fn replay_adopt(&self, target: &AdoptTarget, stamp: &Stamp) {
        match target {
            AdoptTarget::Exact(key) => {
                let si = Self::shard_of_str(key);
                let shard = self.exact[si].read().unwrap();
                if shard.contains_key(key) {
                    self.exact_stamps[si]
                        .write()
                        .unwrap()
                        .insert(key.clone(), stamp.clone());
                }
            }
            AdoptTarget::Object(id) => {
                let si = Self::shard_of(*id);
                let present = self.objects[si].read().unwrap().contains_key(id);
                if present {
                    self.object_stamps[si]
                        .write()
                        .unwrap()
                        .insert(*id, stamp.clone());
                    self.object_by_stamp
                        .write()
                        .unwrap()
                        .insert(stamp.clone(), *id);
                }
            }
        }
        self.note_floor(stamp);
    }

    /// Per-origin high-water marks of the *present* state: the max stamp
    /// version per origin across entries, tombstones, and objects. This is
    /// what a sync round advertises; deriving it from live state (rather
    /// than a separate counter) means a cleared node naturally re-requests
    /// everything — `clear` is a local operation, peers re-seed it.
    pub fn sync_hwms(&self) -> HashMap<String, u64> {
        let mut hwms: HashMap<String, u64> = HashMap::new();
        let mut fold = |s: &Stamp| {
            let e = hwms.entry(s.origin.clone()).or_insert(0);
            *e = (*e).max(s.version);
        };
        for si in 0..SHARD_COUNT {
            for s in self.exact_stamps[si].read().unwrap().values() {
                fold(s);
            }
            for s in self.exact_tombs[si].read().unwrap().values() {
                fold(s);
            }
            for s in self.object_stamps[si].read().unwrap().values() {
                fold(s);
            }
        }
        hwms
    }

    /// Collect every entry whose stamp is above the peer's advertised
    /// high-water mark for its origin — the anti-entropy delta. Runs in
    /// staged O(n) passes (stamps → key shards → one index row sweep) with
    /// no nested shard locks, entirely off the request hot path. Version-0
    /// (never-adopted) entries have no stamp and are never shipped.
    pub fn sync_delta(&self, peer_hwms: &HashMap<String, u64>) -> Vec<SyncEntry> {
        let newer =
            |s: &Stamp| s.version > peer_hwms.get(&s.origin).copied().unwrap_or(0);
        let mut out = Vec::new();
        for si in 0..SHARD_COUNT {
            {
                let shard = self.exact[si].read().unwrap();
                let stamps = self.exact_stamps[si].read().unwrap();
                for (k, s) in stamps.iter() {
                    if newer(s) {
                        if let Some(v) = shard.get(k) {
                            out.push(SyncEntry::Exact {
                                key: k.clone(),
                                response: v.clone(),
                                stamp: s.clone(),
                            });
                        }
                    }
                }
            }
            for (k, s) in self.exact_tombs[si].read().unwrap().iter() {
                if newer(s) {
                    out.push(SyncEntry::Tomb {
                        key: k.clone(),
                        stamp: s.clone(),
                    });
                }
            }
        }
        // Objects: wanted ids first (stamps are recorded only once the
        // object and all its keys are in place, so everything collected
        // below is fully assembled), then one pass over the key shards for
        // the id→keys reverse mapping, then one index sweep for the rows.
        let mut wanted: HashMap<u64, Stamp> = HashMap::new();
        for si in 0..SHARD_COUNT {
            for (id, s) in self.object_stamps[si].read().unwrap().iter() {
                if newer(s) {
                    wanted.insert(*id, s.clone());
                }
            }
        }
        if wanted.is_empty() {
            return out;
        }
        let mut obj_keys: HashMap<u64, Vec<(u64, CachedType)>> = HashMap::new();
        for si in 0..SHARD_COUNT {
            for (key_id, e) in self.keys[si].read().unwrap().iter() {
                if wanted.contains_key(&e.object_id) {
                    obj_keys
                        .entry(e.object_id)
                        .or_default()
                        .push((*key_id, e.ctype));
                }
            }
        }
        let need_rows: HashSet<u64> =
            obj_keys.values().flatten().map(|(id, _)| *id).collect();
        let mut rows: HashMap<u64, Vec<f32>> = HashMap::new();
        {
            let index = self.index.read().unwrap();
            index.for_each_row(|id, row| {
                if need_rows.contains(&id) {
                    rows.insert(id, row.to_vec());
                }
            });
        }
        for (id, stamp) in wanted {
            let obj = {
                let shard = self.objects[Self::shard_of(id)].read().unwrap();
                shard.get(&id).cloned()
            };
            // A concurrent clear() can race this collection; an object
            // gone mid-pass is simply not shipped this round.
            let Some(obj) = obj else {
                continue;
            };
            let mut ks = obj_keys.remove(&id).unwrap_or_default();
            ks.sort_by_key(|(kid, _)| *kid);
            let keys: Vec<(CachedType, Vec<f32>)> = ks
                .into_iter()
                .filter_map(|(kid, ct)| rows.get(&kid).map(|r| (ct, r.clone())))
                .collect();
            out.push(SyncEntry::Object {
                text: obj.text,
                origin: obj.origin,
                is_document: obj.is_document,
                stamp,
                keys,
            });
        }
        out
    }

    /// Apply one remote entry under the deterministic tiebreaker,
    /// journaling winners through the local WAL (so replication survives
    /// restart and compaction without ever needing the peer's history).
    /// Exact entries are last-writer-wins against both the present entry
    /// and any tombstone; objects are add-only, deduplicated by stamp and
    /// re-keyed under fresh local ids; vectors land verbatim
    /// (stored-form), never re-embedded or re-normalized.
    pub fn apply_sync_entry(&self, entry: SyncEntry) -> Result<SyncApplied> {
        if let Some(r) = self.repl.get() {
            // Lamport receive rule: later local writes must beat this.
            r.observe(entry.stamp().version);
        }
        self.note_floor(entry.stamp());
        let journal = self.journal.get();
        let _gate = journal.map(|j| j.enter());
        match entry {
            SyncEntry::Exact {
                key,
                response,
                stamp,
            } => {
                let si = Self::shard_of_str(&key);
                let mut shard = self.exact[si].write().unwrap();
                let mut stamps = self.exact_stamps[si].write().unwrap();
                let mut tombs = self.exact_tombs[si].write().unwrap();
                let current = stamps
                    .get(&key)
                    .cloned()
                    .or_else(|| shard.contains_key(&key).then(Stamp::zero));
                if let Some(cur) = current {
                    if !stamp.beats(&cur) {
                        return Ok(SyncApplied::Stale);
                    }
                }
                if let Some(t) = tombs.get(&key) {
                    if !stamp.beats(t) {
                        return Ok(SyncApplied::Stale);
                    }
                }
                shard.insert(key.clone(), response.clone());
                stamps.insert(key.clone(), stamp.clone());
                tombs.remove(&key);
                if let Some(j) = journal {
                    // The key is already normalized; exact_key is
                    // idempotent, so journaling it as the prompt replays
                    // to the same key.
                    j.log_put_exact_v(&key, &response, &stamp);
                }
                Ok(SyncApplied::Applied)
            }
            SyncEntry::Tomb { key, stamp } => {
                let si = Self::shard_of_str(&key);
                let mut shard = self.exact[si].write().unwrap();
                let mut stamps = self.exact_stamps[si].write().unwrap();
                let mut tombs = self.exact_tombs[si].write().unwrap();
                if let Some(t) = tombs.get(&key) {
                    if !stamp.beats(t) {
                        return Ok(SyncApplied::Stale);
                    }
                }
                let current = stamps
                    .get(&key)
                    .cloned()
                    .or_else(|| shard.contains_key(&key).then(Stamp::zero));
                if let Some(cur) = current {
                    if !stamp.beats(&cur) {
                        return Ok(SyncApplied::Stale);
                    }
                }
                shard.remove(&key);
                stamps.remove(&key);
                // Recorded even when the key was absent here: the
                // tombstone must outlive the race with a slower remote
                // put of the entry it killed.
                tombs.insert(key.clone(), stamp.clone());
                if let Some(j) = journal {
                    j.log_remove_exact_v(&key, &stamp);
                }
                Ok(SyncApplied::Applied)
            }
            SyncEntry::Object {
                text,
                origin,
                is_document,
                stamp,
                keys,
            } => {
                if self.object_by_stamp.read().unwrap().contains_key(&stamp) {
                    return Ok(SyncApplied::Stale);
                }
                let object_id = self.fresh_id();
                let object = CacheObject {
                    id: object_id,
                    text,
                    origin,
                    is_document,
                };
                self.objects[Self::shard_of(object_id)]
                    .write()
                    .unwrap()
                    .insert(object_id, object.clone());
                let mut entries: Vec<(u64, CachedType)> =
                    Vec::with_capacity(keys.len());
                {
                    let mut index = self.index.write().unwrap();
                    for (ctype, vector) in &keys {
                        let key_id = self.fresh_id();
                        index.insert_stored(key_id, vector)?;
                        entries.push((key_id, *ctype));
                    }
                }
                for (key_id, ctype) in &entries {
                    self.keys[Self::shard_of(*key_id)]
                        .write()
                        .unwrap()
                        .insert(*key_id, KeyEntry { object_id, ctype: *ctype });
                }
                self.object_stamps[Self::shard_of(object_id)]
                    .write()
                    .unwrap()
                    .insert(object_id, stamp.clone());
                self.object_by_stamp
                    .write()
                    .unwrap()
                    .insert(stamp.clone(), object_id);
                if let Some(j) = journal {
                    let logged: Vec<(u64, CachedType, Vec<f32>)> = entries
                        .iter()
                        .zip(keys.iter())
                        .map(|(&(key_id, ctype), (_, v))| (key_id, ctype, v.clone()))
                        .collect();
                    if let Err(e) = j.log_put_v(object, logged, &stamp) {
                        self.object_stamps[Self::shard_of(object_id)]
                            .write()
                            .unwrap()
                            .remove(&object_id);
                        self.object_by_stamp.write().unwrap().remove(&stamp);
                        {
                            let mut index = self.index.write().unwrap();
                            for (key_id, _) in &entries {
                                index.remove(*key_id);
                            }
                        }
                        for (key_id, _) in &entries {
                            self.keys[Self::shard_of(*key_id)]
                                .write()
                                .unwrap()
                                .remove(key_id);
                        }
                        self.objects[Self::shard_of(object_id)]
                            .write()
                            .unwrap()
                            .remove(&object_id);
                        return Err(e);
                    }
                }
                Ok(SyncApplied::Applied)
            }
        }
    }

    /// Deterministic, id-free fingerprint of the replicated corpus: exact
    /// entries + stamps, tombstones, and the object multiset with each
    /// object's typed keys as exact f32 bit patterns. Two converged
    /// replicas produce identical fingerprints even though their local
    /// ids differ — the convergence tests' bit-exactness oracle.
    pub fn replica_fingerprint(&self) -> Vec<String> {
        fn fmt_stamp(s: Option<&Stamp>) -> String {
            match s {
                Some(s) => format!("{}#{}", s.origin, s.version),
                None => "#0".to_string(),
            }
        }
        let mut lines = Vec::new();
        for si in 0..SHARD_COUNT {
            {
                let shard = self.exact[si].read().unwrap();
                let stamps = self.exact_stamps[si].read().unwrap();
                for (k, v) in shard.iter() {
                    lines.push(format!("exact|{k}|{v}|{}", fmt_stamp(stamps.get(k))));
                }
            }
            for (k, s) in self.exact_tombs[si].read().unwrap().iter() {
                lines.push(format!("tomb|{k}|{}", fmt_stamp(Some(s))));
            }
        }
        let mut rows: HashMap<u64, String> = HashMap::new();
        {
            let index = self.index.read().unwrap();
            index.for_each_row(|id, row| {
                let mut hex = String::with_capacity(row.len() * 8);
                for x in row {
                    hex.push_str(&format!("{:08x}", x.to_bits()));
                }
                rows.insert(id, hex);
            });
        }
        let mut obj_keys: HashMap<u64, Vec<String>> = HashMap::new();
        for si in 0..SHARD_COUNT {
            for (key_id, e) in self.keys[si].read().unwrap().iter() {
                let bits = rows.get(key_id).cloned().unwrap_or_default();
                obj_keys
                    .entry(e.object_id)
                    .or_default()
                    .push(format!("{}:{}", e.ctype.as_str(), bits));
            }
        }
        for si in 0..SHARD_COUNT {
            let stamps = self.object_stamps[si].read().unwrap();
            for obj in self.objects[si].read().unwrap().values() {
                let mut ks = obj_keys.remove(&obj.id).unwrap_or_default();
                ks.sort();
                lines.push(format!(
                    "obj|{}|{}|{}|{}|{}",
                    obj.text,
                    obj.origin,
                    obj.is_document,
                    fmt_stamp(stamps.get(&obj.id)),
                    ks.join(",")
                ));
            }
        }
        lines.sort();
        lines
    }

    // ---------------------------------------------------------- snapshot

    /// Write this cache's durable image into `dir`: `vecdb.bin` (bulk
    /// rows, pre-normalized — LBV2 on the flat tier, LBV3 with the trained
    /// centroids + assignments on the IVF tier, so a cold restore never
    /// re-trains) plus `cache.jsonl` (meta, object, key, and exact rows).
    /// The caller must have quiesced writers — the persist layer holds its
    /// compaction gate exclusively around this.
    pub fn snapshot_into(&self, dir: &Path) -> Result<()> {
        {
            let index = self.index.read().unwrap();
            index.save(&dir.join("vecdb.bin"))?;
        }
        use crate::util::json::Json;
        // Stream rows through a BufWriter: a months-old cache must not be
        // duplicated wholesale in RAM while the compaction gate is held.
        let mut w =
            std::io::BufWriter::new(std::fs::File::create(dir.join("cache.jsonl"))?);
        // Ids are small sequential allocations (f64-exact), unlike the
        // hashed request ids elsewhere — safe as JSON numbers. Version
        // floors fold in the live write clock (and the present stamps,
        // for caches replicating without a journal) so a restored node
        // never re-issues a version; the "floors" key is omitted when
        // empty, keeping unreplicated snapshots byte-identical to pre-
        // replication ones.
        let mut floors = self.version_floors.lock().unwrap().clone();
        for (origin, v) in self.sync_hwms() {
            let e = floors.entry(origin).or_insert(0);
            *e = (*e).max(v);
        }
        if let Some(r) = self.repl.get() {
            let e = floors.entry(r.node_id.clone()).or_insert(0);
            *e = (*e).max(r.clock.load(Ordering::Relaxed));
        }
        let mut meta_fields = vec![
            ("t", Json::str("meta")),
            (
                "next_id",
                Json::num(self.next_id.load(Ordering::Relaxed) as f64),
            ),
            ("relevance_threshold", Json::Num(self.relevance_threshold)),
        ];
        if !floors.is_empty() {
            meta_fields.push((
                "floors",
                Json::Obj(
                    floors
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ));
        }
        let meta = Json::obj(meta_fields);
        writeln!(w, "{}", meta.to_string())?;
        let stamp_fields = |s: Option<&Stamp>| -> Vec<(&'static str, Json)> {
            match s {
                Some(s) => vec![
                    ("so", Json::str(s.origin.clone())),
                    ("sv", Json::num(s.version as f64)),
                ],
                None => Vec::new(),
            }
        };
        for (si, shard) in self.objects.iter().enumerate() {
            let stamps = self.object_stamps[si].read().unwrap();
            for obj in shard.read().unwrap().values() {
                let mut fields = vec![
                    ("t", Json::str("obj")),
                    ("id", Json::num(obj.id as f64)),
                    ("text", Json::str(obj.text.clone())),
                    ("origin", Json::str(obj.origin.clone())),
                    ("doc", Json::Bool(obj.is_document)),
                ];
                fields.extend(stamp_fields(stamps.get(&obj.id)));
                writeln!(w, "{}", Json::obj(fields).to_string())?;
            }
        }
        for shard in &self.keys {
            for (key_id, entry) in shard.read().unwrap().iter() {
                let row = Json::obj(vec![
                    ("t", Json::str("key")),
                    ("id", Json::num(*key_id as f64)),
                    ("obj", Json::num(entry.object_id as f64)),
                    ("ctype", Json::str(entry.ctype.as_str())),
                ]);
                writeln!(w, "{}", row.to_string())?;
            }
        }
        for (si, shard) in self.exact.iter().enumerate() {
            let stamps = self.exact_stamps[si].read().unwrap();
            for (k, v) in shard.read().unwrap().iter() {
                // Keys are stored normalized; restore re-inserts them
                // verbatim (normalization is idempotent).
                let mut fields = vec![
                    ("t", Json::str("exact")),
                    ("k", Json::str(k.clone())),
                    ("v", Json::str(v.clone())),
                ];
                fields.extend(stamp_fields(stamps.get(k)));
                writeln!(w, "{}", Json::obj(fields).to_string())?;
            }
        }
        for shard in &self.exact_tombs {
            for (k, s) in shard.read().unwrap().iter() {
                let row = Json::obj(vec![
                    ("t", Json::str("tomb")),
                    ("k", Json::str(k.clone())),
                    ("so", Json::str(s.origin.clone())),
                    ("sv", Json::num(s.version as f64)),
                ]);
                writeln!(w, "{}", row.to_string())?;
            }
        }
        let f = w
            .into_inner()
            .map_err(|e| anyhow!("cache snapshot flush: {e}"))?;
        f.sync_all()?;
        Ok(())
    }

    /// Load a snapshot written by [`SemanticCache::snapshot_into`] back
    /// into a fresh cache via the validated bulk path, with the default
    /// index-tier policy. The *trained* state (centroids, assignments,
    /// nprobe) always comes from the snapshot itself; the policy knobs
    /// (migration threshold, retrain fraction, next train's parameters)
    /// come from the config — deployments that customized them via
    /// [`SemanticCache::with_index_config`] should restore through
    /// [`SemanticCache::restore_from_dir_with`] to keep their policy.
    pub fn restore_from_dir(dir: &Path, embed_dim: usize) -> Result<SemanticCache> {
        Self::restore_from_dir_with(dir, embed_dim, AdaptiveConfig::default())
    }

    /// [`SemanticCache::restore_from_dir`] with an explicit index-tier
    /// policy (the restore-side pair of `with_index_config`).
    pub fn restore_from_dir_with(
        dir: &Path,
        embed_dim: usize,
        cfg: AdaptiveConfig,
    ) -> Result<SemanticCache> {
        use std::io::BufRead as _;
        let index = AdaptiveIndex::load(&dir.join("vecdb.bin"), cfg)?;
        // Stream line-by-line, mirroring the writer: boot must not hold
        // the whole cache.jsonl text alongside the parsed rows.
        let reader = std::io::BufReader::new(std::fs::File::open(dir.join("cache.jsonl"))?);
        let mut objects = Vec::new();
        let mut keys = Vec::new();
        let mut exact = Vec::new();
        let mut meta: Option<(u64, f64)> = None;
        // Replication extras — absent (and free) in pre-replication
        // snapshots: per-entry stamps, tombstones, version floors.
        let mut obj_stamps: Vec<(u64, Stamp)> = Vec::new();
        let mut exact_stamps: Vec<(String, Stamp)> = Vec::new();
        let mut tombs: Vec<(String, Stamp)> = Vec::new();
        let mut floors: HashMap<String, u64> = HashMap::new();
        // "so"/"sv" are optional on obj/exact rows (legacy rows lack
        // them); a malformed half-present pair is rejected.
        let row_stamp = |row: &crate::util::json::Json| -> Result<Option<Stamp>> {
            match (row.get("so"), row.get("sv")) {
                (Some(_), _) | (_, Some(_)) => Ok(Some(Stamp {
                    origin: row.str_of("so")?,
                    version: row.f64_of("sv")? as u64,
                })),
                (None, None) => Ok(None),
            }
        };
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let row = crate::util::json::Json::parse(&line)?;
            match row.str_of("t")?.as_str() {
                "meta" => {
                    meta = Some((
                        row.f64_of("next_id")? as u64,
                        row.f64_of("relevance_threshold")?,
                    ));
                    if let Some(crate::util::json::Json::Obj(m)) = row.get("floors") {
                        for (origin, v) in m {
                            let v = v
                                .as_f64()
                                .ok_or_else(|| anyhow!("floor for '{origin}' not a number"))?;
                            floors.insert(origin.clone(), v as u64);
                        }
                    }
                }
                "obj" => {
                    let id = row.f64_of("id")? as u64;
                    if let Some(s) = row_stamp(&row)? {
                        obj_stamps.push((id, s));
                    }
                    objects.push(CacheObject {
                        id,
                        text: row.str_of("text")?,
                        origin: row.str_of("origin")?,
                        is_document: row
                            .req("doc")?
                            .as_bool()
                            .ok_or_else(|| anyhow!("object row 'doc' not a bool"))?,
                    })
                }
                "key" => keys.push((
                    row.f64_of("id")? as u64,
                    row.f64_of("obj")? as u64,
                    CachedType::parse(&row.str_of("ctype")?)
                        .ok_or_else(|| anyhow!("bad ctype in key row"))?,
                )),
                "exact" => {
                    let k = row.str_of("k")?;
                    if let Some(s) = row_stamp(&row)? {
                        exact_stamps.push((k.clone(), s));
                    }
                    exact.push((k, row.str_of("v")?))
                }
                "tomb" => tombs.push((
                    row.str_of("k")?,
                    Stamp {
                        origin: row.str_of("so")?,
                        version: row.f64_of("sv")? as u64,
                    },
                )),
                other => bail!("unknown cache snapshot row type '{other}'"),
            }
        }
        let (next_id, relevance_threshold) =
            meta.ok_or_else(|| anyhow!("cache snapshot missing meta row"))?;
        let cache = Self::restore_bulk(
            embed_dim,
            index,
            objects,
            keys,
            exact,
            next_id,
            relevance_threshold,
        )?;
        for (id, s) in obj_stamps {
            cache.object_stamps[Self::shard_of(id)]
                .write()
                .unwrap()
                .insert(id, s.clone());
            cache.object_by_stamp.write().unwrap().insert(s.clone(), id);
            cache.note_floor(&s);
        }
        for (k, s) in exact_stamps {
            cache.note_floor(&s);
            cache.exact_stamps[Self::shard_of_str(&k)]
                .write()
                .unwrap()
                .insert(k, s);
        }
        for (k, s) in tombs {
            cache.note_floor(&s);
            cache.exact_tombs[Self::shard_of_str(&k)]
                .write()
                .unwrap()
                .insert(k, s);
        }
        {
            let mut f = cache.version_floors.lock().unwrap();
            for (origin, v) in floors {
                let e = f.entry(origin).or_insert(0);
                *e = (*e).max(v);
            }
        }
        Ok(cache)
    }

    /// Validated bulk load: rebuild the sharded maps and adopt a loaded
    /// index wholesale, for **whichever tier is active** — the flat tier's
    /// id→slot map or the IVF tier's posting lists + id→(cell, slot) map
    /// were rebuilt by [`AdaptiveIndex::load`]; shard placement is
    /// re-derived here from the same id/key hashing the live path uses.
    /// Rejects dangling key→object references, keys without vectors,
    /// orphan vectors, duplicate ids, and a stale id allocator — a
    /// snapshot failing any of these is corrupt, and loading it would
    /// silently lose recall.
    #[allow(clippy::too_many_arguments)]
    pub fn restore_bulk(
        embed_dim: usize,
        index: AdaptiveIndex,
        objects: Vec<CacheObject>,
        keys: Vec<(u64, u64, CachedType)>,
        exact: Vec<(String, String)>,
        next_id: u64,
        relevance_threshold: f64,
    ) -> Result<SemanticCache> {
        if index.dim() != embed_dim {
            bail!(
                "snapshot vector dim {} does not match embed dim {embed_dim}",
                index.dim()
            );
        }
        if index.len() != keys.len() {
            bail!(
                "snapshot has {} vectors but {} key rows",
                index.len(),
                keys.len()
            );
        }
        let mut cache = SemanticCache::new(embed_dim);
        let object_ids: HashSet<u64> = objects.iter().map(|o| o.id).collect();
        if object_ids.len() != objects.len() {
            bail!("duplicate object id in snapshot");
        }
        let mut max_id = 0u64;
        for obj in objects {
            max_id = max_id.max(obj.id);
            cache.objects[Self::shard_of(obj.id)]
                .write()
                .unwrap()
                .insert(obj.id, obj);
        }
        for (key_id, object_id, ctype) in keys {
            if !index.contains(key_id) {
                bail!("key {key_id} has no vector in the snapshot index");
            }
            if !object_ids.contains(&object_id) {
                bail!("key {key_id} references unknown object {object_id}");
            }
            max_id = max_id.max(key_id);
            let prev = cache.keys[Self::shard_of(key_id)]
                .write()
                .unwrap()
                .insert(key_id, KeyEntry { object_id, ctype });
            if prev.is_some() {
                bail!("duplicate key id {key_id} in snapshot");
            }
        }
        if next_id <= max_id {
            bail!("snapshot next_id {next_id} not past max id {max_id}");
        }
        for (k, v) in exact {
            cache.exact[Self::shard_of_str(&k)].write().unwrap().insert(k, v);
        }
        *cache.index.write().unwrap() = index;
        cache.next_id.store(next_id, Ordering::Relaxed);
        cache.relevance_threshold = relevance_threshold;
        Ok(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn exact_path_normalizes() {
        let c = SemanticCache::new(8);
        c.put_exact("What is the  Capital of Sudan?", "Khartoum");
        assert_eq!(
            c.get_exact("what is the capital of sudan"),
            Some("Khartoum".to_string())
        );
        assert_eq!(c.get_exact("unrelated"), None);
    }

    #[test]
    fn cached_type_names_unique() {
        let all = [
            CachedType::Prompt,
            CachedType::Response,
            CachedType::Chunk,
            CachedType::HypotheticalQuestion,
            CachedType::Keyword,
            CachedType::Summary,
            CachedType::Fact,
        ];
        let names: std::collections::HashSet<&str> =
            all.iter().map(|t| t.as_str()).collect();
        assert_eq!(names.len(), all.len());
    }

    #[test]
    fn get_filter_default() {
        let f = GetFilter::default();
        assert_eq!(f.k, 4);
        assert!(f.types.is_none());
    }

    /// Engine-free concurrency smoke over the sharded exact path: mixed
    /// readers/writers across every shard, no deadlock, consistent counts.
    #[test]
    fn exact_path_concurrent_smoke() {
        let c = Arc::new(SemanticCache::new(8));
        let threads = 8;
        let per_thread = 200;
        std::thread::scope(|s| {
            for t in 0..threads {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for i in 0..per_thread {
                        let prompt = format!("thread {t} prompt number {i}");
                        c.put_exact(&prompt, "resp");
                        assert_eq!(c.get_exact(&prompt).as_deref(), Some("resp"));
                        // Cross-shard reads of other threads' keys.
                        let _ = c.get_exact(&format!("thread {} prompt number {i}", (t + 1) % threads));
                    }
                });
            }
        });
        let total: usize = c.exact.iter().map(|s| s.read().unwrap().len()).sum();
        assert_eq!(total, threads * per_thread);
        // Clear under the new guarded scopes empties every shard.
        c.clear();
        assert_eq!(c.get_exact("thread 0 prompt number 0"), None);
        assert_eq!(c.len_keys(), 0);
        assert_eq!(c.len_objects(), 0);
    }

    /// Engine-free snapshot roundtrip: populate via the WAL-replay path
    /// (synthetic embeddings), snapshot, bulk-restore, and compare maps.
    #[test]
    fn snapshot_roundtrip_via_bulk_load() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(77);
        let cache = SemanticCache::new(8);
        for i in 0..40u64 {
            let object = CacheObject {
                id: i * 3 + 1,
                text: format!("text {i}"),
                origin: format!("origin {i}"),
                is_document: i % 2 == 0,
            };
            let keys: Vec<(u64, CachedType, Vec<f32>)> = vec![
                (
                    i * 3 + 2,
                    CachedType::Prompt,
                    (0..8).map(|_| r.normal() as f32).collect(),
                ),
                (
                    i * 3 + 3,
                    CachedType::Response,
                    (0..8).map(|_| r.normal() as f32).collect(),
                ),
            ];
            cache.apply_logged_put(object, &keys).unwrap();
        }
        cache.put_exact("What is the  Capital of Sudan?", "Khartoum");
        let dir = std::env::temp_dir().join("llmbridge_cache_snap_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        cache.snapshot_into(&dir).unwrap();
        let back = SemanticCache::restore_from_dir(&dir, 8).unwrap();
        assert_eq!(back.len_objects(), cache.len_objects());
        assert_eq!(back.len_keys(), cache.len_keys());
        assert_eq!(back.len_exact(), 1);
        assert_eq!(back.next_id_hint(), cache.next_id_hint());
        assert_eq!(
            back.get_exact("what is the capital of sudan"),
            Some("Khartoum".to_string())
        );
        // Fresh ids allocate past everything restored.
        assert!(back.fresh_id() > 40 * 3);
        // Wrong engine dim is rejected before any partial load.
        assert!(SemanticCache::restore_from_dir(&dir, 16).is_err());
    }

    #[test]
    fn restore_bulk_rejects_inconsistent_snapshots() {
        use crate::vecdb::flat::FlatIndex;
        let adopt = |flat: FlatIndex| AdaptiveIndex::from_flat(flat, AdaptiveConfig::default());
        let mk_index = || {
            let mut idx = FlatIndex::new(4, Metric::Cosine);
            idx.insert(2, &[1.0, 0.0, 0.0, 0.0]).unwrap();
            adopt(idx)
        };
        let obj = CacheObject {
            id: 1,
            text: "t".into(),
            origin: "o".into(),
            is_document: false,
        };
        // Valid baseline.
        assert!(SemanticCache::restore_bulk(
            4,
            mk_index(),
            vec![obj.clone()],
            vec![(2, 1, CachedType::Prompt)],
            vec![],
            3,
            0.4,
        )
        .is_ok());
        // Key references a missing object.
        assert!(SemanticCache::restore_bulk(
            4,
            mk_index(),
            vec![obj.clone()],
            vec![(2, 9, CachedType::Prompt)],
            vec![],
            3,
            0.4,
        )
        .is_err());
        // Key row without a vector in the index.
        let mut idx = mk_index();
        idx.insert(5, &[0.0, 1.0, 0.0, 0.0]).unwrap();
        assert!(SemanticCache::restore_bulk(
            4,
            idx,
            vec![obj.clone()],
            vec![(2, 1, CachedType::Prompt), (7, 1, CachedType::Response)],
            vec![],
            8,
            0.4,
        )
        .is_err());
        // Orphan vector (index larger than the key rows).
        let mut idx = mk_index();
        idx.insert(5, &[0.0, 1.0, 0.0, 0.0]).unwrap();
        assert!(SemanticCache::restore_bulk(
            4,
            idx,
            vec![obj.clone()],
            vec![(2, 1, CachedType::Prompt)],
            vec![],
            6,
            0.4,
        )
        .is_err());
        // Stale id allocator.
        assert!(SemanticCache::restore_bulk(
            4,
            mk_index(),
            vec![obj],
            vec![(2, 1, CachedType::Prompt)],
            vec![],
            2,
            0.4,
        )
        .is_err());
    }

    /// Index rebuild racing concurrent readers: GETs keep the read lock
    /// only per-probe, the k-means runs with no lock held, and the swap
    /// lands without deadlock or lost rows.
    #[test]
    fn rebuild_races_concurrent_reads() {
        use crate::util::rng::Rng;
        use std::sync::atomic::AtomicBool;
        let cfg = AdaptiveConfig {
            migrate_threshold: 400,
            train_sample: 512,
            kmeans_iters: 2,
            ..AdaptiveConfig::default()
        };
        let cache = Arc::new(SemanticCache::with_index_config(8, cfg));
        let put = |r: &mut Rng, i: u64| {
            let base = i * 3 + 1;
            let emb = |r: &mut Rng| (0..8).map(|_| r.normal() as f32).collect::<Vec<f32>>();
            let keys = vec![
                (base + 1, CachedType::Prompt, emb(r)),
                (base + 2, CachedType::Response, emb(r)),
            ];
            cache
                .apply_logged_put(
                    CacheObject {
                        id: base,
                        text: format!("text {i}"),
                        origin: format!("origin {i}"),
                        is_document: false,
                    },
                    &keys,
                )
                .unwrap();
        };
        let mut r = Rng::new(0xACE);
        for i in 0..300u64 {
            put(&mut r, i);
        }
        assert_eq!(cache.index_stats().tier, "flat");
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let cache = Arc::clone(&cache);
                let stop = &stop;
                s.spawn(move || {
                    let mut r = Rng::new(t + 1);
                    while !stop.load(Ordering::Relaxed) {
                        let q: Vec<f32> = (0..8).map(|_| r.normal() as f32).collect();
                        let hits = cache.search_raw(&q, 4, f32::MIN);
                        assert!(hits.len() <= 4);
                    }
                });
            }
            for i in 300..600u64 {
                put(&mut r, i);
            }
            assert!(cache.maybe_rebuild_index(), "600 objects x2 keys > 400");
            stop.store(true, Ordering::Relaxed);
        });
        let stats = cache.index_stats();
        assert_eq!(stats.tier, "ivf");
        assert!(stats.trained);
        assert_eq!(stats.rows, 1200);
        assert_eq!(cache.len_keys(), 1200);
    }

    #[test]
    fn cached_type_tags_roundtrip() {
        for t in [
            CachedType::Prompt,
            CachedType::Response,
            CachedType::Chunk,
            CachedType::HypotheticalQuestion,
            CachedType::Keyword,
            CachedType::Summary,
            CachedType::Fact,
        ] {
            assert_eq!(CachedType::from_tag(t.tag()), Some(t));
            assert_eq!(CachedType::parse(t.as_str()), Some(t));
        }
        assert_eq!(CachedType::from_tag(9), None);
        assert_eq!(CachedType::parse("nope"), None);
    }

    /// The version tiebreaker in isolation: any interleaving of the same
    /// op set on two replicas converges to identical winners — no
    /// sockets, no engine, exact entries and tombstones applied straight
    /// through `apply_sync_entry`. An independent per-key max-stamp
    /// oracle checks the winner really is the highest stamp.
    #[test]
    fn prop_tiebreaker_any_interleaving_converges() {
        use crate::util::prop::forall;
        forall(
            0xC0FFEE,
            60,
            |r| {
                // Few keys, few origins, small versions: conflicts are
                // dense. (origin, version) pairs are deduplicated — a
                // real node's clock never issues the same version twice.
                let mut used: HashSet<(String, u64)> = HashSet::new();
                let n = 2 + r.below(10);
                (0..n)
                    .map(|_| {
                        let key = format!("key {}", r.below(4));
                        let origin =
                            format!("node-{}", (b'a' + r.below(3) as u8) as char);
                        let mut version = 1 + r.below(5) as u64;
                        while !used.insert((origin.clone(), version)) {
                            version += 1;
                        }
                        let stamp = Stamp { origin, version };
                        if r.chance(0.3) {
                            SyncEntry::Tomb { key, stamp }
                        } else {
                            SyncEntry::Exact {
                                response: format!(
                                    "{}@{}",
                                    stamp.origin, stamp.version
                                ),
                                key,
                                stamp,
                            }
                        }
                    })
                    .collect::<Vec<_>>()
            },
            |entries| {
                let a = SemanticCache::new(4);
                let b = SemanticCache::new(4);
                for e in entries {
                    a.apply_sync_entry(e.clone()).unwrap();
                }
                // Reverse order on b, then re-deliver everything forward
                // (idempotent re-delivery must not disturb the winners).
                for e in entries.iter().rev() {
                    b.apply_sync_entry(e.clone()).unwrap();
                }
                for e in entries {
                    b.apply_sync_entry(e.clone()).unwrap();
                }
                if a.replica_fingerprint() != b.replica_fingerprint() {
                    return false;
                }
                let mut winners: HashMap<&str, &SyncEntry> = HashMap::new();
                for e in entries {
                    let k = match e {
                        SyncEntry::Exact { key, .. } | SyncEntry::Tomb { key, .. } => {
                            key.as_str()
                        }
                        SyncEntry::Object { .. } => unreachable!(),
                    };
                    match winners.get(k) {
                        Some(cur) if !e.stamp().beats(cur.stamp()) => {}
                        _ => {
                            winners.insert(k, e);
                        }
                    }
                }
                winners.into_iter().all(|(k, e)| match e {
                    SyncEntry::Exact { response, .. } => {
                        a.get_exact(k).as_deref() == Some(response.as_str())
                    }
                    SyncEntry::Tomb { .. } => a.get_exact(k).is_none(),
                    SyncEntry::Object { .. } => true,
                })
            },
        );
    }

    /// Lamport rule: a local overwrite of an observed remote entry must
    /// outrank it globally, not just locally — the write clock advances
    /// past every stamp it has seen.
    #[test]
    fn local_overwrite_beats_observed_remote_stamp() {
        let c = SemanticCache::new(4);
        c.enable_replication("a");
        c.apply_sync_entry(SyncEntry::Exact {
            key: "k".into(),
            response: "remote".into(),
            stamp: Stamp {
                origin: "z".into(),
                version: 50,
            },
        })
        .unwrap();
        c.put_exact("k", "local");
        assert_eq!(c.get_exact("k").as_deref(), Some("local"));
        let hwms = c.sync_hwms();
        assert!(hwms["a"] > 50, "local stamp {:?} must beat version 50", hwms);
        // The delta against an empty peer ships the local winner.
        let delta = c.sync_delta(&HashMap::new());
        assert!(delta.iter().any(|e| matches!(
            e,
            SyncEntry::Exact { response, .. } if response == "local"
        )));
    }

    #[test]
    fn exact_shards_stripe() {
        // Distinct normalized prompts should not all land in one shard.
        let c = SemanticCache::new(8);
        for i in 0..64 {
            c.put_exact(&format!("prompt variant {i}"), "r");
        }
        let populated = c.exact.iter().filter(|s| !s.read().unwrap().is_empty()).count();
        assert!(populated > SHARD_COUNT / 2, "populated={populated}");
    }
}
